//! Out-of-core GenBank/PPI scenario (the paper's motivating workload):
//! kmer-family graphs under progressively tighter GPU memory constraints —
//! the Table III experiment, plus the AIRES memory plan internals
//! (Eq. 5-7 block budgets, B panelling, spill, segment cache) that explain
//! *why* AIRES keeps running where the baselines OOM.
//!
//! Run: `cargo run --release --example outofcore_kmer`

use aires::coordinator::{FEAT_DIM, LAYERS};
use aires::memsim::CostModel;
use aires::sched::{all_schedulers, Aires, Workload};
use aires::util::human_bytes;

fn main() {
    let cm = CostModel::default();

    for name in ["kV1r", "kP1a", "kA2a"] {
        let d = aires::graphgen::catalog::by_name(name).unwrap();
        println!(
            "== {} — {}M vertices, {}M edges, requires {} GB ==",
            d.name, d.vertices_m, d.edges_m, d.memory_req_gb
        );
        // Sweep from the Table II constraint down to 40% of the requirement.
        let caps: Vec<f64> = (0..6)
            .map(|i| d.memory_constraint_gb * (1.0 - 0.12 * i as f64))
            .collect();
        println!(
            "{:>9} {:>11} {:>9} {:>9} {:>9}   AIRES plan",
            "cap (GB)", "MaxMemory", "UCG", "ETC", "AIRES"
        );
        for cap in caps {
            let mut w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
            w.gpu_mem_bytes = (cap * 1e9) as u64;
            let mut cells = Vec::new();
            for s in all_schedulers() {
                let r = s.run_epoch(&w, &cm);
                cells.push(r.makespan_s.map_or("OOM".into(), |t| format!("{t:.2}s")));
            }
            let plan = Aires::plan(&w)
                .map(|p| {
                    format!(
                        "p={} panels={} spill={} cache={:.0}%",
                        human_bytes(p.p),
                        p.b_panels,
                        human_bytes(p.spill),
                        100.0 * p.cache_frac
                    )
                })
                .unwrap_or_else(|| "infeasible".into());
            println!(
                "{:>9.1} {:>11} {:>9} {:>9} {:>9}   {}",
                cap, cells[0], cells[1], cells[2], cells[3], plan
            );
        }
        println!();
    }

    // How far down does AIRES go? Find its floor for kV1r.
    let d = aires::graphgen::catalog::by_name("kV1r").unwrap();
    let mut lo = 0.5f64;
    let mut hi = d.memory_constraint_gb;
    for _ in 0..20 {
        let mid = (lo + hi) / 2.0;
        let mut w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
        w.gpu_mem_bytes = (mid * 1e9) as u64;
        if Aires::plan(&w).is_some() {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    println!(
        "AIRES feasibility floor for kV1r: ~{hi:.2} GB (vs 19 GB where ETC dies, 21 GB for UCG/MaxMemory)"
    );
}
