//! Regenerates every table and figure in the paper's evaluation (§V) and
//! writes `report.md` — the one-command reproduction entry point.
//!
//! Run: `cargo run --release --example reproduce_paper [-- out.md]`

use aires::coordinator::report::full_report;
use aires::memsim::CostModel;

fn main() {
    let out = std::env::args().nth(1).unwrap_or_else(|| "report.md".to_string());
    let cm = CostModel::default();
    let text = full_report(&cm);
    std::fs::write(&out, &text).expect("write report");
    print!("{text}");
    eprintln!("\nwrote {out}");
}
