//! Quickstart: one out-of-core SpGEMM through the full stack in ~40 lines.
//!
//! Builds a small kmer-like graph, RoBW-partitions it under a byte budget,
//! runs the aggregation through the AOT-compiled Pallas `bsr_spmm` artifact
//! on the PJRT CPU client, verifies against the in-crate CPU oracle, and
//! contrasts the naive partitioning's merge overhead with RoBW's (none).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use aires::gcn::model::dense_affine;
use aires::gcn::OocGcnLayer;
use aires::memsim::GpuMem;
use aires::partition::naive::{merge_overhead, naive_partition};
use aires::partition::robw::robw_partition;
use aires::sparse::norm::normalize_adjacency;
use aires::sparse::spmm::{spmm, Dense};
use aires::util::human_bytes;
use aires::util::rng::Pcg;

fn main() -> anyhow::Result<()> {
    // 1. A small protein-interaction-like graph (kmer family, Table II).
    let mut rng = Pcg::seed(2025);
    let n = 800;
    let a = aires::graphgen::kmer::generate(&mut rng, n, 3.4);
    let a_hat = normalize_adjacency(&a);
    println!("graph: {n} vertices, {} stored non-zeros", a_hat.nnz());

    // 2. The alignment story (paper Fig. 3/4): naive byte-granular cuts
    //    leave partial rows that must round-trip to the host; RoBW cuts
    //    only on row boundaries.
    let budget = 4096u64;
    let naive_segs = naive_partition(&a_hat, budget);
    let ov = merge_overhead(&naive_segs);
    let robw_segs = robw_partition(&a_hat, budget);
    println!(
        "naive partition : {} segments, {} partial cuts, {} merge round-trip",
        naive_segs.len(),
        ov.partial_cuts,
        human_bytes(ov.dtoh_bytes + ov.resend_bytes)
    );
    println!("RoBW  partition : {} segments, 0 partial cuts (by construction)", robw_segs.len());

    // 3. Aggregation + fused combine through the PJRT accelerator path.
    let f = 64;
    let x = Dense::from_vec(n, f, (0..n * f).map(|_| rng.normal() as f32).collect());
    let w = Dense::from_vec(f, f, (0..f * f).map(|_| (rng.normal() * 0.2) as f32).collect());
    let mut exec = aires::runtime::Executor::from_env()?;
    let layer = OocGcnLayer { w: w.clone(), b: vec![0.0; f], relu: true, seg_budget: budget };
    let mut mem = GpuMem::new(64 << 20);
    let (out, report) = layer.forward(&mut exec, &a_hat, &x, &mut mem)?;
    println!(
        "accelerator pass: {} RoBW segments, peak device memory {}",
        report.segments,
        human_bytes(report.peak_gpu_bytes)
    );

    // 4. Verify against the pure-rust oracle.
    let want = dense_affine(&spmm(&a_hat, &x), &w, &vec![0.0; f], true);
    let diff = out.max_abs_diff(&want);
    println!("max |accelerator - oracle| = {diff:.2e}");
    assert!(diff < 1e-3);
    println!("quickstart OK");
    Ok(())
}
