//! End-to-end driver (the mandated validation run, DESIGN.md):
//!
//!  1. REAL COMPUTE — trains a 2-layer GCN for several hundred steps on a
//!     synthetic kmer-family graph, every step executed through the
//!     AOT-compiled `gcn2_train_step` artifact on the PJRT CPU client
//!     (fwd + softmax-xent + bwd + SGD lowered from JAX; the combine tiles
//!     inside are the Pallas L1 kernel). Logs the loss curve.
//!  2. OUT-OF-CORE COMPUTE — runs one aggregation epoch of the same graph
//!     through the RoBW + `bsr_spmm` tile pipeline under a memory ledger,
//!     verified against the CPU oracle.
//!  3. PAPER-SCALE SCHEDULE — replays the same workload shape at Table II
//!     scale through all four schedulers and reports the per-epoch latency
//!     + speedups (the paper's headline metric).
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example train_gcn_e2e`

use aires::coordinator::{fig6_row, FEAT_DIM, LAYERS};
use aires::gcn::model::dense_affine;
use aires::gcn::{OocGcnLayer, Trainer};
use aires::memsim::{CostModel, GpuMem};
use aires::sched::Workload;
use aires::sparse::norm::normalize_adjacency;
use aires::sparse::spmm::{spmm, Dense};
use aires::util::rng::Pcg;
use aires::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let mut exec = aires::runtime::Executor::from_env()?;
    let mut rng = Pcg::seed(42);

    // ---------------------------------------------------------------- 1.
    println!("== Phase 1: real training through PJRT artifacts ==");
    let graph = aires::graphgen::kmer::generate(&mut rng, 1024, 3.2);
    let mut trainer = Trainer::new(&exec, &graph, 42)?;
    println!(
        "2-layer GCN: n={} f0={} hidden={} classes={} ({} trainable params)",
        trainer.n,
        trainer.f0,
        trainer.hidden,
        trainer.classes,
        trainer.f0 * trainer.hidden + trainer.hidden + trainer.hidden * trainer.classes + trainer.classes
    );
    let steps = 300;
    let sw = Stopwatch::start();
    for step in 0..steps {
        let loss = trainer.step(&mut exec, 2.0)?;
        if step % 25 == 0 || step + 1 == steps {
            println!("  step {step:4}  loss {loss:.4}");
        }
    }
    let train_secs = sw.secs();
    let first = trainer.losses[0];
    let last = *trainer.losses.last().unwrap();
    println!(
        "  {steps} steps in {:.1}s ({:.1} steps/s); loss {first:.4} -> {last:.4}",
        train_secs,
        steps as f64 / train_secs
    );
    assert!(last < first * 0.8, "training must make real progress");

    // ---------------------------------------------------------------- 2.
    println!("\n== Phase 2: out-of-core aggregation through RoBW + bsr_spmm ==");
    let a_hat = normalize_adjacency(&graph);
    let f = 64;
    let x = Dense::from_vec(1024, f, (0..1024 * f).map(|_| rng.normal() as f32).collect());
    let w = Dense::from_vec(f, f, (0..f * f).map(|_| (rng.normal() * 0.2) as f32).collect());
    let layer = OocGcnLayer { w: w.clone(), b: vec![0.0; f], relu: true, seg_budget: 8192 };
    let mut mem = GpuMem::new(128 << 20);
    let sw = Stopwatch::start();
    let (out, report) = layer.forward(&mut exec, &a_hat, &x, &mut mem)?;
    let ooc_secs = sw.secs();
    let want = dense_affine(&spmm(&a_hat, &x), &w, &vec![0.0; f], true);
    let diff = out.max_abs_diff(&want);
    println!(
        "  {} RoBW segments, ~{} artifact calls, {:.2}s, max diff vs oracle {diff:.2e}",
        report.segments, report.artifact_calls_estimate, ooc_secs
    );
    assert!(diff < 1e-3);

    // ---------------------------------------------------------------- 3.
    println!("\n== Phase 3: paper-scale scheduling (per-epoch latency) ==");
    let cm = CostModel::default();
    println!(
        "{:<10} {:>11} {:>9} {:>9} {:>9} | speedups",
        "dataset", "MaxMemory", "UCG", "ETC", "AIRES"
    );
    for d in aires::graphgen::CATALOG.iter() {
        let row = fig6_row(d, &cm);
        let fmt = |s: &str| {
            row.makespan(s).map_or("OOM".to_string(), |t| format!("{t:.2}s"))
        };
        println!(
            "{:<10} {:>11} {:>9} {:>9} {:>9} | {:.2}x / {:.2}x / {:.2}x",
            d.name,
            fmt("MaxMemory"),
            fmt("UCG"),
            fmt("ETC"),
            fmt("AIRES"),
            row.speedup_over("MaxMemory").unwrap_or(f64::NAN),
            row.speedup_over("UCG").unwrap_or(f64::NAN),
            row.speedup_over("ETC").unwrap_or(f64::NAN),
        );
    }
    // One-time preprocessing cost, reported separately (amortized).
    let d = aires::graphgen::catalog::by_name("kP1a").unwrap();
    let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    println!(
        "\nkP1a one-time RoBW preprocessing: {}",
        aires::util::human_secs(aires::sched::Aires::prep_time(&w, &cm))
    );

    println!("\ntrain_gcn_e2e OK");
    Ok(())
}
