"""L2 correctness: GCN model graph vs oracle; training step sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Skip (not error) when the property-testing dependency is absent from the
# offline image — the rust differential suite carries the oracle coverage.
hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _mk_graph(rng, n, f0, hd, c):
    a = (rng.random((n, n)) < 0.05).astype(np.float32)
    a = np.maximum(a, a.T)
    a_hat = np.asarray(ref.normalize_adj_ref(jnp.asarray(a)))
    x = rng.normal(size=(n, f0)).astype(np.float32)
    w1 = (rng.normal(size=(f0, hd)) * 0.3).astype(np.float32)
    b1 = np.zeros((hd,), np.float32)
    w2 = (rng.normal(size=(hd, c)) * 0.3).astype(np.float32)
    b2 = np.zeros((c,), np.float32)
    # Labels correlated with the features (quantile buckets of a random
    # projection) so the training-sanity tests have signal to fit.
    proj = x @ rng.normal(size=(f0,))
    y = np.clip(
        np.searchsorted(np.quantile(proj, np.linspace(0, 1, c + 1)[1:-1]), proj),
        0,
        c - 1,
    ).astype(np.int32)
    return tuple(jnp.asarray(v) for v in (a_hat, x, w1, b1, w2, b2, y))


class TestForward:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), f0=st.sampled_from([4, 8]), c=st.sampled_from([3, 7]))
    def test_fwd_matches_ref(self, seed, f0, c):
        rng = np.random.default_rng(seed)
        n, hd = 64, 16
        a_hat, x, w1, b1, w2, b2, _ = _mk_graph(rng, n, f0, hd, c)
        got = model.gcn2_fwd(a_hat, x, w1, b1, w2, b2, bm=64)
        want = ref.gcn2_fwd_ref(a_hat, x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_normalization_row_stochastic_like(self):
        """Â of a k-regular graph has rows summing to ~1."""
        n = 32
        a = np.zeros((n, n), np.float32)
        for i in range(n):
            a[i, (i + 1) % n] = 1.0
            a[(i + 1) % n, i] = 1.0
        a_hat = ref.normalize_adj_ref(jnp.asarray(a))
        np.testing.assert_allclose(np.asarray(a_hat).sum(1), np.ones(n), rtol=1e-5)


class TestTrainStep:
    def test_loss_decreases(self):
        rng = np.random.default_rng(0)
        n, f0, hd, c = 64, 8, 16, 4
        a_hat, x, w1, b1, w2, b2, y = _mk_graph(rng, n, f0, hd, c)
        lr = jnp.float32(3.0)
        step = jax.jit(model.gcn2_train_step)
        losses = []
        for _ in range(100):
            loss, w1, b1, w2, b2 = step(a_hat, x, w1, b1, w2, b2, y, lr)
            losses.append(float(loss))
        assert losses[-1] < losses[0] * 0.7, (losses[0], losses[-1])

    def test_loss_matches_ref_at_init(self):
        rng = np.random.default_rng(1)
        n, f0, hd, c = 64, 8, 16, 4
        a_hat, x, w1, b1, w2, b2, y = _mk_graph(rng, n, f0, hd, c)
        loss = model.gcn2_loss((w1, b1, w2, b2), a_hat, x, y)
        logits = ref.gcn2_fwd_ref(a_hat, x, w1, b1, w2, b2)
        want = ref.softmax_xent_ref(logits, y)
        np.testing.assert_allclose(float(loss), float(want), rtol=1e-4)

    def test_zero_lr_is_identity(self):
        rng = np.random.default_rng(2)
        a_hat, x, w1, b1, w2, b2, y = _mk_graph(rng, 64, 8, 16, 4)
        _, nw1, nb1, nw2, nb2 = model.gcn2_train_step(
            a_hat, x, w1, b1, w2, b2, y, jnp.float32(0.0)
        )
        np.testing.assert_array_equal(nw1, w1)
        np.testing.assert_array_equal(nw2, w2)

    def test_gradient_direction(self):
        """One step with tiny lr reduces loss (first-order check)."""
        rng = np.random.default_rng(3)
        a_hat, x, w1, b1, w2, b2, y = _mk_graph(rng, 64, 8, 16, 4)
        l0 = float(model.gcn2_loss((w1, b1, w2, b2), a_hat, x, y))
        _, nw1, nb1, nw2, nb2 = model.gcn2_train_step(
            a_hat, x, w1, b1, w2, b2, y, jnp.float32(1e-2)
        )
        l1 = float(model.gcn2_loss((nw1, nb1, nw2, nb2), a_hat, x, y))
        assert l1 < l0
