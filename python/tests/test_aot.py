"""AOT path: every artifact lowers to HLO text that the 0.5.1 parser accepts.

We can't run the rust loader from pytest, but we can assert the invariants it
relies on: text (not proto) interchange, ENTRY signature matching the
manifest, and tuple-rooted outputs.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


class TestHloText:
    def test_lowering_produces_text(self):
        lowered = jax.jit(lambda x: (x + 1.0,)).lower(
            jax.ShapeDtypeStruct((2, 2), jnp.float32)
        )
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:50]
        assert "ENTRY" in text

    def test_combine_lowering_has_tuple_root(self):
        lowered = jax.jit(
            lambda x, w, b: model.gcn_combine(x, w, b, bm=8)
        ).lower(
            jax.ShapeDtypeStruct((8, 4), jnp.float32),
            jax.ShapeDtypeStruct((4, 4), jnp.float32),
            jax.ShapeDtypeStruct((4,), jnp.float32),
        )
        text = aot.to_hlo_text(lowered)
        # return_tuple=True => root of ENTRY computation is a tuple shape
        entry = text.split("ENTRY")[1]
        assert "(f32[8,4]" in entry, entry[:200]


class TestManifest:
    def test_manifest_exists_and_files_present(self):
        for entry in _manifest():
            path = os.path.join(ART, entry["file"])
            assert os.path.exists(path), entry["file"]
            head = open(path).read(200)
            assert head.startswith("HloModule"), entry["file"]

    def test_manifest_covers_all_entry_points(self):
        names = {e["name"] for e in _manifest()}
        assert any(n.startswith("bsr_spmm_") for n in names)
        assert any(n.startswith("gcn_combine_") for n in names)
        assert any(n.startswith("gcn2_fwd_") for n in names)
        assert any(n.startswith("gcn2_train_step_") for n in names)

    def test_manifest_shapes_are_concrete(self):
        for entry in _manifest():
            for spec in entry["inputs"] + entry["outputs"]:
                assert all(isinstance(d, int) and d > 0 for d in spec["shape"]) or spec["shape"] == []
                assert spec["dtype"] in ("f32", "s32")

    def test_train_step_io_arity(self):
        (entry,) = [e for e in _manifest() if e["name"].startswith("gcn2_train_step")]
        assert len(entry["inputs"]) == 8  # a_hat,x,w1,b1,w2,b2,y,lr
        assert len(entry["outputs"]) == 5  # loss + 4 params

    def test_spmm_meta_consistent_with_shapes(self):
        for entry in _manifest():
            if not entry["name"].startswith("bsr_spmm_"):
                continue
            m = entry["meta"]
            nblk, colidx, blocks, h = entry["inputs"]
            assert nblk["shape"] == [m["r"]]
            assert colidx["shape"] == [m["r"], m["nb"]]
            assert blocks["shape"] == [m["r"], m["nb"], m["bm"], m["bk"]]
            assert h["shape"] == [m["k"], m["f"]]
            (out,) = entry["outputs"]
            assert out["shape"] == [m["r"] * m["bm"], m["f"]]
