"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/values; every property asserts allclose against
``ref.py``. This is the CORE correctness signal for the compute layer — the
rust runtime executes exactly the HLO these kernels lower to.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Skip (not error) when the property-testing dependency is absent from the
# offline image — the rust differential suite carries the oracle coverage.
hypothesis = pytest.importorskip("hypothesis")
st = pytest.importorskip("hypothesis.strategies")
given, settings = hypothesis.given, hypothesis.settings

from compile import model
from compile.kernels import ref
from compile.kernels.bsr_spmm import bsr_spmm
from compile.kernels.gcn_tile import gcn_combine

jax.config.update("jax_platform_name", "cpu")


def _mk_bsr(rng, r, nb, bm, bk, kb, f, *, full=False):
    nblk = (
        np.full((r,), nb, np.int32)
        if full
        else rng.integers(0, nb + 1, (r,)).astype(np.int32)
    )
    colidx = rng.integers(0, kb, (r, nb)).astype(np.int32)
    blocks = rng.normal(size=(r, nb, bm, bk)).astype(np.float32)
    h = rng.normal(size=(kb * bk, f)).astype(np.float32)
    return (
        jnp.asarray(nblk),
        jnp.asarray(colidx),
        jnp.asarray(blocks),
        jnp.asarray(h),
    )


class TestBsrSpmm:
    @settings(max_examples=25, deadline=None)
    @given(
        r=st.integers(1, 4),
        nb=st.integers(1, 6),
        bexp=st.integers(1, 4),
        kb=st.integers(1, 5),
        f=st.sampled_from([1, 3, 8, 17]),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref(self, r, nb, bexp, kb, f, seed):
        bm = bk = 2**bexp
        rng = np.random.default_rng(seed)
        nblk, colidx, blocks, h = _mk_bsr(rng, r, nb, bm, bk, kb, f)
        got = bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
        want = ref.bsr_spmm_ref(nblk, colidx, blocks, h, bm=bm, bk=bk)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_rectangular_blocks(self):
        rng = np.random.default_rng(7)
        r, nb, bm, bk, kb, f = 3, 5, 4, 16, 3, 9
        nblk, colidx, blocks, h = _mk_bsr(rng, r, nb, bm, bk, kb, f)
        got = bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
        want = ref.bsr_spmm_ref(nblk, colidx, blocks, h, bm=bm, bk=bk)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_valid_blocks_gives_zero_rows(self):
        rng = np.random.default_rng(1)
        nblk, colidx, blocks, h = _mk_bsr(rng, 2, 3, 8, 8, 2, 4)
        nblk = jnp.zeros_like(nblk)
        got = bsr_spmm(nblk, colidx, blocks, h, bm=8, bk=8)
        np.testing.assert_array_equal(got, np.zeros_like(got))

    def test_padding_is_ignored(self):
        """Garbage in padded tile slots must not leak into the output."""
        rng = np.random.default_rng(2)
        r, nb, bm, bk, kb, f = 2, 4, 8, 8, 2, 4
        nblk, colidx, blocks, h = _mk_bsr(rng, r, nb, bm, bk, kb, f)
        nblk = jnp.array([2, 1], jnp.int32)
        base = bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
        poisoned = np.asarray(blocks).copy()
        poisoned[0, 2:] = 1e9
        poisoned[1, 1:] = -1e9
        got = bsr_spmm(nblk, colidx, jnp.asarray(poisoned), h, bm=bm, bk=bk)
        np.testing.assert_allclose(got, base, rtol=1e-6)

    def test_duplicate_colidx_accumulates(self):
        """Two tiles pointing at the same block column must sum."""
        bm = bk = 4
        h = jnp.asarray(np.random.default_rng(3).normal(size=(8, 5)), jnp.float32)
        tile = jnp.eye(4, dtype=jnp.float32)
        blocks = jnp.stack([tile, tile])[None]  # [1, 2, 4, 4]
        nblk = jnp.array([2], jnp.int32)
        colidx = jnp.array([[1, 1]], jnp.int32)
        got = bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
        np.testing.assert_allclose(got, 2 * h[4:8], rtol=1e-6)

    def test_identity_blocks_select_h_rows(self):
        bm = bk = 8
        kb = 4
        h = jnp.asarray(np.random.default_rng(4).normal(size=(kb * bk, 6)), jnp.float32)
        blocks = jnp.eye(8, dtype=jnp.float32)[None, None]
        nblk = jnp.array([1], jnp.int32)
        for c in range(kb):
            colidx = jnp.array([[c]], jnp.int32)
            got = bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
            np.testing.assert_allclose(got, h[c * bk : (c + 1) * bk], rtol=1e-6)

    @pytest.mark.parametrize("suffix,r,nb,bm,bk,k,f", [
        ("r8_nb16_b32_k1024_f64", 8, 16, 32, 32, 1024, 64),
        ("r4_nb8_b64_k1024_f64", 4, 8, 64, 64, 1024, 64),
    ])
    def test_artifact_shapes(self, suffix, r, nb, bm, bk, k, f):
        """The exact shape variants aot.py emits must be valid + correct."""
        rng = np.random.default_rng(5)
        nblk, colidx, blocks, h = _mk_bsr(rng, r, nb, bm, bk, k // bk, f)
        got = bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
        want = ref.bsr_spmm_ref(nblk, colidx, blocks, h, bm=bm, bk=bk)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


class TestGcnCombine:
    @settings(max_examples=25, deadline=None)
    @given(
        tiles=st.integers(1, 4),
        bm=st.sampled_from([4, 8, 16]),
        f=st.integers(1, 40),
        h=st.integers(1, 24),
        relu=st.booleans(),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_matches_ref(self, tiles, bm, f, h, relu, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(tiles * bm, f)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(f, h)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(h,)), jnp.float32)
        got = gcn_combine(x, w, b, bm=bm, relu=relu)
        want = ref.gcn_combine_ref(x, w, b, relu=relu)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_relu_clamps_negatives(self):
        x = jnp.full((8, 4), -1.0, jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        out = gcn_combine(x, w, b, bm=8, relu=True)
        np.testing.assert_array_equal(out, np.zeros((8, 4), np.float32))

    def test_no_relu_passes_negatives(self):
        x = jnp.full((8, 4), -1.0, jnp.float32)
        w = jnp.eye(4, dtype=jnp.float32)
        b = jnp.zeros((4,), jnp.float32)
        out = gcn_combine(x, w, b, bm=8, relu=False)
        np.testing.assert_array_equal(out, np.full((8, 4), -1.0, np.float32))

    def test_bias_broadcast(self):
        x = jnp.zeros((4, 3), jnp.float32)
        w = jnp.zeros((3, 5), jnp.float32)
        b = jnp.arange(5, dtype=jnp.float32)
        out = gcn_combine(x, w, b, bm=4, relu=False)
        np.testing.assert_allclose(out, np.tile(np.arange(5, dtype=np.float32), (4, 1)))


class TestCombineVjp:
    """The hand-written VJP (model._combine) must match jnp autodiff."""

    @settings(max_examples=15, deadline=None)
    @given(
        bm=st.sampled_from([4, 8]),
        f=st.integers(1, 12),
        h=st.integers(1, 8),
        relu=st.booleans(),
        seed=st.integers(0, 2**32 - 1),
    )
    def test_grads_match_ref(self, bm, f, h, relu, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(2 * bm, f)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(f, h)), jnp.float32)
        b = jnp.asarray(rng.normal(size=(h,)), jnp.float32)

        def loss_kernel(x, w, b):
            return (model._combine(x, w, b, bm, relu) ** 2).sum()

        def loss_ref(x, w, b):
            return (ref.gcn_combine_ref(x, w, b, relu=relu) ** 2).sum()

        g_k = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
        g_r = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
        for a, bb in zip(g_k, g_r):
            np.testing.assert_allclose(a, bb, rtol=1e-3, atol=1e-3)
