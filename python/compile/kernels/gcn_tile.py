"""Layer-1 Pallas kernel: fused GCN combination tile (X @ W + b, ReLU).

Paper Eq. (3): H^{k+1} = sigma(X^{k} W^{k}). The combination matmul is dense
and MXU-shaped; we fuse bias + ReLU into the same tile so the activation
never round-trips through HBM. Grid tiles the row dimension (the RoBW block
rows produced by aggregation); W stays resident across the grid, which is
the TPU analogue of the paper keeping the weight panel in shared memory.

interpret=True for CPU-PJRT execution (see bsr_spmm.py docstring).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.dot(x, w, preferred_element_type=jnp.float32) + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "relu"))
def gcn_combine(x, w, b, *, bm, relu=True):
    """Fused combine: relu(x @ w + b), row-tiled by ``bm``.

    Shapes: x f32[P, F], w f32[F, H], b f32[H] -> f32[P, H]; P % bm == 0.
    """
    p, f = x.shape
    f2, h = w.shape
    assert f == f2 and p % bm == 0, (x.shape, w.shape, bm)

    kernel = functools.partial(_combine_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(p // bm,),
        in_specs=[
            pl.BlockSpec((bm, f), lambda i: (i, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, h), jnp.float32),
        interpret=True,
    )(x, w, b)
