"""Pure-jnp correctness oracles for the Pallas kernels.

These are the build-time ground truth: pytest asserts kernel == ref over
hypothesis-driven shape/value sweeps, and aot.py refuses to emit artifacts
if the smoke check fails. Keep these boring and obviously correct.
"""

import jax.numpy as jnp


def bsr_spmm_ref(nblk, colidx, blocks, h, *, bm, bk):
    """Dense reference for block-sparse SpMM (see bsr_spmm.bsr_spmm)."""
    r, nb = colidx.shape
    k, f = h.shape
    out = jnp.zeros((r * bm, f), jnp.float32)
    for i in range(r):
        acc = jnp.zeros((bm, f), jnp.float32)
        for j in range(nb):
            valid = j < int(nblk[i])
            if not valid:
                continue
            c = int(colidx[i, j])
            acc = acc + blocks[i, j] @ h[c * bk : (c + 1) * bk, :]
        out = out.at[i * bm : (i + 1) * bm, :].set(acc)
    return out


def gcn_combine_ref(x, w, b, *, relu=True):
    """Dense reference for the fused combine tile."""
    out = x @ w + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def normalize_adj_ref(a_dense):
    """Paper Eq. (2): A_tilde = D^-1/2 (A + I) D^-1/2 over a dense adjacency."""
    n = a_dense.shape[0]
    a_hat = a_dense + jnp.eye(n, dtype=a_dense.dtype)
    deg = a_hat.sum(axis=1)
    d_inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(deg), 0.0)
    return a_hat * d_inv_sqrt[:, None] * d_inv_sqrt[None, :]


def gcn2_fwd_ref(a_hat, x, w1, b1, w2, b2):
    """2-layer GCN forward, paper Eq. (4) applied twice (ReLU then logits)."""
    h1 = jnp.maximum(a_hat @ x @ w1 + b1[None, :], 0.0)
    return a_hat @ h1 @ w2 + b2[None, :]


def softmax_xent_ref(logits, labels):
    """Mean softmax cross-entropy with integer labels."""
    logits = logits - logits.max(axis=-1, keepdims=True)
    logz = jnp.log(jnp.exp(logits).sum(axis=-1))
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return (logz - ll).mean()
