"""Layer-1 Pallas kernel: block-sparse (BSR-like) SpMM.

This is the TPU-style adaptation of AIRES's CUDA SpGEMM kernel (paper §III-A
tiling + §IV CUDA kernels). The paper's CUDA kernel walks CSR(A) rows against
CSC(B) columns with scalar matching per thread; that idiom has no MXU analogue.
AIRES's core algorithmic insight — *row block-wise (RoBW) alignment: the
accelerator only ever receives complete, fixed-shape row blocks* — maps onto
the MXU as block-sparse SpMM:

  * each RoBW segment is re-expressed as ``bm x bk`` dense non-zero tiles
    (extracted by the rust-side ``sparse::block`` module),
  * the per-row-block tile list is padded to a static ``NB`` with a count
    vector (``nblk``) providing the mask — this is the static-shape analogue
    of CSR's variable row extents,
  * the feature panel ``H`` stays resident (VMEM on a real TPU, one buffer
    here) and tiles are gathered from it by block-column index — the
    BlockSpec grid expresses the HBM<->VMEM schedule the paper expressed
    with CUDA threadblocks.

Run under ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that the rust
runtime loads. Real-TPU VMEM/MXU characteristics are estimated analytically
in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bsr_spmm_kernel(nblk_ref, colidx_ref, blocks_ref, h_ref, o_ref, *, nb, bk):
    """One grid step: one row block (bm rows) x full feature width.

    nblk_ref:   s32[1]            number of valid tiles in this row block
    colidx_ref: s32[1, nb]        block-column index per tile (pad entries = 0)
    blocks_ref: f32[1, nb, bm, bk] dense non-zero tiles of the row block
    h_ref:      f32[K, F]          dense feature panel (K = kb * bk)
    o_ref:      f32[bm, F]         output rows for this row block
    """
    bm = blocks_ref.shape[2]
    f = h_ref.shape[1]
    n_valid = nblk_ref[0]

    def body(j, acc):
        cidx = colidx_ref[0, j]
        a_tile = blocks_ref[0, j]  # [bm, bk]
        # Gather the feature tile for this block column. On a real TPU this
        # is the HBM->VMEM DMA the BlockSpec schedule would issue; in
        # interpret mode it lowers to a dynamic-slice.
        h_tile = pl.load(h_ref, (pl.ds(cidx * bk, bk), slice(None)))  # [bk, F]
        contrib = jnp.dot(a_tile, h_tile, preferred_element_type=jnp.float32)
        # Padded tiles (j >= n_valid) are masked out rather than branched
        # over: the MXU pipeline prefers uniform work + select.
        return acc + jnp.where(j < n_valid, contrib, jnp.zeros_like(contrib))

    acc = jax.lax.fori_loop(0, nb, body, jnp.zeros((bm, f), jnp.float32))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def bsr_spmm(nblk, colidx, blocks, h, *, bm, bk):
    """Block-sparse SpMM: out[r*bm:(r+1)*bm, :] = sum_j blocks[r,j] @ H[colidx[r,j]].

    Shapes: nblk s32[R], colidx s32[R, NB], blocks f32[R, NB, bm, bk],
    h f32[K, F] -> f32[R*bm, F]. Static-shape entry point AOT-lowered by
    ``aot.py`` for the rust tile executor.
    """
    r, nb = colidx.shape
    k, f = h.shape
    assert blocks.shape == (r, nb, bm, bk), (blocks.shape, (r, nb, bm, bk))
    assert k % bk == 0

    kernel = functools.partial(_bsr_spmm_kernel, nb=nb, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(r,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (i,)),
            pl.BlockSpec((1, nb), lambda i: (i, 0)),
            pl.BlockSpec((1, nb, bm, bk), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((k, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r * bm, f), jnp.float32),
        interpret=True,
    )(nblk, colidx, blocks, h)
