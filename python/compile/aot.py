"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``

Interchange format is HLO text, NOT ``lowered.compile()``/``.serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the rust
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Each artifact is lowered with ``return_tuple=True`` (rust side untuples),
smoke-checked against the pure-jnp oracle before emission, and described in
``manifest.json`` so the rust ``runtime::artifacts`` registry can validate
shapes/dtypes at load time without re-parsing HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import ref

# ---------------------------------------------------------------------------
# Static shape configuration for the emitted artifacts. The rust tile
# executor pads/reshapes runtime data to these shapes (see rust/src/runtime).
# ---------------------------------------------------------------------------

# Aggregation tile op: R row blocks x NB padded tiles of bm x bk, K = kb*bk.
SPMM_VARIANTS = [
    # (name-suffix, R, NB, bm, bk, K, F)
    ("r8_nb16_b32_k1024_f64", 8, 16, 32, 32, 1024, 64),
    ("r4_nb8_b64_k1024_f64", 4, 8, 64, 64, 1024, 64),
    ("r8_nb16_b32_k1024_f128", 8, 16, 32, 32, 1024, 128),
]

# Fused combine tile: P rows x F in -> H out.
COMBINE_VARIANTS = [
    ("p256_f64_h64", 256, 64, 64, True),
    ("p256_f128_h64", 256, 128, 64, True),
    ("p256_f64_h16_nr", 256, 64, 16, False),
]

# e2e training subgraph: N nodes, F0 input features, H hidden, C classes.
TRAIN_N, TRAIN_F0, TRAIN_H, TRAIN_C = 1024, 32, 64, 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(x):
    kind = {"float32": "f32", "int32": "s32"}[str(x.dtype)]
    return {"shape": list(x.shape), "dtype": kind}


def _emit(out_dir, manifest, name, fn, example_args, meta=None):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    fname = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    outs = jax.eval_shape(fn, *example_args)
    if not isinstance(outs, (tuple, list)):
        outs = (outs,)
    entry = {
        "name": name,
        "file": fname,
        "inputs": [_spec(a) for a in example_args],
        "outputs": [_spec(o) for o in outs],
    }
    if meta:
        entry["meta"] = meta
    manifest.append(entry)
    print(f"  wrote {fname} ({len(text)} chars)")


def _smoke_check():
    """Refuse to emit artifacts if kernels disagree with the oracle."""
    rng = np.random.default_rng(0)
    r_, nb, bm, bk, k, f = 2, 4, 8, 8, 64, 16
    nblk = jnp.array([3, 1], jnp.int32)
    colidx = jnp.array(rng.integers(0, k // bk, (r_, nb)), jnp.int32)
    blocks = jnp.array(rng.normal(size=(r_, nb, bm, bk)), jnp.float32)
    h = jnp.array(rng.normal(size=(k, f)), jnp.float32)
    got = model.bsr_spmm(nblk, colidx, blocks, h, bm=bm, bk=bk)
    want = ref.bsr_spmm_ref(nblk, colidx, blocks, h, bm=bm, bk=bk)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    x = jnp.array(rng.normal(size=(16, 8)), jnp.float32)
    w = jnp.array(rng.normal(size=(8, 4)), jnp.float32)
    b = jnp.array(rng.normal(size=(4,)), jnp.float32)
    np.testing.assert_allclose(
        model.gcn_combine(x, w, b, bm=8),
        ref.gcn_combine_ref(x, w, b),
        rtol=1e-5,
        atol=1e-5,
    )
    print("  smoke check vs ref: OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    print("aot: smoke-checking kernels against oracle")
    _smoke_check()

    manifest = []
    print("aot: lowering artifacts")

    for suffix, r_, nb, bm, bk, k, f in SPMM_VARIANTS:
        spec = lambda shape, dt=jnp.float32: jnp.zeros(shape, dt)
        _emit(
            args.out_dir,
            manifest,
            f"bsr_spmm_{suffix}",
            lambda nblk, colidx, blocks, h, bm=bm, bk=bk: model.bsr_spmm(
                nblk, colidx, blocks, h, bm=bm, bk=bk
            ),
            (
                spec((r_,), jnp.int32),
                spec((r_, nb), jnp.int32),
                spec((r_, nb, bm, bk)),
                spec((k, f)),
            ),
            meta={"r": r_, "nb": nb, "bm": bm, "bk": bk, "k": k, "f": f},
        )

    for suffix, p, f, h, relu in COMBINE_VARIANTS:
        _emit(
            args.out_dir,
            manifest,
            f"gcn_combine_{suffix}",
            lambda x, w, b, relu=relu: model.gcn_combine(x, w, b, bm=64, relu=relu),
            (
                jnp.zeros((p, f), jnp.float32),
                jnp.zeros((f, h), jnp.float32),
                jnp.zeros((h,), jnp.float32),
            ),
            meta={"p": p, "f": f, "h": h, "relu": relu},
        )

    n, f0, hd, c = TRAIN_N, TRAIN_F0, TRAIN_H, TRAIN_C
    train_args = (
        jnp.zeros((n, n), jnp.float32),
        jnp.zeros((n, f0), jnp.float32),
        jnp.zeros((f0, hd), jnp.float32),
        jnp.zeros((hd,), jnp.float32),
        jnp.zeros((hd, c), jnp.float32),
        jnp.zeros((c,), jnp.float32),
    )
    _emit(
        args.out_dir,
        manifest,
        f"gcn2_fwd_n{n}_f{f0}_h{hd}_c{c}",
        model.gcn2_fwd,
        train_args,
        meta={"n": n, "f0": f0, "h": hd, "c": c},
    )
    _emit(
        args.out_dir,
        manifest,
        f"gcn2_train_step_n{n}_f{f0}_h{hd}_c{c}",
        model.gcn2_train_step,
        train_args + (jnp.zeros((n,), jnp.int32), jnp.zeros((), jnp.float32)),
        meta={"n": n, "f0": f0, "h": hd, "c": c},
    )

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"aot: wrote manifest.json with {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
