"""Layer-2: the GCN compute graph in JAX, calling the Layer-1 Pallas kernels.

Build-time only — ``aot.py`` lowers the jitted entry points here to HLO text;
the rust coordinator loads and executes those artifacts via PJRT. Python is
never on the request path.

Entry points (static shapes chosen by aot.py):
  * ``bsr_spmm``       — re-exported L1 kernel, the aggregation tile op the
                         rust tile executor drives per RoBW segment.
  * ``gcn_combine``    — re-exported L1 fused combine tile.
  * ``gcn2_fwd``       — dense 2-layer GCN forward over a small subgraph
                         (used by the e2e example for validation).
  * ``gcn2_train_step``— full fwd + softmax-xent + backward + SGD in one
                         donated-buffer step: the loss-curve driver.
"""

import functools

import jax
import jax.numpy as jnp

from compile.kernels.bsr_spmm import bsr_spmm
from compile.kernels.gcn_tile import gcn_combine

__all__ = ["bsr_spmm", "gcn_combine", "gcn2_fwd", "gcn2_loss", "gcn2_train_step"]


# Pallas interpret-mode has no reverse-mode AD rule, so the combine tile gets
# a hand-written VJP: forward runs the Pallas kernel (the artifact's hot
# path), backward is plain-jnp matmul transposes — the standard Pallas
# custom_vjp pattern.
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _combine(x, w, b, bm, relu):
    return gcn_combine(x, w, b, bm=bm, relu=relu)


def _combine_fwd(x, w, b, bm, relu):
    out = gcn_combine(x, w, b, bm=bm, relu=relu)
    return out, (x, w, out)


def _combine_bwd(bm, relu, resids, g):
    x, w, out = resids
    if relu:
        g = g * (out > 0.0).astype(g.dtype)
    return (g @ w.T, x.T @ g, g.sum(axis=0))


_combine.defvjp(_combine_fwd, _combine_bwd)


def gcn2_fwd(a_hat, x, w1, b1, w2, b2, *, bm=64):
    """2-layer GCN forward (paper Eq. 4 twice): logits = Â·relu(Â·X·W1)·W2.

    Aggregation (Â @ ·) is dense here — this entry point serves small
    subgraphs where Â fits; the out-of-core path aggregates via the
    ``bsr_spmm`` tiles instead. Combination runs through the fused L1 tile.
    """
    agg1 = a_hat @ x
    h1 = _combine(agg1, w1, b1, bm, True)
    agg2 = a_hat @ h1
    return _combine(agg2, w2, b2, bm, False)


def gcn2_loss(params, a_hat, x, y, *, bm=64):
    """Mean softmax cross-entropy of the 2-layer GCN on integer labels."""
    w1, b1, w2, b2 = params
    logits = gcn2_fwd(a_hat, x, w1, b1, w2, b2, bm=bm)
    logits = logits - jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
    logz = jnp.log(jnp.exp(logits).sum(axis=-1))
    ll = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
    return (logz - ll).mean()


def gcn2_train_step(a_hat, x, w1, b1, w2, b2, y, lr):
    """One SGD step; returns (loss, w1', b1', w2', b2').

    Lowered once with donated weight buffers; the rust e2e driver loops this
    artifact to produce the loss curve in EXPERIMENTS.md.
    """
    loss, grads = jax.value_and_grad(gcn2_loss)((w1, b1, w2, b2), a_hat, x, y)
    g1, gb1, g2, gb2 = grads
    return loss, w1 - lr * g1, b1 - lr * gb1, w2 - lr * g2, b2 - lr * gb2
