//! Perf-trajectory store: golden-vector pin of the JSONL record
//! encoding (the on-disk format must not drift silently), fault
//! injection on the skip-and-report reader (torn tail, garbage line,
//! wrong schema — typed errors, never panics), nearest-rank percentile
//! vs a naive sort-based oracle (property), and the regression gate's
//! pass/fail/vacuous semantics on synthetic trajectories.

use aires::benchdb::{
    append_records, gate, gated_metric, parse_trajectory, read_trajectory,
    records_from_bench_json, scenario_stats, trend_lines, unit_for, BenchDbError, RunRecord,
    Trajectory, SCHEMA_VERSION,
};
use aires::testing::{check, TempDir};
use aires::util::percentile;

fn rec(commit: &str, ts: u64, scenario: &str, metric: &str, value: f64) -> RunRecord {
    RunRecord {
        commit: commit.to_string(),
        ts,
        scenario: scenario.to_string(),
        metric: metric.to_string(),
        value,
        unit: unit_for(metric).to_string(),
    }
}

fn traj(records: Vec<RunRecord>) -> Trajectory {
    Trajectory { records, skipped: Vec::new() }
}

/// Naive sort-based nearest-rank oracle, written independently of the
/// library: sort a copy, index at `round(p/100 * (n-1))`.
fn oracle_percentile(values: &[f64], p: f64) -> f64 {
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len();
    let rank = ((p.clamp(0.0, 100.0) / 100.0) * (n as f64 - 1.0)).round() as usize;
    sorted[rank.min(n - 1)]
}

// --- golden vectors: the on-disk line format, byte for byte -------------

#[test]
fn golden_record_encoding_is_byte_stable() {
    assert_eq!(SCHEMA_VERSION, 1, "bumping the schema invalidates these vectors on purpose");
    let r = rec("abc123", 1722873600, "fresh_depth1", "ns_per_segment", 1234.5);
    assert_eq!(
        r.to_line(),
        r#"{"commit":"abc123","metric":"ns_per_segment","scenario":"fresh_depth1","schema":1,"ts":1722873600,"unit":"ns","value":1234.5}"#
    );
    // Dotted serve-percentile path, seconds unit, fractional value.
    let r2 = rec("deadbeef", 1, "serve_open_loop", "per_tenant.tenant_0.p99_s", 0.5);
    assert_eq!(
        r2.to_line(),
        r#"{"commit":"deadbeef","metric":"per_tenant.tenant_0.p99_s","scenario":"serve_open_loop","schema":1,"ts":1,"unit":"s","value":0.5}"#
    );
    // The canonical lines decode back to the records they encode.
    let parsed = parse_trajectory(&format!("{}\n{}\n", r.to_line(), r2.to_line()));
    assert!(parsed.skipped.is_empty(), "{:?}", parsed.skipped);
    assert_eq!(parsed.records, vec![r, r2]);
}

// --- fault injection: skip-and-report, never panic ----------------------

#[test]
fn reader_skips_and_reports_defective_lines() {
    let good1 = rec("a", 1, "s", "ns_per_segment", 1.0).to_line();
    let good2 = rec("b", 2, "s", "ns_per_segment", 2.0).to_line();
    let wrong_schema = good1.replace("\"schema\":1", "\"schema\":99");
    let torn = &good2[..good2.len() / 2];
    // Garbage first, a blank line in the middle, the torn tail last.
    let text = format!("not json at all\n{good1}\n{wrong_schema}\n\n{good2}\n{torn}");
    let parsed = parse_trajectory(&text);
    assert_eq!(parsed.records.len(), 2, "valid records survive: {:?}", parsed.skipped);
    assert_eq!(parsed.skipped.len(), 3);
    assert_eq!(parsed.skipped[0].line, 1);
    assert!(matches!(parsed.skipped[0].error, BenchDbError::Malformed(_)));
    assert_eq!(parsed.skipped[1].line, 3);
    assert!(matches!(
        parsed.skipped[1].error,
        BenchDbError::WrongSchema { found: 99, expected: 1 }
    ));
    assert_eq!(parsed.skipped[2].line, 6);
    assert!(matches!(parsed.skipped[2].error, BenchDbError::Malformed(_)));
    // The valid prefix still renders: stats see both surviving samples.
    let stats = scenario_stats(&parsed);
    assert_eq!(stats.len(), 1);
    assert_eq!(stats[0].samples, 2);
    assert_eq!(stats[0].latest, 2.0);
}

#[test]
fn typed_errors_for_missing_and_bad_fields() {
    let base = rec("a", 1, "s", "m", 1.0).to_line();
    let no_commit = base.replace("\"commit\":\"a\",", "");
    let bad_ts = base.replace("\"ts\":1", "\"ts\":-3");
    let bad_value = base.replace("\"value\":1", "\"value\":\"fast\"");
    let parsed = parse_trajectory(&format!("{no_commit}\n{bad_ts}\n{bad_value}\n[1,2]\n"));
    assert!(parsed.records.is_empty());
    assert_eq!(parsed.skipped.len(), 4);
    assert_eq!(parsed.skipped[0].error, BenchDbError::MissingField("commit"));
    assert!(matches!(parsed.skipped[1].error, BenchDbError::BadField { field: "ts", .. }));
    assert!(matches!(parsed.skipped[2].error, BenchDbError::BadField { field: "value", .. }));
    assert!(matches!(parsed.skipped[3].error, BenchDbError::Malformed(_)));
}

#[test]
fn missing_trajectory_file_is_a_typed_io_error() {
    let dir = TempDir::new("benchdb-io");
    let err = read_trajectory(&dir.path().join("nope.jsonl")).unwrap_err();
    assert!(matches!(err, BenchDbError::Io(_)));
}

#[test]
fn append_creates_parents_and_recovers_from_a_torn_tail() {
    let dir = TempDir::new("benchdb-append");
    let path = dir.path().join("nested/store/trajectory.jsonl");
    append_records(&path, &[rec("a", 1, "s", "ns_per_segment", 10.0)]).unwrap();
    append_records(&path, &[rec("b", 2, "s", "ns_per_segment", 11.0)]).unwrap();
    let parsed = read_trajectory(&path).unwrap();
    assert_eq!(parsed.records.len(), 2);
    assert!(parsed.skipped.is_empty());
    // Simulate a crash mid-append: tear the final line.
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() - 20]).unwrap();
    let parsed = read_trajectory(&path).unwrap();
    assert_eq!(parsed.records.len(), 1, "the valid prefix survives the tear");
    assert_eq!(parsed.skipped.len(), 1);
    assert!(matches!(parsed.skipped[0].error, BenchDbError::Malformed(_)));
    // The store stays appendable: the next run starts on a fresh line,
    // leaving the torn fragment isolated instead of corrupting it too.
    append_records(&path, &[rec("c", 3, "s", "ns_per_segment", 12.0)]).unwrap();
    let parsed = read_trajectory(&path).unwrap();
    assert_eq!(parsed.records.len(), 2);
    assert_eq!(parsed.skipped.len(), 1);
    assert_eq!(parsed.latest_run(), Some((3, "c".to_string())));
}

// --- property: nearest-rank percentile vs the sort oracle ---------------

#[test]
fn percentile_matches_sort_oracle_property() {
    check("percentile == sort oracle", 41, |rng| {
        let n = rng.range(1, 64);
        let mode = rng.range(0, 3);
        let values: Vec<f64> = (0..n)
            .map(|_| match mode {
                0 => rng.f64() * 10.0,               // spread samples
                1 => (rng.range(0, 4) as f64) * 0.5, // heavy ties
                _ => 2.5,                            // all equal
            })
            .collect();
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut prev = f64::NEG_INFINITY;
        for p in [0.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
            let got = percentile(&sorted, p);
            if got != oracle_percentile(&values, p) {
                return Err(format!(
                    "p={p}: got {got}, oracle {} (n={n})",
                    oracle_percentile(&values, p)
                ));
            }
            if got != aires::gcn::serve::percentile(&sorted, p) {
                return Err(format!("p={p}: serve::percentile disagrees with util"));
            }
            if !values.contains(&got) {
                return Err(format!("p={p}: {got} is not a member of the sample"));
            }
            if got < prev {
                return Err(format!("percentile not monotone in p: {got} < {prev} at p={p}"));
            }
            prev = got;
        }
        let p = rng.f64() * 100.0;
        if percentile(&sorted, p) != oracle_percentile(&values, p) {
            return Err(format!("random p={p}: oracle mismatch"));
        }
        if percentile(&sorted, 0.0) != sorted[0] || percentile(&sorted, 100.0) != sorted[n - 1] {
            return Err("p=0/p=100 must be min/max".to_string());
        }
        Ok(())
    });
}

#[test]
fn report_percentiles_match_sort_oracle_property() {
    check("scenario_stats p50/p99 == sort oracle", 42, |rng| {
        let runs = rng.range(1, 12);
        let mut records = Vec::new();
        let mut values = Vec::new();
        for r in 0..runs {
            // Quantized draws so tied samples across runs are common.
            let v = (rng.f64() * 800.0).round() / 8.0;
            values.push(v);
            records.push(rec(&format!("c{r:02}"), 100 + r as u64, "scen", "ns_per_segment", v));
        }
        let stats = scenario_stats(&traj(records));
        if stats.len() != 1 {
            return Err(format!("expected one series, got {}", stats.len()));
        }
        let s = &stats[0];
        if s.samples != runs {
            return Err(format!("samples {} != runs {runs}", s.samples));
        }
        for (name, got, p) in [("p50", s.p50, 50.0), ("p99", s.p99, 99.0), ("min", s.min, 0.0)] {
            if got != oracle_percentile(&values, p) {
                return Err(format!(
                    "{name}: got {got}, oracle {}",
                    oracle_percentile(&values, p)
                ));
            }
        }
        if s.latest != *values.last().unwrap() {
            return Err(format!("latest {} != newest run's value", s.latest));
        }
        Ok(())
    });
}

// --- the regression gate ------------------------------------------------

#[test]
fn gate_fails_beyond_threshold_and_passes_within() {
    let mut records = vec![
        rec("run-a", 100, "fresh_depth1", "ns_per_segment", 100.0),
        rec("run-b", 200, "fresh_depth1", "ns_per_segment", 104.0),
    ];
    // run-b vs the run-a baseline: +4% is within a 10% threshold.
    let out = gate(&traj(records.clone()), 10.0);
    assert_eq!(out.baseline_runs, 1);
    assert_eq!(out.checks.len(), 1);
    assert!((out.checks[0].regress_pct - 4.0).abs() < 1e-9, "{:?}", out.checks[0]);
    assert!(out.passed());
    // A synthetic 2x regression fails the same threshold...
    records.push(rec("run-c", 300, "fresh_depth1", "ns_per_segment", 208.0));
    let out = gate(&traj(records.clone()), 10.0);
    assert_eq!(out.baseline_runs, 2);
    assert_eq!(out.checks[0].baseline_median, 104.0, "nearest-rank median of [100, 104]");
    assert_eq!(out.checks[0].regress_pct, 100.0);
    assert!(!out.passed());
    assert!(out.checks[0].failed);
    // ...but a generous threshold admits it.
    assert!(gate(&traj(records.clone()), 150.0).passed());
    // An improvement (negative regression) always passes.
    records.push(rec("run-d", 400, "fresh_depth1", "ns_per_segment", 90.0));
    let out = gate(&traj(records), 10.0);
    assert!(out.passed());
    assert!(out.checks[0].regress_pct < 0.0);
}

#[test]
fn gate_is_vacuous_without_a_baseline() {
    // Empty store: nothing to gate, nothing to divide by.
    let out = gate(&Trajectory::default(), 5.0);
    assert!(out.passed());
    assert_eq!((out.baseline_runs, out.checks.len()), (0, 0));
    assert_eq!(out.latest_run, None);
    // A single run seeds the baseline instead of being judged.
    let out = gate(&traj(vec![rec("a", 1, "s", "ns_per_segment", 5.0)]), 5.0);
    assert!(out.passed());
    assert_eq!((out.baseline_runs, out.checks.len()), (0, 0));
    assert_eq!(out.latest_run, Some((1, "a".to_string())));
}

#[test]
fn gate_skips_zero_baselines_and_ungated_metrics() {
    let records = vec![
        rec("a", 1, "s", "ns_per_segment", 0.0),
        rec("a", 1, "s", "allocs_per_segment", 5.0),
        rec("b", 2, "s", "ns_per_segment", 50.0),
        // 100x worse, but allocation counts are reported, not gated.
        rec("b", 2, "s", "allocs_per_segment", 500.0),
    ];
    let out = gate(&traj(records), 5.0);
    assert!(out.passed(), "a zero baseline must be skipped, never divided: {out:?}");
    assert_eq!(out.skipped_zero_baseline, 1);
    assert!(out.checks.is_empty());
    // A metric first seen in the newest run has no priors: skipped too.
    let out = gate(
        &traj(vec![
            rec("a", 1, "s", "ns_per_segment", 10.0),
            rec("b", 2, "s", "ns_per_segment", 10.0),
            rec("b", 2, "s2", "ns_per_segment", 99.0),
        ]),
        5.0,
    );
    assert!(out.passed());
    assert_eq!(out.checks.len(), 1);
    assert!(gated_metric("ns_per_segment"));
    assert!(gated_metric("ns_per_layer"));
    assert!(gated_metric("ns_per_step"));
    assert!(gated_metric("per_tenant.tenant_0.p99_s"));
    assert!(gated_metric("bytes_per_segment"), "encoded footprint is gated");
    assert!(!gated_metric("per_tenant.tenant_0.p50_s"));
    assert!(!gated_metric("allocs_per_segment"));
    assert!(!gated_metric("segments_per_s"));
}

// --- cross-commit trend lines -------------------------------------------

#[test]
fn trend_lines_order_runs_and_stamp_deltas() {
    let records = vec![
        // Out of file order on purpose: runs must sort by (ts, commit).
        rec("run-c", 300, "train_stream", "ns_per_step", 150.0),
        rec("run-a", 100, "train_stream", "ns_per_step", 100.0),
        rec("run-b", 200, "train_stream", "ns_per_step", 120.0),
        // Ungated series never trend.
        rec("run-a", 100, "train_stream", "allocs_per_step", 7.0),
        // A duplicated metric within one run: last record in file order
        // wins, same resolution as scenario_stats' `latest`.
        rec("run-b", 200, "train_stream", "ns_per_step", 110.0),
    ];
    let trends = trend_lines(&traj(records));
    assert_eq!(trends.len(), 1, "only the gated series trends: {trends:?}");
    let t = &trends[0];
    assert_eq!((t.scenario.as_str(), t.metric.as_str(), t.unit.as_str()),
               ("train_stream", "ns_per_step", "ns"));
    let values: Vec<f64> = t.points.iter().map(|p| p.value).collect();
    assert_eq!(values, vec![100.0, 110.0, 150.0], "oldest first, dup resolved");
    assert_eq!(t.points[0].delta_pct, None, "first run has nothing previous");
    assert!((t.points[1].delta_pct.unwrap() - 10.0).abs() < 1e-9);
    assert!((t.points[2].delta_pct.unwrap() - (40.0 / 110.0 * 100.0)).abs() < 1e-9);
    assert_eq!(t.points[2].run, (300, "run-c".to_string()));
}

#[test]
fn trend_lines_skip_zero_previous_values() {
    let records = vec![
        rec("a", 1, "s", "p99_s", 0.0),
        rec("b", 2, "s", "p99_s", 0.5),
        rec("c", 3, "s", "p99_s", 1.0),
    ];
    let trends = trend_lines(&traj(records));
    assert_eq!(trends[0].points[1].delta_pct, None, "zero previous: nothing to divide by");
    assert_eq!(trends[0].points[2].delta_pct, Some(100.0));
    // An empty trajectory trends nothing.
    assert!(trend_lines(&Trajectory::default()).is_empty());
}

// --- ingest: BENCH_streaming.json → records -----------------------------

#[test]
fn ingest_flattens_bench_emission_including_serve_percentiles() {
    let text = r#"{"bench":"micro_hotpath/streaming","graph":"kmer-12000","results":{"fresh_depth1":{"mean_s":0.01,"ns_per_segment":100.5},"segread_packed":{"bytes_per_segment":4096.0,"ns_per_segment":80.0},"serve_open_loop":{"ledger_balanced":true,"per_tenant":{"tenant_0":{"p50_s":0.001,"p99_s":0.002}},"segments_per_s":500}}}"#;
    let recs = records_from_bench_json(text, "abc", 7).unwrap();
    let find = |scenario: &str, metric: &str| {
        recs.iter()
            .find(|r| r.scenario == scenario && r.metric == metric)
            .unwrap_or_else(|| panic!("missing {scenario}/{metric} in {recs:?}"))
    };
    assert_eq!(find("fresh_depth1", "ns_per_segment").value, 100.5);
    assert_eq!(find("fresh_depth1", "ns_per_segment").unit, "ns");
    assert_eq!(find("fresh_depth1", "mean_s").unit, "s");
    // The encoded-store footprint series ingests with its own unit.
    assert_eq!(find("segread_packed", "bytes_per_segment").value, 4096.0);
    assert_eq!(find("segread_packed", "bytes_per_segment").unit, "bytes");
    // Serve open-loop percentiles land in the same record stream.
    assert_eq!(find("serve_open_loop", "per_tenant.tenant_0.p99_s").value, 0.002);
    assert_eq!(find("serve_open_loop", "per_tenant.tenant_0.p99_s").unit, "s");
    assert_eq!(find("serve_open_loop", "segments_per_s").unit, "seg/s");
    // Booleans trend as 0/1; the non-results top-level keys do not ingest.
    assert_eq!(find("serve_open_loop", "ledger_balanced").value, 1.0);
    assert_eq!(recs.len(), 8);
    for r in &recs {
        assert_eq!((r.commit.as_str(), r.ts), ("abc", 7));
    }
}

#[test]
fn ingest_rejects_non_bench_sources() {
    for bad in ["{}", "[]", r#"{"results":{}}"#, r#"{"results":3}"#, "not json"] {
        assert!(
            matches!(records_from_bench_json(bad, "c", 1), Err(BenchDbError::BadSource(_))),
            "source {bad:?} must be a BadSource error"
        );
    }
}

#[test]
fn ingest_append_report_gate_end_to_end() {
    let dir = TempDir::new("benchdb-e2e");
    let db = dir.path().join("trajectory.jsonl");
    let emission = |ns: f64| {
        format!(r#"{{"bench":"micro_hotpath/streaming","results":{{"fresh_depth1":{{"ns_per_segment":{ns}}}}}}}"#)
    };
    for (commit, ts, ns) in [("run-a", 10u64, 100.0), ("run-b", 20, 102.0)] {
        let recs = records_from_bench_json(&emission(ns), commit, ts).unwrap();
        append_records(&db, &recs).unwrap();
    }
    let parsed = read_trajectory(&db).unwrap();
    assert!(parsed.skipped.is_empty());
    assert_eq!(parsed.runs().len(), 2);
    assert!(gate(&parsed, 10.0).passed(), "+2% is within 10%");
    // A 10x regression lands as the newest run and fails the gate.
    let recs = records_from_bench_json(&emission(1000.0), "run-c", 30).unwrap();
    append_records(&db, &recs).unwrap();
    let parsed = read_trajectory(&db).unwrap();
    let out = gate(&parsed, 10.0);
    assert!(!out.passed());
    assert_eq!(out.latest_run, Some((30, "run-c".to_string())));
    let stats = scenario_stats(&parsed);
    assert_eq!(stats[0].samples, 3);
    assert_eq!(stats[0].latest, 1000.0);
    assert_eq!(stats[0].min, 100.0);
}
