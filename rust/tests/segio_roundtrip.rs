//! Roundtrip and rejection properties of the on-disk segment format
//! (`sparse::segio`).
//!
//! Contract: `decode(encode(m)) == m` for every CSR the operand
//! generators can produce (random, pathological, rmat, road, kmer), the
//! encoding itself is byte-stable (`encode(decode(encode(m))) ==
//! encode(m)`), and every structural defect — wrong version, corrupt
//! header or payload, truncation — is rejected with the *typed*
//! [`SegioError`] variant naming that defect, never a panic and never a
//! silently wrong matrix.

use aires::partition::robw::{materialize, robw_partition};
use aires::sparse::segio::{
    decode_panel, decode_panel_into, decode_segment, decode_segment_into, decode_segment_ref,
    encode_panel, encode_segment, encode_segment_packed, encode_segment_with, encoded_len,
    encoded_packed_len, fnv1a64, read_segment, read_segment_into, write_segment,
    write_segment_encoded, SegEncoding, SegioError, FORMAT_VERSION, HEADER_BYTES, KIND_CSR,
    KIND_CSR_PACKED, KIND_PANEL,
};
use aires::sparse::spmm::Dense;
use aires::sparse::Csr;
use aires::testing::{check, gen, TempDir};
use aires::util::rng::Pcg;

/// One operand from any family the kernels are tested on.
fn operand(rng: &mut Pcg) -> Csr {
    match rng.range(0, 6) {
        0 => gen::csr(rng, 48, 0.3),
        1 => gen::pathological(rng, 32),
        2 => aires::graphgen::rmat::generate(rng, 6, 8, Default::default()),
        3 => {
            let n = rng.range(2, 150);
            aires::graphgen::road::generate(rng, n)
        }
        4 => {
            let n = rng.range(2, 200);
            aires::graphgen::kmer::generate(rng, n, 3.0)
        }
        _ => gen::adjacency(rng, 40, 0.25),
    }
}

#[test]
fn roundtrip_is_identity_and_byte_stable_across_families() {
    check("segio decode(encode(m)) == m", 301, |rng| {
        let m = operand(rng);
        let buf = encode_segment(&m);
        let back = decode_segment(&buf).map_err(|e| format!("decode failed: {e}"))?;
        if back != m {
            return Err(format!("roundtrip diverged on {}x{} (nnz {})", m.nrows, m.ncols, m.nnz()));
        }
        // Byte stability: re-encoding the decoded matrix reproduces the
        // exact file bytes (no nondeterminism, no canonicalization drift).
        if encode_segment(&back) != buf {
            return Err("re-encoding is not byte-identical".into());
        }
        Ok(())
    });
}

#[test]
fn roundtrip_covers_robw_planned_segments() {
    // The real producers don't encode whole matrices — they encode RoBW
    // slices. Every planned slice must survive the disk format.
    check("segio roundtrip over RoBW slices", 302, |rng| {
        let m = operand(rng);
        let budget = rng.range(64, 2048) as u64;
        for seg in robw_partition(&m, budget) {
            let sub = materialize(&m, &seg);
            let back = decode_segment(&encode_segment(&sub))
                .map_err(|e| format!("segment [{}, {}): {e}", seg.row_lo, seg.row_hi))?;
            if back != sub {
                return Err(format!("segment [{}, {}) diverged", seg.row_lo, seg.row_hi));
            }
        }
        Ok(())
    });
}

#[test]
fn wrong_version_is_rejected_with_typed_error() {
    check("segio rejects wrong version", 303, |rng| {
        let m = operand(rng);
        let mut buf = encode_segment(&m);
        let found = (FORMAT_VERSION + 1 + (rng.below(250) as u32)).max(2);
        buf[8..12].copy_from_slice(&found.to_le_bytes());
        // Re-seal the header checksum so the *version* check is what fires
        // (a stale checksum would mask it).
        let sum = fnv1a64(&buf[0..56]);
        buf[56..64].copy_from_slice(&sum.to_le_bytes());
        match decode_segment(&buf) {
            Err(SegioError::WrongVersion { found: f, expected }) => {
                if f != found || expected != FORMAT_VERSION {
                    return Err(format!("wrong fields: found {f}, expected {expected}"));
                }
                Ok(())
            }
            other => Err(format!("expected WrongVersion, got {other:?}")),
        }
    });
}

#[test]
fn corrupted_bytes_are_rejected_with_typed_errors() {
    check("segio rejects corruption", 304, |rng| {
        let m = operand(rng);
        let buf = encode_segment(&m);
        // Flip one random byte; skip positions where a flip legitimately
        // changes nothing (there are none — every byte is covered by a
        // checksum, the magic, or the version field).
        let pos = rng.below(buf.len() as u64) as usize;
        let mut bad = buf.clone();
        bad[pos] ^= 0x01;
        match decode_segment(&bad) {
            Ok(got) => Err(format!(
                "flip at byte {pos} of {} decoded successfully (got {}x{}, nnz {})",
                buf.len(),
                got.nrows,
                got.ncols,
                got.nnz()
            )),
            Err(
                SegioError::BadMagic
                | SegioError::WrongVersion { .. }
                | SegioError::HeaderChecksum { .. }
                | SegioError::PayloadChecksum { .. },
            ) => Ok(()),
            Err(other) => Err(format!("flip at byte {pos}: unexpected error kind {other:?}")),
        }
    });
}

#[test]
fn every_truncation_is_rejected() {
    check("segio rejects truncation", 305, |rng| {
        let m = operand(rng);
        let buf = encode_segment(&m);
        // A strict prefix can never decode: the header advertises the
        // exact payload length.
        for cut in [
            0,
            1,
            HEADER_BYTES - 1,
            HEADER_BYTES,
            HEADER_BYTES + (buf.len() - HEADER_BYTES) / 2,
            buf.len() - 1,
        ] {
            if cut >= buf.len() {
                continue;
            }
            match decode_segment(&buf[..cut]) {
                Ok(_) => return Err(format!("prefix of {cut}/{} bytes decoded", buf.len())),
                Err(SegioError::Truncated { need, got }) => {
                    if got != cut as u64 || need <= got {
                        return Err(format!("bad Truncated fields: need {need}, got {got}"));
                    }
                }
                Err(other) => return Err(format!("cut {cut}: expected Truncated, got {other:?}")),
            }
        }
        let _ = rng.below(2); // keep the stream advancing per case
        Ok(())
    });
}

#[test]
fn decode_into_recycled_scratch_equals_fresh_decode() {
    // The recycled staging path decodes every segment into the same
    // caller-owned scratch. Reusing one scratch across the full operand
    // family mix must never leak a previous decode into the next one.
    let mut scratch = Csr::empty(0, 0);
    check("segio decode_segment_into == decode_segment", 307, |rng| {
        let m = operand(rng);
        let buf = encode_segment(&m);
        let want = decode_segment(&buf).map_err(|e| format!("fresh decode failed: {e}"))?;
        decode_segment_into(&buf, &mut scratch)
            .map_err(|e| format!("recycled decode failed: {e}"))?;
        if scratch != want {
            return Err(format!(
                "recycled decode diverged on {}x{} (nnz {})",
                m.nrows,
                m.ncols,
                m.nnz()
            ));
        }
        Ok(())
    });
}

#[test]
fn decode_into_resets_scratch_on_every_defect() {
    // After a failed decode the scratch must be an empty 0x0 matrix, not
    // a half-written hybrid of the old and new segment.
    let mut rng = Pcg::seed(308);
    let good = encode_segment(&operand(&mut rng));
    let mut scratch = decode_segment(&good).unwrap(); // non-empty contents
    let mut bad = good.clone();
    *bad.last_mut().unwrap() ^= 0x01; // payload corruption
    assert!(decode_segment_into(&bad, &mut scratch).is_err());
    assert_eq!(scratch, Csr::empty(0, 0));
    let mut scratch = decode_segment(&good).unwrap();
    assert!(decode_segment_into(&good[..HEADER_BYTES - 1], &mut scratch).is_err());
    assert_eq!(scratch, Csr::empty(0, 0));
}

#[test]
fn read_into_reuses_buffers_across_files() {
    let dir = TempDir::new("segio-read-into");
    let mut rng = Pcg::seed(309);
    let mut bytes_scratch = Vec::new();
    let mut csr_scratch = Csr::empty(0, 0);
    for i in 0..8 {
        let m = operand(&mut rng);
        let path = dir.path().join(format!("case-{i}.bin"));
        let written = write_segment(&path, &m).unwrap();
        let read = read_segment_into(&path, &mut bytes_scratch, &mut csr_scratch).unwrap();
        assert_eq!(read, written, "case {i}");
        assert_eq!(csr_scratch, m, "case {i}");
        // The fresh-allocation reader agrees byte for byte.
        let (fresh, fresh_read) = read_segment(&path).unwrap();
        assert_eq!(fresh, csr_scratch);
        assert_eq!(fresh_read, read);
    }
    // Truncation through the recycled reader carries the typed error.
    let m = operand(&mut rng);
    let path = dir.path().join("trunc.bin");
    write_segment(&path, &m).unwrap();
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() / 2]).unwrap();
    assert!(matches!(
        read_segment_into(&path, &mut bytes_scratch, &mut csr_scratch),
        Err(SegioError::Truncated { .. })
    ));
    assert!(matches!(
        read_segment_into(&dir.path().join("nope.bin"), &mut bytes_scratch, &mut csr_scratch),
        Err(SegioError::Io(_))
    ));
}

/// A random dense panel with bit-pattern variety (negative zeros,
/// subnormals) the feature-panel spill path must preserve exactly.
fn panel_operand(rng: &mut Pcg) -> Dense {
    let nrows = rng.range(0, 40);
    let ncols = rng.range(0, 12);
    let data = (0..nrows * ncols)
        .map(|_| match rng.range(0, 12) {
            0 => -0.0,
            1 => f32::from_bits(rng.range(1, 1 << 20) as u32), // subnormal
            _ => rng.normal() as f32,
        })
        .collect();
    Dense::from_vec(nrows, ncols, data)
}

#[test]
fn panel_roundtrip_is_bit_identical_across_shapes() {
    // The cross-layer pipeline's panel spill rides this property: a
    // spilled-and-reloaded intermediate panel must not disturb one bit,
    // or the multi-layer differential sweep loses byte-identity.
    let mut scratch = Dense::zeros(0, 0);
    check("segio decode_panel(encode_panel(p)) == p", 310, |rng| {
        let p = panel_operand(rng);
        let buf = encode_panel(&p);
        let back = decode_panel(&buf).map_err(|e| format!("decode failed: {e}"))?;
        if back.nrows != p.nrows || back.ncols != p.ncols || back.data.len() != p.data.len() {
            return Err(format!("shape diverged on {}x{}", p.nrows, p.ncols));
        }
        for (i, (a, b)) in p.data.iter().zip(back.data.iter()).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!("bit {i} diverged: {:#x} != {:#x}", a.to_bits(), b.to_bits()));
            }
        }
        if encode_panel(&back) != buf {
            return Err("re-encoding is not byte-identical".into());
        }
        // Recycled-scratch decode agrees with the fresh one.
        decode_panel_into(&buf, &mut scratch)
            .map_err(|e| format!("recycled decode failed: {e}"))?;
        if scratch != back {
            return Err("recycled panel decode diverged".into());
        }
        Ok(())
    });
}

#[test]
fn panel_corrupted_bytes_are_rejected_with_typed_errors() {
    // The chaos harness's CorruptOnRead fault flips panel bytes too; the
    // quarantine path relies on every flip surfacing as a typed error.
    check("segio rejects panel corruption", 312, |rng| {
        let p = panel_operand(rng);
        let buf = encode_panel(&p);
        let pos = rng.below(buf.len() as u64) as usize;
        let mut bad = buf.clone();
        bad[pos] ^= 0x01;
        match decode_panel(&bad) {
            Ok(got) => Err(format!(
                "flip at byte {pos} of {} decoded successfully ({}x{})",
                buf.len(),
                got.nrows,
                got.ncols
            )),
            Err(
                SegioError::BadMagic
                | SegioError::WrongVersion { .. }
                | SegioError::WrongKind { .. }
                | SegioError::HeaderChecksum { .. }
                | SegioError::PayloadChecksum { .. },
            ) => Ok(()),
            Err(other) => Err(format!("flip at byte {pos}: unexpected error kind {other:?}")),
        }
    });
}

#[test]
fn every_panel_truncation_is_rejected() {
    check("segio rejects panel truncation", 313, |rng| {
        let p = panel_operand(rng);
        let buf = encode_panel(&p);
        for cut in [
            0,
            1,
            HEADER_BYTES - 1,
            HEADER_BYTES,
            HEADER_BYTES + (buf.len() - HEADER_BYTES) / 2,
            buf.len() - 1,
        ] {
            if cut >= buf.len() {
                continue;
            }
            match decode_panel(&buf[..cut]) {
                Ok(_) => return Err(format!("prefix of {cut}/{} bytes decoded", buf.len())),
                Err(SegioError::Truncated { need, got }) => {
                    if got != cut as u64 || need <= got {
                        return Err(format!("bad Truncated fields: need {need}, got {got}"));
                    }
                }
                Err(other) => return Err(format!("cut {cut}: expected Truncated, got {other:?}")),
            }
        }
        let _ = rng.below(2); // keep the stream advancing per case
        Ok(())
    });
}

#[test]
fn panel_and_segment_records_never_cross_decode() {
    let mut rng = Pcg::seed(311);
    let seg = encode_segment(&operand(&mut rng));
    let panel = encode_panel(&panel_operand(&mut rng));
    assert_eq!(
        decode_panel(&seg).unwrap_err(),
        SegioError::WrongKind { found: KIND_CSR, expected: KIND_PANEL }
    );
    assert_eq!(
        decode_segment(&panel).unwrap_err(),
        SegioError::WrongKind { found: KIND_PANEL, expected: KIND_CSR }
    );
}

#[test]
fn file_roundtrip_through_a_real_directory() {
    let dir = TempDir::new("segio-roundtrip");
    let mut rng = Pcg::seed(306);
    for i in 0..8 {
        let m = operand(&mut rng);
        let path = dir.path().join(format!("case-{i}.bin"));
        let written = write_segment(&path, &m).unwrap();
        assert_eq!(written, std::fs::metadata(&path).unwrap().len());
        let (back, read) = read_segment(&path).unwrap();
        assert_eq!(back, m, "case {i}");
        assert_eq!(read, written);
    }
    // A missing file is an Io error, not a panic.
    assert!(matches!(
        read_segment(&dir.path().join("nope.bin")),
        Err(SegioError::Io(_))
    ));
}

// ---------------------------------------------------------------------------
// Storage engine v2: KIND_CSR_PACKED records. Same contract as the raw
// suite above — identity roundtrips, byte stability, typed rejection of
// every defect — plus the packed-specific obligations: the size
// predictor is exact, Auto strictly picks the smaller file, and the
// family decoder accepts packed while the panel and zero-copy decoders
// reject it by kind.
// ---------------------------------------------------------------------------

#[test]
fn packed_golden_vector_pins_the_wire_format() {
    // Independently computed (Python struct/FNV-1a port of the spec) for
    // a 2x5 segment: zigzag codes [2, 6, 4] at width 3 pack into the
    // single word 2 | 6<<3 | 4<<6 = 306. Pins the file-level layout the
    // same way the unit golden vector pins the in-memory encoder.
    let want: [u8; 116] = [
        65, 73, 82, 69, 83, 83, 69, 71, 1, 0, 0, 0, 3, 0, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0, 5, 0,
        0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 52, 0, 0, 0, 0, 0, 0, 0, 109, 190, 60, 6,
        228, 250, 15, 14, 148, 153, 227, 107, 240, 117, 150, 247, 0, 0, 0, 0, 0, 0, 0, 0, 2, 0,
        0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 50, 1, 0, 0, 0, 0, 0,
        0, 0, 0, 192, 63, 0, 0, 0, 192, 0, 0, 128, 62,
    ];
    let m = Csr {
        nrows: 2,
        ncols: 5,
        rowptr: vec![0, 2, 3],
        colidx: vec![1, 4, 2],
        vals: vec![1.5, -2.0, 0.25],
    };
    m.validate().expect("golden matrix must be a valid CSR");
    assert_eq!(encode_segment_packed(&m), want.to_vec());
    assert_eq!(encoded_packed_len(&m), want.len() as u64);

    // The encoded file writer produces the same bytes and reports the
    // kind it chose; the generic file reader accepts them back.
    let dir = TempDir::new("segio-packed-golden");
    let path = dir.path().join("golden.bin");
    let (written, kind) = write_segment_encoded(&path, &m, SegEncoding::Packed).unwrap();
    assert_eq!((written, kind), (want.len() as u64, KIND_CSR_PACKED));
    assert_eq!(std::fs::read(&path).unwrap(), want.to_vec());
    let (back, read) = read_segment(&path).unwrap();
    assert_eq!(back, m);
    assert_eq!(read, written);
}

#[test]
fn packed_roundtrip_is_identity_across_families() {
    let mut scratch = Csr::empty(0, 0);
    check("segio packed decode(encode(m)) == m", 314, |rng| {
        let m = operand(rng);
        let buf = encode_segment_packed(&m);
        if buf.len() as u64 != encoded_packed_len(&m) {
            return Err(format!(
                "size predictor off: {} bytes encoded, {} predicted",
                buf.len(),
                encoded_packed_len(&m)
            ));
        }
        let back = decode_segment(&buf).map_err(|e| format!("decode failed: {e}"))?;
        if back != m {
            return Err(format!("roundtrip diverged on {}x{} (nnz {})", m.nrows, m.ncols, m.nnz()));
        }
        if encode_segment_packed(&back) != buf {
            return Err("re-encoding is not byte-identical".into());
        }
        // The recycled-scratch decoder handles the packed kind too.
        decode_segment_into(&buf, &mut scratch)
            .map_err(|e| format!("recycled decode failed: {e}"))?;
        if scratch != m {
            return Err("recycled packed decode diverged".into());
        }
        // Auto strictly picks the smaller encoding (raw on ties), and the
        // bytes it emits are exactly the bytes of the encoder it picked.
        let (abuf, akind) = encode_segment_with(&m, SegEncoding::Auto);
        let (plen, rlen) = (encoded_packed_len(&m), encoded_len(m.nrows, m.nnz()));
        let want_kind = if plen < rlen { KIND_CSR_PACKED } else { KIND_CSR };
        if akind != want_kind {
            return Err(format!("auto chose kind {akind} (packed {plen} vs raw {rlen} bytes)"));
        }
        if abuf.len() as u64 != plen.min(rlen) {
            return Err(format!("auto emitted {} bytes, min is {}", abuf.len(), plen.min(rlen)));
        }
        Ok(())
    });
}

#[test]
fn packed_roundtrip_covers_robw_planned_segments() {
    // The packed store encodes the same RoBW slices the raw store does;
    // every planned slice must survive the compressed layout too.
    check("segio packed roundtrip over RoBW slices", 315, |rng| {
        let m = operand(rng);
        let budget = rng.range(64, 2048) as u64;
        for seg in robw_partition(&m, budget) {
            let sub = materialize(&m, &seg);
            let buf = encode_segment_packed(&sub);
            if buf.len() as u64 != encoded_packed_len(&sub) {
                return Err(format!(
                    "segment [{}, {}): size predictor off ({} vs {})",
                    seg.row_lo,
                    seg.row_hi,
                    buf.len(),
                    encoded_packed_len(&sub)
                ));
            }
            let back = decode_segment(&buf)
                .map_err(|e| format!("segment [{}, {}): {e}", seg.row_lo, seg.row_hi))?;
            if back != sub {
                return Err(format!("segment [{}, {}) diverged", seg.row_lo, seg.row_hi));
            }
        }
        Ok(())
    });
}

#[test]
fn packed_corrupted_bytes_are_rejected_with_typed_errors() {
    check("segio rejects packed corruption", 316, |rng| {
        let m = operand(rng);
        let buf = encode_segment_packed(&m);
        let pos = rng.below(buf.len() as u64) as usize;
        let mut bad = buf.clone();
        bad[pos] ^= 0x01;
        match decode_segment(&bad) {
            Ok(got) => Err(format!(
                "flip at byte {pos} of {} decoded successfully (got {}x{}, nnz {})",
                buf.len(),
                got.nrows,
                got.ncols,
                got.nnz()
            )),
            // WrongKind joins the accept set: flipping the kind word's low
            // byte turns KIND_CSR_PACKED into KIND_CHECK, which the family
            // check rejects before the header checksum runs.
            Err(
                SegioError::BadMagic
                | SegioError::WrongVersion { .. }
                | SegioError::WrongKind { .. }
                | SegioError::HeaderChecksum { .. }
                | SegioError::PayloadChecksum { .. },
            ) => Ok(()),
            Err(other) => Err(format!("flip at byte {pos}: unexpected error kind {other:?}")),
        }
    });
}

#[test]
fn every_packed_truncation_is_rejected() {
    check("segio rejects packed truncation", 317, |rng| {
        let m = operand(rng);
        let buf = encode_segment_packed(&m);
        for cut in [
            0,
            1,
            HEADER_BYTES - 1,
            HEADER_BYTES,
            HEADER_BYTES + (buf.len() - HEADER_BYTES) / 2,
            buf.len() - 1,
        ] {
            if cut >= buf.len() {
                continue;
            }
            match decode_segment(&buf[..cut]) {
                Ok(_) => return Err(format!("prefix of {cut}/{} bytes decoded", buf.len())),
                Err(SegioError::Truncated { need, got }) => {
                    if got != cut as u64 || need <= got {
                        return Err(format!("bad Truncated fields: need {need}, got {got}"));
                    }
                }
                Err(other) => return Err(format!("cut {cut}: expected Truncated, got {other:?}")),
            }
        }
        let _ = rng.below(2); // keep the stream advancing per case
        Ok(())
    });
}

#[test]
fn packed_records_decode_as_segments_but_never_as_panels_or_refs() {
    let mut rng = Pcg::seed(318);
    let m = operand(&mut rng);
    let packed = encode_segment_packed(&m);
    // The copy decoders accept the whole CSR *family* — a packed record
    // is a segment, just with a compressed colidx section.
    assert_eq!(decode_segment(&packed).unwrap(), m);
    // The panel decoder rejects it by kind, naming what it found.
    assert_eq!(
        decode_panel(&packed).unwrap_err(),
        SegioError::WrongKind { found: KIND_CSR_PACKED, expected: KIND_PANEL }
    );
    // The zero-copy decoder serves the raw layout only: borrowed colidx
    // words don't exist in a packed record, so the mmap path must fall
    // back to a copy decode rather than misread the bit stream.
    assert_eq!(
        decode_segment_ref(&packed).unwrap_err(),
        SegioError::WrongKind { found: KIND_CSR_PACKED, expected: KIND_CSR }
    );
}
