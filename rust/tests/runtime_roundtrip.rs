//! End-to-end artifact round-trip: rust loads the HLO text emitted by
//! `python/compile/aot.py`, compiles it on the PJRT CPU client, executes,
//! and checks numerics against the in-crate CPU oracles. This is the
//! proof that L1 (Pallas) / L2 (JAX) / L3 (rust) compose.

use aires::runtime::tile_exec::{BsrSpmmExec, CombineExec};
use aires::runtime::{find_artifact_dir, Executor};
use aires::sparse::spmm::{spmm, Dense};
use aires::sparse::Coo;
use aires::util::rng::Pcg;

fn executor() -> Option<Executor> {
    let dir = find_artifact_dir()?;
    Some(Executor::new(&dir).expect("executor"))
}

fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> aires::Csr {
    let mut coo = Coo::new(nrows, ncols);
    for r in 0..nrows {
        for c in 0..ncols {
            if rng.chance(density) {
                coo.push(r as u32, c as u32, rng.normal() as f32);
            }
        }
    }
    coo.to_csr()
}

fn random_dense(rng: &mut Pcg, nrows: usize, ncols: usize) -> Dense {
    Dense::from_vec(nrows, ncols, (0..nrows * ncols).map(|_| rng.normal() as f32).collect())
}

#[test]
fn bsr_spmm_artifact_matches_cpu_oracle() {
    let Some(mut exec) = executor() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let spmm_exec = BsrSpmmExec::for_feature_width(&exec, 64).expect("variant");
    let mut rng = Pcg::seed(1234);
    for &(m, k, d) in &[(100usize, 512usize, 0.02f64), (37, 1000, 0.05), (256, 1024, 0.01)] {
        let a = random_csr(&mut rng, m, k, d);
        let h = random_dense(&mut rng, k, 64);
        let got = spmm_exec.spmm(&mut exec, &a, &h).expect("artifact spmm");
        let want = spmm(&a, &h);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "m={m} k={k} d={d}: max diff {diff}");
    }
}

#[test]
fn combine_artifact_matches_cpu_oracle() {
    let Some(mut exec) = executor() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    let comb = CombineExec::for_widths(&exec, 64, 64, true).expect("variant");
    let mut rng = Pcg::seed(99);
    let x = random_dense(&mut rng, 300, 64); // non-multiple of p=256
    let w = random_dense(&mut rng, 64, 64);
    let b: Vec<f32> = (0..64).map(|_| rng.normal() as f32).collect();
    let got = comb.combine(&mut exec, &x, &w, &b).expect("combine");
    // CPU oracle.
    let mut want = Dense::zeros(300, 64);
    for i in 0..300 {
        for j in 0..64 {
            let mut acc = b[j];
            for l in 0..64 {
                acc += x.at(i, l) * w.at(l, j);
            }
            *want.at_mut(i, j) = acc.max(0.0);
        }
    }
    let diff = got.max_abs_diff(&want);
    assert!(diff < 1e-3, "max diff {diff}");
}

#[test]
fn train_step_artifact_reduces_loss() {
    let Some(mut exec) = executor() else {
        eprintln!("skipping: run `make artifacts` first");
        return;
    };
    use aires::runtime::executor::Buf;
    let name = exec
        .manifest()
        .find_prefix("gcn2_train_step_")
        .expect("train artifact")
        .name
        .clone();
    let spec = exec.spec(&name).unwrap().clone();
    let n = spec.meta["n"] as usize;
    let f0 = spec.meta["f0"] as usize;
    let hd = spec.meta["h"] as usize;
    let c = spec.meta["c"] as usize;

    // Small ring-like graph, normalized adjacency, learnable labels.
    let mut rng = Pcg::seed(7);
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        let j = (i + 1) % n;
        coo.push(i as u32, j as u32, 1.0);
        coo.push(j as u32, i as u32, 1.0);
    }
    let a_hat = aires::sparse::norm::normalize_adjacency(&coo.to_csr());
    let a_dense = a_hat.to_dense();
    let x: Vec<f32> = (0..n * f0).map(|_| rng.normal() as f32).collect();
    let mut w1: Vec<f32> = (0..f0 * hd).map(|_| (rng.normal() * 0.3) as f32).collect();
    let mut b1 = vec![0f32; hd];
    let mut w2: Vec<f32> = (0..hd * c).map(|_| (rng.normal() * 0.3) as f32).collect();
    let mut b2 = vec![0f32; c];
    // Labels from a random projection of x (learnable signal).
    let proj: Vec<f32> = (0..f0).map(|_| rng.normal() as f32).collect();
    let labels: Vec<i32> = (0..n)
        .map(|i| {
            let s: f32 = (0..f0).map(|j| x[i * f0 + j] * proj[j]).sum();
            if s > 0.0 { 1 } else { 0 }
        })
        .collect();

    let mut losses = Vec::new();
    for _ in 0..12 {
        let outs = exec
            .run(
                &name,
                &[
                    Buf::F32(a_dense.clone()),
                    Buf::F32(x.clone()),
                    Buf::F32(w1.clone()),
                    Buf::F32(b1.clone()),
                    Buf::F32(w2.clone()),
                    Buf::F32(b2.clone()),
                    Buf::S32(labels.clone()),
                    Buf::F32(vec![1.0f32]),
                ],
            )
            .expect("train step");
        let loss = outs[0].as_f32().unwrap()[0];
        losses.push(loss);
        w1 = outs[1].as_f32().unwrap().to_vec();
        b1 = outs[2].as_f32().unwrap().to_vec();
        w2 = outs[3].as_f32().unwrap().to_vec();
        b2 = outs[4].as_f32().unwrap().to_vec();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss must decrease: {losses:?}"
    );
}
