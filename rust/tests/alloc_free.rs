//! Allocation-freedom of the recycled staging pipeline, proven with a
//! counting `#[global_allocator]`.
//!
//! AIRES identifies sparse-format memory allocation as the dominant
//! out-of-core SpGEMM overhead; the recycling subsystem
//! (`runtime::recycle` + the `Prefetch::run_recycling` return channel +
//! `segio::*_into` decoding + `spmm_par_into` panel writes) exists to
//! remove it from the steady state. This suite pins that property:
//!
//! 1. **Strict per-segment**: on the cache-disabled disk-backed path at
//!    depth 1, segment 1 may allocate (pool warm-up at the plan's
//!    high-water capacities) but segments 2..n perform **zero** heap
//!    allocations — counted around each stage+consume step.
//! 2. **End-to-end scale-invariance**: a warmed `forward_cpu` pass over
//!    the recycled disk path costs a small constant number of allocations
//!    regardless of segment count, while the fresh path scales with it.
//!
//! Everything lives in ONE `#[test]` because the allocation counter is
//! process-global: concurrent tests would bleed counts into each other.

use aires::benchlib::allocation_count;
use aires::gcn::{serve_batch, OocGcnLayer, OocGcnModel, PipelineConfig, StagingConfig, TenantQuery};
use aires::memsim::GpuMem;
use aires::partition::robw::robw_partition;
use aires::runtime::pool::Pool;
use aires::runtime::prefetch::Prefetch;
use aires::runtime::recycle::BufferPool;
use aires::runtime::segstore::{SegmentRead, SegmentStore};
use aires::sparse::spmm::{spmm_par_into, Dense};
use aires::testing::TempDir;
use aires::util::rng::Pcg;
use std::sync::Arc;

#[global_allocator]
static COUNTING: aires::benchlib::CountingAlloc = aires::benchlib::CountingAlloc;

#[test]
fn recycled_disk_path_is_allocation_free_in_steady_state() {
    let mut rng = Pcg::seed(400);
    let a = aires::graphgen::kmer::generate(&mut rng, 600, 3.0);
    let a_hat = aires::sparse::norm::normalize_adjacency(&a);
    let x = Dense::from_vec(600, 16, (0..600 * 16).map(|_| rng.normal() as f32).collect());
    let layer = OocGcnLayer {
        w: Dense::from_vec(16, 8, (0..16 * 8).map(|_| (rng.normal() * 0.2) as f32).collect()),
        b: vec![0.1; 8],
        relu: true,
        seg_budget: 2 << 10,
    };
    let segs = robw_partition(&a_hat, layer.seg_budget);
    let n = segs.len();
    assert!(n >= 8, "need a real stream to measure steady state (got {n} segments)");

    // Host cache disabled: every read is a real file read, and every
    // served segment is Owned — the full recycling cycle.
    let dir = TempDir::new("alloc-free");
    let store = Arc::new(SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap());
    let bpool = BufferPool::new(64 << 20);
    let serial = Pool::serial();
    let f = x.ncols;

    // ---- 1. Strict per-segment counting at depth 1 ---------------------
    // Depth 1 runs stage(i) then consume(i) serially on this thread, so a
    // counter snapshot taken inside each consume cleanly brackets one
    // segment's stage + compute. Pre-allocate everything the measurement
    // itself needs (snapshot vec, aggregation panel) before streaming.
    let mut agg = Dense::zeros(a_hat.nrows, f);
    let mut snaps: Vec<u64> = Vec::with_capacity(n + 1);
    snaps.push(allocation_count());
    let leftovers = Prefetch::new(1)
        .run_recycling::<SegmentRead, aires::sparse::Csr, String, _, _>(
            &serial,
            n,
            |i, reuse| {
                store
                    .read_reusing(i, reuse, Some(&bpool))
                    .map(|(m, _)| m)
                    .map_err(|e| e.to_string())
            },
            |i, item| {
                let seg = &segs[i];
                spmm_par_into(
                    &item,
                    &x,
                    &serial,
                    &mut agg.data[seg.row_lo * f..seg.row_hi * f],
                );
                snaps.push(allocation_count());
                Ok(item.reclaim())
            },
        )
        .unwrap();
    for m in leftovers {
        bpool.put_csr(m);
    }
    let deltas: Vec<u64> = snaps.windows(2).map(|w| w[1] - w[0]).collect();
    assert_eq!(deltas.len(), n);
    // Segment 0 warms the pool (scratch sized to the plan's maxima).
    assert!(deltas[0] > 0, "first segment allocates its scratch once");
    for (i, &d) in deltas.iter().enumerate().skip(1) {
        assert_eq!(
            d, 0,
            "segment {i}/{n} allocated {d} times in steady state (deltas: {deltas:?})"
        );
    }
    // The pool saw exactly the warm-up misses plus per-segment reuse.
    let st = bpool.stats();
    assert!(st.hits >= n - 1, "byte scratch must be reused every segment: {st:?}");

    // The streamed panel equals the serial product — the measurement did
    // not trade correctness for allocation counts.
    let want = aires::sparse::spmm::spmm(&a_hat, &x);
    assert_eq!(agg, want, "recycled streamed aggregation diverged");

    // ---- 2. End-to-end scale-invariance of forward_cpu -----------------
    // A warmed recycled pass allocates O(1); the fresh path O(segments).
    // Use depth 1 so the pipeline spawns no producer thread (thread spawns
    // allocate and would blur the constant).
    let count_pass = |staging: &StagingConfig| {
        let mut mem = GpuMem::new(1 << 30);
        let before = allocation_count();
        let (out, _) = layer.forward_cpu(&a_hat, &x, &mut mem, &serial, staging).unwrap();
        let allocs = allocation_count() - before;
        (out, allocs)
    };
    let shared = Arc::new(BufferPool::new(64 << 20));
    let recycled_cfg = StagingConfig::disk(store.clone(), 1).with_recycle(shared.clone());
    let fresh_cfg = StagingConfig::disk(store.clone(), 1);
    let (out_warmup, _) = count_pass(&recycled_cfg); // warm the pool
    let (out_recycled, allocs_recycled) = count_pass(&recycled_cfg);
    let (out_fresh, allocs_fresh) = count_pass(&fresh_cfg);
    assert_eq!(out_recycled, out_fresh, "recycled and fresh passes must agree");
    assert_eq!(out_recycled, out_warmup);
    // Fresh pays at least rowptr+colidx+vals+file-scratch per segment.
    assert!(
        allocs_fresh >= 3 * n as u64,
        "fresh pass should allocate per segment: {allocs_fresh} over {n} segments"
    );
    // A warmed recycled pass costs a small constant (plan vec, panel,
    // report plumbing) — far below one allocation per segment and
    // independent of the segment count.
    assert!(
        allocs_recycled < allocs_fresh / 2,
        "recycled pass ({allocs_recycled}) must allocate far less than fresh ({allocs_fresh})"
    );
    assert!(
        allocs_recycled < 48 + n as u64 / 8,
        "recycled warmed pass must not scale with segments: {allocs_recycled} over {n}"
    );

    // Scale-invariance: double the stream length, same warmed cost.
    let fine_budget = 1536u64;
    let fine_segs = robw_partition(&a_hat, fine_budget);
    let n2 = fine_segs.len();
    assert!(n2 > n, "finer budget must yield more segments");
    let dir2 = TempDir::new("alloc-free-fine");
    let store2 = Arc::new(SegmentStore::spill(&a_hat, &fine_segs, dir2.path(), 0).unwrap());
    let layer2 = OocGcnLayer {
        w: layer.w.clone(),
        b: layer.b.clone(),
        relu: layer.relu,
        seg_budget: fine_budget,
    };
    let cfg2 = StagingConfig::disk(store2.clone(), 1).with_recycle(shared.clone());
    let count2 = |staging: &StagingConfig| {
        let mut mem = GpuMem::new(1 << 30);
        let before = allocation_count();
        let _ = layer2.forward_cpu(&a_hat, &x, &mut mem, &serial, staging).unwrap();
        allocation_count() - before
    };
    let _ = count2(&cfg2); // warm at the finer plan's capacities
    let allocs_fine = count2(&cfg2);
    assert!(
        allocs_fine < 48 + n2 as u64 / 8,
        "warmed cost must stay constant as segments grow: {allocs_fine} over {n2} segments"
    );

    // ---- 3. Cross-layer pipeline stays allocation-free per segment -----
    // A 3-layer model over the SAME store streams 3n segments through one
    // pipeline. A warmed recycled pass must cost a small constant per
    // *layer* (combine output, plan vec, report plumbing) — never per
    // segment — while the fresh path still scales with the segment count.
    // The one recycle pool also proves the panel slab circulates across
    // layers: every layer's aggregation panel is the same slab.
    let wsq = Dense::from_vec(
        16,
        16,
        (0..16 * 16).map(|_| (rng.normal() * 0.2) as f32).collect(),
    );
    let model = OocGcnModel::new(
        (0..3)
            .map(|_| OocGcnLayer {
                w: wsq.clone(),
                b: vec![0.1; 16],
                relu: true,
                seg_budget: layer.seg_budget,
            })
            .collect(),
    )
    .unwrap();
    let n3 = 3 * n as u64;
    let mpool = Arc::new(BufferPool::new(64 << 20));
    let count_model = |cfg: &PipelineConfig| {
        let mut mem = GpuMem::new(1 << 30);
        let before = allocation_count();
        let (out, _) = model.forward_cpu(&a_hat, &x, &mut mem, &serial, cfg).unwrap();
        (out, allocation_count() - before)
    };
    let recycled_model =
        PipelineConfig::staged(StagingConfig::disk(store.clone(), 1).with_recycle(mpool.clone()));
    let fresh_model = PipelineConfig::staged(StagingConfig::disk(store.clone(), 1));
    let (out_warm, _) = count_model(&recycled_model); // warm the pool
    let (out_rec, allocs_rec) = count_model(&recycled_model);
    let (out_fresh, allocs_fresh3) = count_model(&fresh_model);
    assert_eq!(out_rec, out_fresh, "recycled and fresh multi-layer passes must agree");
    assert_eq!(out_rec, out_warm);
    assert!(
        allocs_fresh3 >= 3 * n3,
        "fresh cross-layer pass should allocate per segment: {allocs_fresh3} over {n3}"
    );
    assert!(
        allocs_rec < allocs_fresh3 / 2,
        "recycled cross-layer pass ({allocs_rec}) must allocate far less than fresh \
         ({allocs_fresh3})"
    );
    assert!(
        allocs_rec < 128 + n3 / 8,
        "recycled warmed cross-layer pass must not scale with segments: \
         {allocs_rec} over {n3}"
    );
    assert!(mpool.stats().hits > 0, "segment scratch must cycle across layers");

    // ---- 4. Multi-tenant serve stays allocation-free per segment -------
    // A warmed recycled serve_batch over the same store fans each staged
    // segment out to every tenant. Its per-pass cost is constant (plan
    // vec, admission bookkeeping, one combine output per tenant) — the
    // per-segment staging cycle allocates nothing, exactly like the solo
    // pass — while the fresh path still scales with the segment count.
    let queries: Vec<TenantQuery> = (0..2)
        .map(|_| TenantQuery { x: x.clone(), layer: layer.clone() })
        .collect();
    let spool = Arc::new(BufferPool::new(64 << 20));
    let count_serve = |staging: &StagingConfig| {
        let mut mem = GpuMem::new(1 << 30);
        let before = allocation_count();
        let (results, _) = serve_batch(&a_hat, &queries, &mut mem, &serial, staging);
        let allocs = allocation_count() - before;
        let outs: Vec<Dense> =
            results.into_iter().map(|r| r.expect("serve tenants complete")).collect();
        (outs, allocs)
    };
    let recycled_serve = StagingConfig::disk(store.clone(), 1).with_recycle(spool.clone());
    let fresh_serve = StagingConfig::disk(store.clone(), 1);
    let (outs_warm, _) = count_serve(&recycled_serve); // warm the pool
    let (outs_rec, allocs_serve_rec) = count_serve(&recycled_serve);
    let (outs_fresh, allocs_serve_fresh) = count_serve(&fresh_serve);
    assert_eq!(outs_rec, outs_fresh, "recycled and fresh serve passes must agree");
    assert_eq!(outs_rec, outs_warm);
    assert_eq!(outs_rec[0], out_recycled, "served tenant diverged from its solo pass");
    assert_eq!(outs_rec[0], outs_rec[1], "identical tenants must get identical answers");
    assert!(
        allocs_serve_fresh >= 3 * n as u64,
        "fresh serve pass should allocate per segment: {allocs_serve_fresh} over {n}"
    );
    assert!(
        allocs_serve_rec < allocs_serve_fresh / 2,
        "recycled serve pass ({allocs_serve_rec}) must allocate far less than fresh \
         ({allocs_serve_fresh})"
    );
    assert!(
        allocs_serve_rec < 96 + n as u64 / 8,
        "recycled warmed serve pass must not scale with segments: \
         {allocs_serve_rec} over {n}"
    );

    // ---- 5. Streamed training step stays allocation-free per segment ---
    // The backward sweep reverses the concatenated plan through the same
    // recycling channel, so a warmed streamed train step (forward AND
    // backward, gradient/activation panels through the tiered store) costs
    // a per-layer constant: recycling must save allocations on every
    // staged segment, and the warmed cost must not grow when the plan gets
    // finer.
    use aires::gcn::train_stream::synthetic_labels;
    use aires::gcn::{RecomputePolicy, StreamedTrainer, TrainStreamConfig};
    use aires::runtime::segstore::PanelStore;

    let labels = synthetic_labels(&x, 4, &mut rng);
    let widths = [16usize, 8, 8, 4];
    let train_layers = |budget: u64| -> Vec<OocGcnLayer> {
        (0..3)
            .map(|l| OocGcnLayer {
                w: Dense::from_vec(
                    widths[l],
                    widths[l + 1],
                    (0..widths[l] * widths[l + 1])
                        .map(|i| ((i % 7) as f32 - 3.0) * 0.1)
                        .collect(),
                ),
                b: vec![0.05; widths[l + 1]],
                relu: l < 2,
                seg_budget: budget,
            })
            .collect()
    };
    // Warm two steps (pool capacities and panel-store files reach steady
    // state), then count the third.
    let count_step = |store: Arc<SegmentStore>,
                      budget: u64,
                      policy: RecomputePolicy,
                      recycle: Option<Arc<BufferPool>>|
     -> (u64, u64) {
        let pdir = TempDir::new("alloc-free-train");
        let panels = Arc::new(PanelStore::new(pdir.path(), 0).unwrap());
        let mut staging = StagingConfig::disk(store, 1);
        if let Some(rp) = recycle {
            staging = staging.with_recycle(rp);
        }
        let cfg = TrainStreamConfig::new(staging, panels).with_policy(policy);
        let mut tr = StreamedTrainer::new(train_layers(budget), labels.clone()).unwrap();
        let mut mem = GpuMem::new(1 << 30);
        for _ in 0..2 {
            tr.step(&a_hat, &x, &mut mem, &serial, &cfg, 0.1).unwrap();
        }
        let before = allocation_count();
        let rep = tr.step(&a_hat, &x, &mut mem, &serial, &cfg, 0.1).unwrap();
        let allocs = allocation_count() - before;
        assert!(rep.loss.is_finite(), "warmed step must still train: {}", rep.loss);
        assert_eq!(mem.used, 0, "streamed step left the ledger unbalanced");
        (allocs, (rep.forward.merged().segments + rep.backward_segments) as u64)
    };
    let tpool = Arc::new(BufferPool::new(64 << 20));
    for policy in [RecomputePolicy::Reload, RecomputePolicy::Recompute] {
        let (allocs_train_rec, segs_train) =
            count_step(store.clone(), layer.seg_budget, policy, Some(tpool.clone()));
        let (allocs_train_fresh, segs_train_fresh) =
            count_step(store.clone(), layer.seg_budget, policy, None);
        assert_eq!(segs_train, segs_train_fresh);
        // The fresh step pays rowptr+colidx+vals per staged segment that
        // the recycled one does not.
        assert!(
            allocs_train_fresh >= allocs_train_rec + 2 * segs_train,
            "{policy:?}: recycling must save allocations on every staged segment \
             (fresh {allocs_train_fresh}, recycled {allocs_train_rec}, {segs_train} segments)"
        );
        // Scale-invariance: a finer plan streams more segments through the
        // same warmed step for (near-)identical allocation cost.
        let (allocs_train_fine, segs_train_fine) =
            count_step(store2.clone(), fine_budget, policy, Some(tpool.clone()));
        assert!(segs_train_fine > segs_train, "finer plan must stream more segments");
        assert!(
            allocs_train_fine <= allocs_train_rec + 96,
            "{policy:?}: warmed step cost must not scale with segments: \
             {allocs_train_fine} over {segs_train_fine} segments vs \
             {allocs_train_rec} over {segs_train}"
        );
        assert!(
            allocs_train_rec < 512 + segs_train / 4,
            "{policy:?}: warmed streamed step must stay a small constant: \
             {allocs_train_rec} over {segs_train} segments"
        );
    }

    // ---- 6. Warm mmap path is payload-copy-free ------------------------
    // Storage engine v2's zero-copy obligation: a steady-state mapped pass
    // over a raw store serves every colidx/vals section borrowed from the
    // mapping — `payload_copy_count()` must not move at all. The copy path
    // over the same store materializes every segment, proving the counter
    // is live and the mapped pass genuinely skipped the decode copies.
    use aires::sparse::segio::payload_copy_count;

    let mmap_cfg = StagingConfig::disk(store.clone(), 1).with_mmap(true);
    let mut mem = GpuMem::new(1 << 30);
    let (out_mm_warm, _) =
        layer.forward_cpu(&a_hat, &x, &mut mem, &serial, &mmap_cfg).unwrap();
    let before_copies = payload_copy_count();
    let (out_mm, _) = layer.forward_cpu(&a_hat, &x, &mut mem, &serial, &mmap_cfg).unwrap();
    let mapped_copies = payload_copy_count() - before_copies;
    assert_eq!(
        mapped_copies, 0,
        "warm mapped pass materialized {mapped_copies} payloads over {n} segments"
    );
    assert_eq!(out_mm, out_mm_warm);
    assert_eq!(out_mm, out_recycled, "mapped pass diverged from the copy-path oracle");
    let before_copies = payload_copy_count();
    let (out_cp, _) = layer.forward_cpu(&a_hat, &x, &mut mem, &serial, &fresh_cfg).unwrap();
    let copy_copies = payload_copy_count() - before_copies;
    assert_eq!(out_cp, out_mm);
    assert!(
        copy_copies >= n as u64,
        "copy path must materialize every segment ({copy_copies} copies over {n})"
    );
    assert_eq!(mem.used, 0, "mapped passes left the ledger unbalanced");
}
