//! Cross-module integration: generators -> normalization -> partitioning ->
//! SpGEMM -> schedulers -> experiment harnesses, and the full experiment
//! suite consistency (same numbers from CLI-facing and bench-facing paths).

use aires::coordinator::{
    fig3_cross_check, fig3_merging, fig6_row, fig6_speedup, fig8_bandwidth, mean_speedup,
    table3_memcap, FEAT_DIM, LAYERS,
};
use aires::memsim::CostModel;
use aires::sched::{all_schedulers, Aires, Scheduler, Workload};
use aires::sparse::norm::normalize_adjacency;
use aires::sparse::spgemm::spgemm_gustavson;
use aires::sparse::spmm::{spmm, Dense};
use aires::util::rng::Pcg;

#[test]
fn full_gcn_aggregation_pipeline_on_every_family() {
    // generator -> Â -> RoBW -> per-segment SpMM == whole SpMM.
    let mut rng = Pcg::seed(1);
    for d in aires::graphgen::CATALOG.iter() {
        let g = d.scaled(&mut rng, 400);
        let a_hat = normalize_adjacency(&g);
        let x = Dense::from_vec(
            a_hat.ncols,
            8,
            (0..a_hat.ncols * 8).map(|_| rng.normal() as f32).collect(),
        );
        let whole = spmm(&a_hat, &x);
        let segs = aires::partition::robw::robw_partition(&a_hat, 4096);
        let mut stitched = Dense::zeros(a_hat.nrows, 8);
        for s in &segs {
            let part = spmm(&aires::partition::robw::materialize(&a_hat, s), &x);
            stitched.data[s.row_lo * 8..s.row_hi * 8].copy_from_slice(&part.data);
        }
        assert!(whole.max_abs_diff(&stitched) < 1e-4, "{}", d.name);
    }
}

#[test]
fn spgemm_on_sparse_features_matches_paper_setup() {
    // The paper's actual operand pair: CSR adjacency x CSC sparse features.
    let mut rng = Pcg::seed(2);
    let g = aires::graphgen::kmer::generate(&mut rng, 300, 3.0);
    let a_hat = normalize_adjacency(&g);
    let feats = aires::graphgen::random_sparse_features(&mut rng, 300, 64, 95.0);
    let prod = aires::sparse::spgemm::spgemm_csr_csc(&a_hat, &feats.to_csc());
    let want = spgemm_gustavson(&a_hat, &feats);
    assert_eq!(prod.c.to_dense(), want.to_dense());
    // The Eq. 5 model must cover the real output within its design margin.
    let model = aires::memsim::OutputModel::from_matrices(&a_hat, &feats.to_csc());
    let real = prod.c.size_bytes();
    assert!(model.m_c() as f64 > 0.2 * real as f64, "model absurdly low");
}

#[test]
fn fig3_cross_check_on_real_matrices() {
    // The analytic Fig. 3 harness's premise — naive cuts rows, RoBW does
    // not — verified with the real partitioners on scaled kmer graphs.
    let mut rng = Pcg::seed(3);
    for name in ["kV2a", "kU1a", "kP1a"] {
        let d = aires::graphgen::catalog::by_name(name).unwrap();
        let g = d.scaled(&mut rng, 600);
        let (naive_cuts, robw_mismatch) = fig3_cross_check(&g, 512);
        assert!(naive_cuts > 0, "{name}: naive must cut rows");
        assert_eq!(robw_mismatch, 0, "{name}: RoBW must never cut rows");
    }
}

#[test]
fn experiment_suite_is_deterministic() {
    let cm = CostModel::default();
    let a = fig6_speedup(&cm);
    let b = fig6_speedup(&cm);
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(x.makespan("AIRES"), y.makespan("AIRES"));
        assert_eq!(x.makespan("ETC"), y.makespan("ETC"));
    }
}

#[test]
fn headline_claims_hold() {
    // The abstract's headline: "up to 1.8x lower latency" vs baselines,
    // and consistent speedup across all datasets.
    let cm = CostModel::default();
    let rows = fig6_speedup(&cm);
    let max_speedup = rows
        .iter()
        .filter_map(|r| r.speedup_over("MaxMemory"))
        .fold(0.0f64, f64::max);
    assert!(max_speedup >= 1.8, "peak speedup {max_speedup:.2} must reach 1.8x");
    assert!(mean_speedup(&rows, "ETC") >= 1.4, "mean vs ETC too low");
}

#[test]
fn table3_cells_match_fig6_at_full_constraint() {
    // Table III's first row per dataset uses the Table II constraint, so
    // it must agree with Fig. 6's numbers (single source of truth).
    let cm = CostModel::default();
    let t3 = table3_memcap(&cm);
    for (name, cap) in [("kV1r", 24.0), ("kP1a", 16.0), ("socLJ1", 11.0)] {
        let row = t3
            .iter()
            .find(|r| r.dataset == name && r.constraint_gb == cap)
            .unwrap();
        let d = aires::graphgen::catalog::by_name(name).unwrap();
        let mut w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
        w.gpu_mem_bytes = (cap * 1e9) as u64;
        let direct = Aires.run_epoch(&w, &cm).makespan_s.unwrap();
        let cell = row.cells.iter().find(|(n, _)| *n == "AIRES").unwrap().1.unwrap();
        assert!((direct - cell).abs() < 1e-9);
    }
}

#[test]
fn fig8_bandwidths_within_physical_limits() {
    let cm = CostModel::default();
    for r in fig8_bandwidth(&cm) {
        assert!(r.gpu_ssd_gbps <= cm.gds_read_gbps + 1e-9, "{:?}", r);
        assert!(r.cpu_ssd_gbps <= cm.nvme_read_gbps + 1e-9, "{:?}", r);
    }
}

#[test]
fn merge_overhead_shrinks_with_memory_fig3_obs2() {
    // Fig. 3 observation 2: less memory -> higher merging overhead.
    let cm = CostModel::default();
    let d = aires::graphgen::catalog::by_name("kP1a").unwrap();
    let mut tight = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    tight.gpu_mem_bytes = (15.0e9) as u64;
    let mut loose = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    loose.gpu_mem_bytes = (16.5e9) as u64;
    let r_tight = aires::coordinator::fig3_row(&tight, &cm);
    let r_loose = aires::coordinator::fig3_row(&loose, &cm);
    assert!(r_tight.overhead_pct > r_loose.overhead_pct);
}

#[test]
fn every_scheduler_reports_features_consistent_with_behaviour() {
    let cm = CostModel::default();
    let d = aires::graphgen::catalog::by_name("kU1a").unwrap();
    let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    for s in all_schedulers() {
        let f = s.features();
        let r = s.run_epoch(&w, &cm);
        let gds = r.io.gpu_ssd_bytes();
        let um = r.io.get("UM").bytes;
        assert_eq!(gds > 0, f.dual_way, "{}: GDS usage vs dual_way flag", s.name());
        assert_eq!(um > 0, f.um_reads, "{}: UM usage vs um_reads flag", s.name());
    }
}

#[test]
fn fig6_speedup_scales_with_dataset_size() {
    // Paper observation: "As the dataset size grows, the speedup of AIRES
    // over MaxMemory ... increases" (within the kmer family).
    let cm = CostModel::default();
    let small = fig6_row(aires::graphgen::catalog::by_name("kV2a").unwrap(), &cm);
    let large = fig6_row(aires::graphgen::catalog::by_name("kV1r").unwrap(), &cm);
    let s1 = small.speedup_over("MaxMemory").unwrap();
    let s2 = large.speedup_over("MaxMemory").unwrap();
    assert!(s2 > s1 * 0.9, "speedup should not collapse with scale: {s1:.2} -> {s2:.2}");
}

#[test]
fn failure_injection_degraded_gds() {
    // Failure scenario: GDS path degrades to 10% (firmware/driver issue).
    // AIRES must still complete every workload — slower, but never OOM,
    // and never slower than simply routing everything like MaxMemory.
    let mut cm = CostModel::default();
    cm.gds_read_gbps *= 0.1;
    cm.gds_write_gbps *= 0.1;
    for d in aires::graphgen::CATALOG.iter() {
        let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
        let healthy = Aires.run_epoch(&w, &CostModel::default());
        let degraded = Aires.run_epoch(&w, &cm);
        assert!(degraded.oom.is_none(), "{}: degraded GDS must not OOM", d.name);
        assert!(
            degraded.makespan_s.unwrap() >= healthy.makespan_s.unwrap(),
            "{}: degradation cannot speed things up",
            d.name
        );
    }
}

#[test]
fn config_overrides_flow_into_experiments() {
    // A config that doubles storage speed must strictly improve AIRES.
    let cfg = aires::config::Config::from_json_str(
        r#"{"cost_model":{"nvme_read_gbps":13.2,"gds_read_gbps":11.6,"gds_write_gbps":10.0}}"#,
    )
    .unwrap();
    let base = fig6_row(aires::graphgen::catalog::by_name("kU1a").unwrap(), &CostModel::default());
    let fast = fig6_row(aires::graphgen::catalog::by_name("kU1a").unwrap(), &cfg.cost_model);
    assert!(fast.makespan("AIRES").unwrap() < base.makespan("AIRES").unwrap());
}

#[test]
fn chrome_trace_of_epoch_is_valid_json() {
    let cm = CostModel::default();
    let d = aires::graphgen::catalog::by_name("kV2a").unwrap();
    let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    let r = Aires.run_epoch(&w, &cm);
    let trace = aires::memsim::trace::chrome_trace_log(&r.log);
    let parsed = aires::util::json::parse(&trace).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.len() >= r.log.len(), "every op appears at least once");
}

#[test]
fn fig3_merging_magnitudes() {
    // kV2a ~tens of percent; kP1a several-fold lower (paper: 50% and ~6x).
    let cm = CostModel::default();
    let rows = fig3_merging(&cm);
    let by = |n: &str| rows.iter().find(|r| r.dataset == n).unwrap().overhead_pct;
    assert!(by("kV2a") >= 25.0 && by("kV2a") <= 80.0, "kV2a {:.1}%", by("kV2a"));
    assert!(by("kV2a") / by("kP1a") >= 3.0, "ratio {:.1}", by("kV2a") / by("kP1a"));
}
