//! CLI integration: flag handling exercised against the real binary.
//!
//! Regression coverage for the PR 3 bugfix: malformed flag values used to
//! `expect()`-panic with a backtrace, and `--prefetch-depth 0` was
//! silently floored to 1. Malformed input must now exit with the
//! conventional usage code (2) and a message naming the flag; depth 0
//! must warn explicitly.

use aires::testing::TempDir;
use std::process::Command;

fn aires_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_aires"))
}

fn run(args: &[&str]) -> (Option<i32>, String, String) {
    let out = aires_bin().args(args).output().expect("spawn aires binary");
    (
        out.status.code(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn malformed_prefetch_depth_is_a_usage_error_not_a_panic() {
    let (code, _, err) = run(&["catalog", "--prefetch-depth", "abc"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--prefetch-depth"), "must name the flag: {err}");
    assert!(err.contains("abc"), "must echo the offending value: {err}");
    assert!(!err.contains("panicked"), "must not panic: {err}");
}

#[test]
fn malformed_threads_is_a_usage_error() {
    let (code, _, err) = run(&["catalog", "--threads", "many"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("--threads"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn malformed_host_cache_bytes_is_a_usage_error() {
    let (code, _, err) = run(&["catalog", "--host-cache-bytes", "-5"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("--host-cache-bytes"), "{err}");
}

#[test]
fn malformed_recycle_cap_bytes_is_a_usage_error() {
    let (code, _, err) = run(&["catalog", "--recycle-cap-bytes", "lots"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("--recycle-cap-bytes"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn flag_without_value_is_a_usage_error() {
    // Previously a trailing flag was silently ignored.
    let (code, _, err) = run(&["catalog", "--prefetch-depth"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("requires a value"), "{err}");
}

#[test]
fn malformed_subcommand_numeric_flags_are_usage_errors() {
    // The rework covers pre-existing per-subcommand flags too (parsed
    // before any executor/artifact setup, so this needs no PJRT).
    let (code, _, err) = run(&["spgemm", "--nodes", "60O"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("--nodes"), "{err}");
    let (code, _, err) = run(&["train", "--lr", "fast"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("--lr"), "{err}");
}

#[test]
fn prefetch_depth_zero_warns_and_still_runs() {
    let (code, out, err) = run(&["catalog", "--prefetch-depth", "0"]);
    assert_eq!(code, Some(0), "depth 0 is clamped, not fatal; stderr: {err}");
    assert!(!out.is_empty(), "subcommand still produced its output");
    assert!(err.contains("warning"), "clamp must be announced: {err}");
    assert!(err.contains("--prefetch-depth 0"), "{err}");
}

#[test]
fn missing_config_file_is_a_usage_error_not_a_panic() {
    let (code, _, err) = run(&["catalog", "--config", "/nonexistent/aires-config.json"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(!err.contains("panicked"), "{err}");
    assert!(err.contains("--config"), "{err}");
}

#[test]
fn segcheck_streams_from_disk_and_verifies_byte_identity() {
    let dir = TempDir::new("cli-segcheck");
    let (code, out, err) = run(&[
        "segcheck",
        "--nodes",
        "200",
        "--budget",
        "2048",
        "--segment-dir",
        dir.path().to_str().unwrap(),
        "--host-cache-bytes",
        "65536",
    ]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    assert!(out.contains("recycle pool"), "recycling is on by default: {out}");
    assert!(
        dir.path().join("seg-00000.bin").exists(),
        "--segment-dir must hold the spilled segment files"
    );
}

#[test]
fn gcnstream_layers_zero_warns_and_still_runs() {
    let (code, out, err) =
        run(&["gcnstream", "--nodes", "120", "--budget", "2048", "--layers", "0"]);
    assert_eq!(code, Some(0), "layers 0 is clamped, not fatal; stderr: {err}");
    assert!(err.contains("warning"), "clamp must be announced: {err}");
    assert!(err.contains("--layers 0"), "{err}");
    assert!(out.contains("1 layers"), "runs as a single layer: {out}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
}

#[test]
fn gcnstream_malformed_layers_is_a_usage_error_not_a_panic() {
    let (code, _, err) = run(&["gcnstream", "--layers", "three"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--layers"), "must name the flag: {err}");
    assert!(err.contains("three"), "must echo the offending value: {err}");
    assert!(!err.contains("panicked"), "must not panic: {err}");
    // A trailing flag without a value is the same class of error.
    let (code, _, err) = run(&["gcnstream", "--layers"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("requires a value"), "{err}");
}

#[test]
fn gcnstream_segment_dir_reuse_smoke() {
    // Two runs into the same --segment-dir: the second must reuse the
    // spilled fixture (open_or_spill fingerprint path) and still verify
    // byte-identity across all layers.
    let dir = TempDir::new("cli-gcnstream");
    let args = [
        "gcnstream",
        "--nodes",
        "150",
        "--budget",
        "2048",
        "--layers",
        "2",
        "--segment-dir",
        dir.path().to_str().unwrap(),
    ];
    let (code, out, err) = run(&args);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    assert!(out.contains("layer 1:"), "per-layer report lines: {out}");
    let seg0 = dir.path().join("seg-00000.bin");
    assert!(seg0.exists(), "--segment-dir must hold the spilled segment files");
    let mtime = std::fs::metadata(&seg0).unwrap().modified().unwrap();
    let (code, out, err) = run(&args);
    assert_eq!(code, Some(0), "second run; stderr: {err}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    assert_eq!(
        std::fs::metadata(&seg0).unwrap().modified().unwrap(),
        mtime,
        "byte-valid fixture must be reused, not respilled"
    );
}

#[test]
fn gcnstream_panel_dir_spills_and_verifies() {
    let dir = TempDir::new("cli-gcnstream-panels");
    let (code, out, err) = run(&[
        "gcnstream",
        "--nodes",
        "120",
        "--budget",
        "2048",
        "--layers",
        "3",
        "--panel-dir",
        dir.path().to_str().unwrap(),
    ]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("panel spill"), "panel tier must be reported: {out}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    assert!(
        dir.path().join("panel-00000.bin").exists(),
        "--panel-dir must hold the spilled intermediate panels"
    );
    assert!(
        !dir.path().join("panel-00002.bin").exists(),
        "the final layer's output is returned, never spilled"
    );
}

#[test]
fn serve_open_loop_smoke_reports_latency_and_balance() {
    let out_file = TempDir::new("cli-serve");
    let report = out_file.path().join("serve.json");
    let (code, out, err) = run(&[
        "serve",
        "--scale",
        "7",
        "--feat",
        "16",
        "--budget",
        "4096",
        "--tenants",
        "4",
        "--requests",
        "2",
        "--rate-hz",
        "500",
        "--out",
        report.to_str().unwrap(),
    ]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("4 tenants"), "stdout: {out}");
    assert!(out.contains("tenant 3:"), "per-tenant latency lines: {out}");
    assert!(out.contains("p99"), "stdout: {out}");
    assert!(out.contains("ledger balanced after every batch: OK"), "stdout: {out}");
    assert!(!err.contains("panicked"), "{err}");
    let json = std::fs::read_to_string(&report).expect("--out writes the ServeReport");
    assert!(json.contains("\"ledger_balanced\": true") || json.contains("\"ledger_balanced\":true"),
        "report must record balance: {json}");
    assert!(json.contains("tenant_3"), "report carries every tenant: {json}");
    assert!(json.contains("p99_s"), "report carries percentiles: {json}");
}

#[test]
fn serve_malformed_flags_are_usage_errors_and_zero_clamps_warn() {
    let (code, _, err) = run(&["serve", "--tenants", "many"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--tenants"), "must name the flag: {err}");
    assert!(err.contains("many"), "must echo the offending value: {err}");
    assert!(!err.contains("panicked"), "{err}");
    let (code, _, err) = run(&["serve", "--rate-hz"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("requires a value"), "{err}");
    // Zero tenants serves nobody: clamped to 1 with a warning, not fatal.
    let (code, out, err) = run(&[
        "serve", "--scale", "6", "--feat", "8", "--tenants", "0", "--requests", "1",
        "--rate-hz", "500",
    ]);
    assert_eq!(code, Some(0), "tenants 0 is clamped, not fatal; stderr: {err}");
    assert!(err.contains("warning"), "clamp must be announced: {err}");
    assert!(out.contains("1 tenants"), "runs with one tenant: {out}");
}

// --- bench subcommand family: the perf-trajectory store -----------------

/// Minimal BENCH_streaming.json emission with a controllable gated
/// metric (ns/segment) and a serve p99, mirroring what micro_hotpath
/// writes.
fn bench_emission(ns_per_segment: f64) -> String {
    format!(
        r#"{{"bench":"micro_hotpath/streaming","results":{{"fresh_depth1":{{"mean_s":0.01,"ns_per_segment":{ns_per_segment}}},"serve_open_loop":{{"ledger_balanced":true,"per_tenant":{{"tenant_0":{{"p50_s":0.001,"p99_s":0.002}}}}}}}}}}"#
    )
}

#[test]
fn bench_without_db_is_a_usage_error() {
    for action in ["ingest", "report", "gate"] {
        let (code, _, err) = run(&["bench", action]);
        assert_eq!(code, Some(2), "bench {action} without --db exits 2; stderr: {err}");
        assert!(err.contains("--db"), "must name the missing flag: {err}");
        assert!(!err.contains("panicked"), "{err}");
    }
    // No action at all is the same class of error.
    let (code, _, err) = run(&["bench"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("ingest"), "must list the actions: {err}");
    let (code, _, err) = run(&["bench", "prune", "--db", "x.jsonl"]);
    assert_eq!(code, Some(2), "unknown action exits 2; stderr: {err}");
    assert!(err.contains("prune"), "must echo the unknown action: {err}");
}

#[test]
fn bench_gate_malformed_threshold_is_a_usage_error() {
    let (code, _, err) = run(&["bench", "gate", "--db", "x.jsonl", "--max-regress-pct", "lots"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--max-regress-pct"), "must name the flag: {err}");
    assert!(err.contains("lots"), "must echo the offending value: {err}");
    assert!(!err.contains("panicked"), "{err}");
    // Missing threshold entirely is the same class of error.
    let (code, _, err) = run(&["bench", "gate", "--db", "x.jsonl"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("--max-regress-pct"), "{err}");
}

#[test]
fn bench_gate_on_an_empty_store_warns_and_passes() {
    let dir = TempDir::new("cli-bench-empty");
    // Store file does not exist yet: first CI run seeds, never fails.
    let missing = dir.path().join("trajectory.jsonl");
    let (code, out, err) =
        run(&["bench", "gate", "--db", missing.to_str().unwrap(), "--max-regress-pct", "10"]);
    assert_eq!(code, Some(0), "missing store passes; stderr: {err}");
    assert!(out.contains("PASS"), "stdout: {out}");
    assert!(err.contains("warning"), "the vacuous pass must be announced: {err}");
    // An existing-but-empty store is the same vacuous pass (no division).
    let empty = dir.path().join("empty.jsonl");
    std::fs::write(&empty, "").unwrap();
    let (code, out, err) =
        run(&["bench", "gate", "--db", empty.to_str().unwrap(), "--max-regress-pct", "10"]);
    assert_eq!(code, Some(0), "empty store passes; stderr: {err}");
    assert!(out.contains("PASS"), "stdout: {out}");
    assert!(err.contains("warning"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn bench_ingest_report_gate_end_to_end() {
    let dir = TempDir::new("cli-bench-e2e");
    let db = dir.path().join("perf/trajectory.jsonl");
    let db_s = db.to_str().unwrap().to_string();
    let json = dir.path().join("BENCH_streaming.json");
    let json_s = json.to_str().unwrap().to_string();

    // Two healthy runs. Run identity is (ts, commit): same-second
    // ingests stay ordered because run-a < run-b < run-c lexically.
    for (commit, ns) in [("run-a", 100.0), ("run-b", 102.0)] {
        std::fs::write(&json, bench_emission(ns)).unwrap();
        let (code, out, err) =
            run(&["bench", "ingest", "--db", &db_s, "--json", &json_s, "--commit", commit]);
        assert_eq!(code, Some(0), "stderr: {err}");
        assert!(out.contains("ingested"), "stdout: {out}");
        assert!(out.contains(commit), "run identity echoed: {out}");
    }

    // Report renders per-scenario stats incl. the serve percentiles,
    // plus the cross-commit trend of the gated series.
    let (code, out, err) = run(&["bench", "report", "--db", &db_s]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("2 stored run(s)"), "stdout: {out}");
    assert!(out.contains("| fresh_depth1 | ns_per_segment | ns |"), "stdout: {out}");
    assert!(out.contains("per_tenant.tenant_0.p99_s"), "serve p99 folded in: {out}");
    assert!(out.contains("Cross-commit trend"), "trend table renders: {out}");
    assert!(out.contains("100.0000 → 102.0000"), "per-run values oldest → latest: {out}");
    assert!(out.contains("+2.00%"), "latest delta vs the previous commit: {out}");

    // +2% is within a 10% threshold.
    let (code, out, err) =
        run(&["bench", "gate", "--db", &db_s, "--max-regress-pct", "10"]);
    assert_eq!(code, Some(0), "within-threshold run passes; stderr: {err}\n{out}");
    assert!(out.contains("PASS"), "stdout: {out}");

    // A synthetic 10x regression as the newest run fails the same gate.
    std::fs::write(&json, bench_emission(1000.0)).unwrap();
    let (code, _, err) =
        run(&["bench", "ingest", "--db", &db_s, "--json", &json_s, "--commit", "run-c"]);
    assert_eq!(code, Some(0), "stderr: {err}");
    let (code, out, err) =
        run(&["bench", "gate", "--db", &db_s, "--max-regress-pct", "10"]);
    assert_eq!(code, Some(1), "regression beyond threshold exits 1; stdout: {out}");
    assert!(out.contains("FAIL"), "the failing check is rendered: {out}");
    assert!(err.contains("FAIL"), "stderr announces the verdict: {err}");
    assert!(!err.contains("panicked"), "{err}");

    // A garbage line in the store degrades to a warning, never a panic:
    // report still renders the valid prefix and gate still gates.
    use std::io::Write as _;
    let mut f = std::fs::OpenOptions::new().append(true).open(&db).unwrap();
    writeln!(f, "torn garbage {{").unwrap();
    drop(f);
    let (code, out, err) = run(&["bench", "report", "--db", &db_s]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(err.contains("skipped line"), "defect reported on stderr: {err}");
    assert!(out.contains("3 stored run(s)"), "valid prefix renders: {out}");
}

#[test]
fn bench_db_config_key_is_the_flag_fallback() {
    let dir = TempDir::new("cli-bench-cfg");
    let db = dir.path().join("trajectory.jsonl");
    let cfg = dir.path().join("aires.json");
    std::fs::write(&cfg, format!(r#"{{"bench_db":"{}"}}"#, db.to_str().unwrap())).unwrap();
    // With the config key set, --db is optional; store is still missing,
    // so gate warns-and-passes through the fallback path.
    let (code, out, err) = run(&[
        "bench",
        "gate",
        "--config",
        cfg.to_str().unwrap(),
        "--max-regress-pct",
        "10",
    ]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("PASS"), "stdout: {out}");
}

// --- train subcommand: exit conventions + the streamed trainer ----------

#[test]
fn train_without_artifacts_is_an_error_not_a_panic() {
    // The dense path needs compiled PJRT artifacts. Without them it must
    // exit 1 with a message naming the failing stage (previously the
    // last `expect()` panic left in the CLI); with them it trains and
    // exits 0. Either way: no panics.
    let (code, out, err) = run(&["train", "--steps", "1", "--nodes", "64"]);
    match code {
        Some(0) => assert!(out.contains("loss"), "stdout: {out}"),
        Some(1) => assert!(err.contains("error:"), "stderr must name the stage: {err}"),
        other => panic!("expected exit 0 or 1, got {other:?}; stderr: {err}"),
    }
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn train_stream_steps_zero_warns_and_still_runs() {
    // --steps 0 has no losses to report (a typed error in the trainers);
    // the CLI clamps to 1 with a warning, same convention as
    // --prefetch-depth 0.
    let (code, out, err) = run(&[
        "train", "--train-stream", "--steps", "0", "--nodes", "80", "--layers", "2",
        "--budget", "2048",
    ]);
    assert_eq!(code, Some(0), "steps 0 is clamped, not fatal; stderr: {err}");
    assert!(err.contains("warning"), "clamp must be announced: {err}");
    assert!(err.contains("--steps 0"), "{err}");
    assert!(out.contains("streamed loss matches dense oracle: OK"), "stdout: {out}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn train_stream_matches_dense_oracle_across_policies() {
    // The streamed trainer verifies every step's loss bitwise against
    // the dense CPU oracle in-process; the CLI smoke pins that end to
    // end for each recompute policy, with activation/gradient panels
    // landing in --panel-dir.
    let dir = TempDir::new("cli-train-stream");
    for policy in ["reload", "recompute", "auto"] {
        let panel_dir = dir.path().join(policy);
        let (code, out, err) = run(&[
            "train", "--train-stream", "--nodes", "120", "--steps", "2", "--layers", "3",
            "--budget", "2048", "--lr", "0.5", "--recompute-policy", policy,
            "--panel-dir", panel_dir.to_str().unwrap(),
        ]);
        assert_eq!(code, Some(0), "policy {policy}; stderr: {err}");
        assert!(out.contains("streamed loss matches dense oracle: OK"), "policy {policy}: {out}");
        assert!(out.contains("ns_per_step"), "per-step timing reported: {out}");
        assert!(out.contains("backward segments"), "backward sweep reported: {out}");
        assert!(
            panel_dir.join("panel-00000.bin").exists(),
            "policy {policy}: --panel-dir must hold the spilled activation panels"
        );
        assert!(!err.contains("panicked"), "{err}");
    }
}

#[test]
fn train_stream_malformed_policy_is_a_usage_error() {
    let (code, _, err) = run(&["train", "--train-stream", "--recompute-policy", "fast"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--recompute-policy"), "must name the flag: {err}");
    assert!(err.contains("fast"), "must echo the offending value: {err}");
    assert!(!err.contains("panicked"), "{err}");
}

// --- self-healing reads + checkpoint-resume (PR 9) ----------------------

#[test]
fn malformed_heal_flags_are_usage_errors() {
    let (code, _, err) = run(&["catalog", "--retry-max", "abc"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--retry-max"), "must name the flag: {err}");
    assert!(err.contains("abc"), "must echo the offending value: {err}");
    assert!(!err.contains("panicked"), "{err}");
    let (code, _, err) = run(&["catalog", "--retry-backoff-ios"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("requires a value"), "{err}");
}

#[test]
fn faultcheck_heals_and_resumes_deterministically() {
    // The chaos harness end to end: injected transient faults and
    // on-disk corruption must heal to the fault-free oracle's bytes,
    // and a killed-then-resumed streamed run must reproduce the
    // uninterrupted parameters bit for bit.
    let (code, out, err) = run(&["faultcheck"]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("healed output matches oracle: OK"), "stdout: {out}");
    assert!(out.contains("resumed parameters match uninterrupted run: OK"), "stdout: {out}");
    assert!(out.contains("ledger balanced after every scenario: OK"), "stdout: {out}");
    assert!(!err.contains("panicked"), "{err}");
}

/// The `final params fnv64: 0x...` fingerprint line the streamed
/// trainer prints after the optimizer finishes.
fn params_fingerprint(out: &str) -> String {
    out.lines()
        .find(|l| l.starts_with("final params fnv64:"))
        .unwrap_or_else(|| panic!("no fingerprint line in: {out}"))
        .to_string()
}

#[test]
fn train_stream_checkpoint_resume_matches_uninterrupted_run() {
    // A run killed between steps and resumed via --checkpoint-dir must
    // finish with the same parameter bytes as one uninterrupted run.
    let dir = TempDir::new("cli-train-resume");
    let ckdir = dir.path().join("ck");
    let base = [
        "train", "--train-stream", "--nodes", "100", "--steps", "4", "--layers", "2",
        "--budget", "2048", "--lr", "0.5",
    ];
    let (code, out, err) = run(&base);
    assert_eq!(code, Some(0), "uninterrupted run; stderr: {err}");
    let want = params_fingerprint(&out);

    // "Killed" run: two of the four steps, checkpointed.
    let mut partial = base.to_vec();
    partial[5] = "2"; // --steps 2

    partial.extend_from_slice(&["--checkpoint-dir", ckdir.to_str().unwrap()]);
    let (code, _, err) = run(&partial);
    assert_eq!(code, Some(0), "partial run; stderr: {err}");
    assert!(ckdir.join("checkpoint.bin").exists(), "checkpoint must be persisted");

    // Resume: picks up at step 2 and lands on the same bytes.
    let mut resumed = base.to_vec();
    resumed.extend_from_slice(&["--checkpoint-dir", ckdir.to_str().unwrap()]);
    let (code, out, err) = run(&resumed);
    assert_eq!(code, Some(0), "resumed run; stderr: {err}");
    assert!(
        out.contains("resumed from checkpoint: 2 step(s) already complete"),
        "resume must be announced: {out}"
    );
    assert_eq!(params_fingerprint(&out), want, "resumed parameters must match: {out}");
    assert!(out.contains("streamed loss matches dense oracle: OK"), "stdout: {out}");

    // A third run has nothing left to do but still verifies and reports.
    let (code, out, err) = run(&resumed);
    assert_eq!(code, Some(0), "no-op resume; stderr: {err}");
    assert!(out.contains("checkpoint already covers all 4 step(s)"), "stdout: {out}");
    assert_eq!(params_fingerprint(&out), want, "restored parameters must match: {out}");
    assert!(!err.contains("panicked"), "{err}");
}

// --- storage engine v2: --seg-encoding / --mmap (PR 10) -----------------

#[test]
fn malformed_seg_encoding_is_a_usage_error() {
    let (code, _, err) = run(&["catalog", "--seg-encoding", "zip"]);
    assert_eq!(code, Some(2), "usage errors exit 2; stderr: {err}");
    assert!(err.contains("--seg-encoding"), "must name the flag: {err}");
    assert!(err.contains("zip"), "must echo the offending value: {err}");
    assert!(err.contains("raw"), "must list the accepted encodings: {err}");
    assert!(!err.contains("panicked"), "{err}");
    let (code, _, err) = run(&["catalog", "--seg-encoding"]);
    assert_eq!(code, Some(2), "stderr: {err}");
    assert!(err.contains("requires a value"), "{err}");
}

#[test]
fn segcheck_packed_encoding_and_mmap_verify_byte_identity() {
    // The compressed store plus zero-copy reads still verify against the
    // in-memory oracle, the chosen encoding lands on disk as
    // KIND_CSR_PACKED records, and switching the encoding respills the
    // fixture instead of reusing bytes in the wrong layout.
    let dir = TempDir::new("cli-segcheck-packed");
    let base = |enc: &str| {
        vec![
            "segcheck".to_string(),
            "--nodes".to_string(),
            "200".to_string(),
            "--budget".to_string(),
            "2048".to_string(),
            "--segment-dir".to_string(),
            dir.path().to_str().unwrap().to_string(),
            "--seg-encoding".to_string(),
            enc.to_string(),
        ]
    };
    let mut packed_args = base("packed");
    packed_args.push("--mmap".to_string());
    let packed_refs: Vec<&str> = packed_args.iter().map(|s| s.as_str()).collect();
    let (code, out, err) = run(&packed_refs);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    assert!(out.contains("packed encoding"), "the chosen encoding is reported: {out}");
    let seg0 = dir.path().join("seg-00000.bin");
    let hdr = std::fs::read(&seg0).unwrap();
    let kind = u32::from_le_bytes(hdr[12..16].try_into().unwrap());
    assert_eq!(kind, 3, "--seg-encoding packed must write KIND_CSR_PACKED records");

    // Same directory, raw encoding: the packed fixture must not be
    // reused — the marker is keyed by encoding.
    let raw_args = base("raw");
    let raw_refs: Vec<&str> = raw_args.iter().map(|s| s.as_str()).collect();
    let (code, out, err) = run(&raw_refs);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    let hdr = std::fs::read(&seg0).unwrap();
    let kind = u32::from_le_bytes(hdr[12..16].try_into().unwrap());
    assert_eq!(kind, 0, "switching to raw must respill KIND_CSR records");
}

#[test]
fn segcheck_with_recycling_disabled_still_verifies() {
    // --recycle-cap-bytes 0 selects the fresh-allocation path; output
    // must be byte-identical either way and the pool line disappears.
    let dir = TempDir::new("cli-segcheck-fresh");
    let (code, out, err) = run(&[
        "segcheck",
        "--nodes",
        "200",
        "--budget",
        "2048",
        "--segment-dir",
        dir.path().to_str().unwrap(),
        "--recycle-cap-bytes",
        "0",
    ]);
    assert_eq!(code, Some(0), "stderr: {err}");
    assert!(out.contains("byte-identical"), "stdout: {out}");
    assert!(!out.contains("recycle pool"), "no pool line when disabled: {out}");
}
