//! Property-based tests over the coordinator-side invariants: sparse
//! format round-trips, SpGEMM algebra, RoBW/naive partitioning laws,
//! the Eq. 5-7 allocation model, and scheduler-level monotonicity.

use aires::memsim::{CostModel, OutputModel};
use aires::partition::naive::{merge_overhead, naive_partition};
use aires::partition::robw::{calc_mem, materialize, robw_partition};
use aires::sched::{all_schedulers, Scheduler, Workload};
use aires::sparse::spgemm::{spgemm_csr_csc, spgemm_gustavson};
use aires::sparse::{Bsr, Csr};
use aires::testing::{check, gen};

// ----------------------------------------------------------- sparse formats

#[test]
fn prop_csr_csc_roundtrip() {
    check("csr<->csc roundtrip", 10, |rng| {
        let a = gen::csr(rng, 30, 0.35);
        let back = a.to_csc().to_csr();
        if back == a { Ok(()) } else { Err("roundtrip mismatch".into()) }
    });
}

#[test]
fn prop_bsr_dense_equals_csr_dense() {
    check("bsr == csr dense", 11, |rng| {
        let a = gen::csr(rng, 30, 0.3);
        let bm = 1 << rng.range(0, 4);
        let bk = 1 << rng.range(0, 4);
        let bsr = Bsr::from_csr(&a, bm, bk);
        if bsr.to_dense() == a.to_dense() {
            Ok(())
        } else {
            Err(format!("bm={bm} bk={bk}"))
        }
    });
}

#[test]
fn prop_spgemm_formulations_agree() {
    check("gustavson == csr*csc", 12, |rng| {
        let m = rng.range(1, 14);
        let k = rng.range(1, 14);
        let n = rng.range(1, 14);
        let a = gen::csr(rng, 14, 0.4).slice_rows(0, 0); // placeholder, rebuilt below
        let _ = a;
        // build explicit shapes
        let mk = |rng: &mut aires::util::rng::Pcg, r: usize, c: usize| {
            let mut coo = aires::sparse::Coo::new(r, c);
            for i in 0..r {
                for j in 0..c {
                    if rng.chance(0.3) {
                        coo.push(i as u32, j as u32, rng.range(1, 9) as f32 * 0.25);
                    }
                }
            }
            coo.to_csr()
        };
        let a = mk(rng, m, k);
        let b = mk(rng, k, n);
        let g = spgemm_gustavson(&a, &b);
        let x = spgemm_csr_csc(&a, &b.to_csc());
        if g.to_dense() == x.c.to_dense() { Ok(()) } else { Err("mismatch".into()) }
    });
}

#[test]
fn prop_spgemm_distributes_over_row_splits() {
    // C = A·B computed whole must equal vstack of per-segment products —
    // the algebraic fact RoBW streaming relies on.
    check("row-split distributivity", 13, |rng| {
        let a = gen::csr(rng, 24, 0.3);
        let b = {
            let mut coo = aires::sparse::Coo::new(a.ncols, rng.range(1, 16));
            for i in 0..a.ncols {
                for j in 0..coo.ncols {
                    if rng.chance(0.3) {
                        coo.push(i as u32, j as u32, rng.normal() as f32);
                    }
                }
            }
            coo.to_csr()
        };
        let whole = spgemm_gustavson(&a, &b);
        let budget = 64 + rng.below(512);
        let parts: Vec<Csr> = robw_partition(&a, budget)
            .iter()
            .map(|s| spgemm_gustavson(&materialize(&a, s), &b))
            .collect();
        let stacked = Csr::vstack(&parts).map_err(|e| e)?;
        let (d1, d2) = (whole.to_dense(), stacked.to_dense());
        let close = d1
            .iter()
            .zip(d2.iter())
            .all(|(x, y)| (x - y).abs() <= 1e-4 * (1.0 + x.abs()));
        if close { Ok(()) } else { Err("segment product mismatch".into()) }
    });
}

// ------------------------------------------------------------- partitioning

#[test]
fn prop_robw_partition_laws() {
    check("robw laws", 14, |rng| {
        let a = gen::csr(rng, 60, 0.25);
        let budget = 48 + rng.below(2048);
        let segs = robw_partition(&a, budget);
        // Coverage + contiguity.
        if segs[0].row_lo != 0 || segs.last().unwrap().row_hi != a.nrows {
            return Err("does not cover".into());
        }
        for w in segs.windows(2) {
            if w[0].row_hi != w[1].row_lo {
                return Err("not contiguous".into());
            }
        }
        for s in &segs {
            // Budget respected unless a single oversized row.
            if s.row_hi - s.row_lo > 1 && s.bytes > budget {
                return Err(format!("over budget: {s:?}"));
            }
            // calcMem consistency.
            if s.bytes != calc_mem(s.row_hi - s.row_lo, s.nnz) {
                return Err("calc_mem mismatch".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_naive_covers_and_robw_never_cuts() {
    check("naive vs robw cuts", 15, |rng| {
        let a = gen::csr(rng, 50, 0.3);
        let budget = 40 + rng.below(1024);
        let naive = naive_partition(&a, budget);
        if naive[0].nnz_lo != 0 || naive.last().unwrap().nnz_hi != a.nnz() {
            return Err("naive does not cover".into());
        }
        let ov = merge_overhead(&naive);
        // Merge bytes are consistent: dtoh == resend, host merge == 2x.
        if ov.dtoh_bytes != ov.resend_bytes || ov.host_merge_bytes != 2 * ov.dtoh_bytes {
            return Err("merge accounting inconsistent".into());
        }
        // RoBW reassembles exactly (no cuts by construction).
        let parts: Vec<Csr> =
            robw_partition(&a, budget).iter().map(|s| materialize(&a, s)).collect();
        if Csr::vstack(&parts).unwrap() != a {
            return Err("robw reassembly mismatch".into());
        }
        Ok(())
    });
}

// ------------------------------------------------------------ memory model

#[test]
fn prop_eq7_monotone_in_memory() {
    check("eq7 monotone", 16, |rng| {
        let a = gen::csr(rng, 40, 0.3);
        let b = gen::csr(rng, 40, 0.3);
        let model = OutputModel::from_matrices(&a, &b.to_csc());
        let m1 = (1u64 << 20) + rng.below(1 << 24);
        let m2 = m1 * 2;
        match (model.block_budget(m1), model.block_budget(m2)) {
            (Some(p1), Some(p2)) if p2 < p1 => Err(format!("p shrank: {p1} -> {p2}")),
            (Some(_), None) => Err("lost feasibility with more memory".into()),
            _ => Ok(()),
        }
    });
}

// --------------------------------------------------------------- schedulers

#[test]
fn prop_schedulers_monotone_in_memory() {
    // More GPU memory never makes any policy slower (weak monotonicity,
    // small tolerance for pipeline-granularity noise).
    let cm = CostModel::default();
    check("sched monotone", 17, |rng| {
        let d = &aires::graphgen::CATALOG[rng.range(0, 7)];
        let mut w1 = Workload::from_catalog(d, 256, 1);
        let cap = w1.gpu_mem_bytes;
        w1.gpu_mem_bytes = cap + rng.below(cap / 2);
        let mut w2 = w1.clone();
        w2.gpu_mem_bytes = w1.gpu_mem_bytes + rng.below(cap / 2) + 1;
        for s in all_schedulers() {
            let r1 = s.run_epoch(&w1, &cm);
            let r2 = s.run_epoch(&w2, &cm);
            if let (Some(t1), Some(t2)) = (r1.makespan_s, r2.makespan_s) {
                if t2 > t1 * 1.02 {
                    return Err(format!("{}: {t1} -> {t2} with more memory", s.name()));
                }
            }
            if r1.oom.is_none() && r2.oom.is_some() {
                return Err(format!("{}: OOM appeared with more memory", s.name()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_aires_always_survives_where_etc_does() {
    let cm = CostModel::default();
    check("aires dominates etc feasibility", 18, |rng| {
        let d = &aires::graphgen::CATALOG[rng.range(0, 7)];
        let mut w = Workload::from_catalog(d, 256, 1);
        // Sweep caps from 30%..110% of the Table II constraint.
        let frac = 0.3 + rng.f64() * 0.8;
        w.gpu_mem_bytes = ((w.gpu_mem_bytes as f64) * frac) as u64;
        let etc = aires::sched::Etc.run_epoch(&w, &cm);
        let aires_r = aires::sched::Aires.run_epoch(&w, &cm);
        if etc.oom.is_none() && aires_r.oom.is_some() {
            return Err(format!("ETC ran but AIRES OOMed at {} bytes", w.gpu_mem_bytes));
        }
        Ok(())
    });
}

#[test]
fn prop_io_volumes_ordering() {
    // AIRES moves the least GPU-CPU data; MaxMemory the most (Fig. 7).
    let cm = CostModel::default();
    check("io ordering", 19, |rng| {
        let d = &aires::graphgen::CATALOG[rng.range(0, 7)];
        let w = Workload::from_catalog(d, 256, 1);
        let get = |s: &dyn Scheduler| {
            let r = s.run_epoch(&w, &cm);
            r.io.gpu_cpu_bytes()
        };
        let aires_b = get(&aires::sched::Aires);
        let etc_b = get(&aires::sched::Etc);
        let mm_b = get(&aires::sched::MaxMemory);
        if aires_b > etc_b {
            return Err(format!("AIRES {aires_b} > ETC {etc_b}"));
        }
        if etc_b > mm_b {
            return Err(format!("ETC {etc_b} > MaxMemory {mm_b}"));
        }
        Ok(())
    });
}


// ------------------------------------------------------------ misc fuzzing

#[test]
fn prop_json_roundtrip_fuzz() {
    use aires::util::json::{parse, Json};
    fn gen_json(rng: &mut aires::util::rng::Pcg, depth: usize) -> Json {
        match if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => Json::Str(
                (0..rng.range(0, 12))
                    .map(|_| char::from(b'a' + rng.below(26) as u8))
                    .collect::<String>()
                    + if rng.chance(0.3) { "\"\n" } else { "" },
            ),
            4 => Json::Arr((0..rng.range(0, 4)).map(|_| gen_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.range(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json roundtrip", 20, |rng| {
        let v = gen_json(rng, 3);
        let text = v.to_string();
        match parse(&text) {
            Ok(back) if back == v => Ok(()),
            Ok(back) => Err(format!("{v} -> {text} -> {back}")),
            Err(e) => Err(format!("{text}: {e}")),
        }
    });
}

#[test]
fn prop_more_layers_cost_more() {
    // Epoch latency must grow (roughly linearly) with GCN depth for every
    // scheduler — the cycles() contract.
    let cm = CostModel::default();
    check("layers scaling", 21, |rng| {
        let d = &aires::graphgen::CATALOG[rng.range(0, 7)];
        let w1 = Workload::from_catalog(d, 256, 1);
        let w2 = Workload::from_catalog(d, 256, 2);
        for s in all_schedulers() {
            let (r1, r2) = (s.run_epoch(&w1, &cm), s.run_epoch(&w2, &cm));
            if let (Some(t1), Some(t2)) = (r1.makespan_s, r2.makespan_s) {
                if t2 < t1 {
                    return Err(format!("{}: 2 layers faster than 1", s.name()));
                }
                if t2 > 3.0 * t1 {
                    return Err(format!("{}: superlinear depth blowup {t1} -> {t2}", s.name()));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sparsify_assemble_roundtrip() {
    use aires::sparse::spmm::{assemble_csr_c, Dense};
    check("sparsify/assemble", 22, |rng| {
        let a = gen::csr(rng, 40, 0.3);
        let f = rng.range(1, 8);
        let h = Dense::from_vec(
            a.ncols,
            f,
            (0..a.ncols * f).map(|_| rng.normal() as f32).collect(),
        );
        let whole = aires::sparse::spmm::spmm(&a, &h);
        let budget = 64 + rng.below(512);
        let parts: Vec<(usize, Dense)> = robw_partition(&a, budget)
            .iter()
            .map(|s| (s.row_lo, aires::sparse::spmm::spmm(&materialize(&a, s), &h)))
            .collect();
        let assembled = assemble_csr_c(&parts, f, 0.0);
        if assembled.to_dense() == whole.to_csr(0.0).to_dense() {
            Ok(())
        } else {
            Err("assembled CSR C mismatch".into())
        }
    });
}
