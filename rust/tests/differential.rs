//! Differential-testing oracle suite for the parallel execution engine.
//!
//! Contract: every parallel row-range kernel produces output **exactly
//! equal** (same structure, same f32 bits up to `==`) to its serial oracle
//! at every thread count in {1, 2, 4, 8} — determinism comes from fixed
//! row-range partitioning plus ordered merges, never atomics-ordered
//! accumulation, so equality is structural, not statistical.
//!
//! Operands come from three sources: random CSR/CSC via `testing::gen`
//! (density-floored so properties cannot pass vacuously), pathological
//! shapes (empty rows, hub row, 1×N, N×1), and the graphgen families the
//! paper's datasets map to (rmat, road, kmer adjacencies).
//!
//! Beyond the kernels, the same contract covers the *planning* and
//! *streaming* layers: `robw_partition_par` must emit the exact serial
//! plan, and the `runtime::prefetch` pipeline (`OocGcnLayer::forward_cpu`
//! / `forward_staged`) must produce byte-identical layer output at every
//! prefetch depth × thread count combination.
//!
//! Case count per property: `AIRES_PROP_CASES` (default 64).

use aires::gcn::model::dense_affine;
use aires::gcn::{serve_batch, OocGcnLayer, OocGcnModel, PipelineConfig, StagingConfig, TenantQuery};
use aires::memsim::GpuMem;
use aires::runtime::segstore::PanelStore;
use aires::partition::robw::{robw_partition, robw_partition_par};
use aires::runtime::pool::Pool;
use aires::runtime::recycle::BufferPool;
use aires::runtime::segstore::{SegmentStore, UNBOUNDED_CACHE};
use aires::testing::TempDir;
use std::sync::Arc;
use aires::runtime::tile_exec::CpuTileSpmm;
use aires::sparse::block::{pack_csr_batches, pack_csr_batches_par, SpmmBatch};
use aires::sparse::norm::normalize_adjacency;
use aires::sparse::spgemm::{spgemm_gustavson, spgemm_gustavson_par};
use aires::sparse::spmm::{spmm, spmm_par, spmm_transpose, spmm_transpose_par};
use aires::sparse::Csr;
use aires::testing::{check, gen};
use aires::util::rng::Pcg;

const THREADS: [usize; 4] = [1, 2, 4, 8];
/// Prefetch-pipeline sweep: depth {1,2,4} × threads {1,2,8}.
const PREFETCH_DEPTHS: [usize; 3] = [1, 2, 4];
const PREFETCH_THREADS: [usize; 3] = [1, 2, 8];

fn batches_eq(a: &[SpmmBatch], b: &[SpmmBatch]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b.iter()).all(|(x, y)| {
            x.slot_block_row == y.slot_block_row
                && x.nblk == y.nblk
                && x.colidx == y.colidx
                && x.blocks == y.blocks
        })
}

/// The paper-family graphs at test scale (square adjacencies).
fn graph_cases() -> Vec<(&'static str, Csr)> {
    let mut rng = Pcg::seed(7);
    vec![
        ("rmat-9", aires::graphgen::rmat::generate(&mut rng, 9, 8, Default::default())),
        ("road-500", aires::graphgen::road::generate(&mut rng, 500)),
        ("kmer-600", aires::graphgen::kmer::generate(&mut rng, 600, 3.2)),
    ]
}

// ------------------------------------------------------------------ SpGEMM

#[test]
fn diff_spgemm_par_random_operands() {
    check("spgemm_gustavson_par == oracle (random)", 101, |rng| {
        let a = gen::csr(rng, 40, 0.35);
        let n = rng.range(1, 41);
        let b = gen::csr_with_shape(rng, a.ncols, n, 0.35);
        let want = spgemm_gustavson(&a, &b);
        for &t in &THREADS {
            let got = spgemm_gustavson_par(&a, &b, &Pool::new(t));
            got.validate()?;
            if got != want {
                return Err(format!("threads={t}: parallel SpGEMM diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spgemm_par_pathological_operands() {
    check("spgemm_gustavson_par == oracle (pathological)", 102, |rng| {
        let a = gen::pathological(rng, 24);
        let n = rng.range(1, 25);
        let b = gen::csr_with_shape(rng, a.ncols, n, 0.3);
        let want = spgemm_gustavson(&a, &b);
        for &t in &THREADS {
            if spgemm_gustavson_par(&a, &b, &Pool::new(t)) != want {
                return Err(format!(
                    "threads={t}: diverged on pathological {}x{} (nnz {})",
                    a.nrows,
                    a.ncols,
                    a.nnz()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spgemm_par_graph_families() {
    for (name, g) in graph_cases() {
        let want = spgemm_gustavson(&g, &g);
        for &t in &THREADS {
            let got = spgemm_gustavson_par(&g, &g, &Pool::new(t));
            assert_eq!(got, want, "{name}: A*A diverged at {t} threads");
        }
    }
}

// -------------------------------------------------------------------- SpMM

/// The pre-lane-blocking scalar SpMM, kept verbatim as the bit-identity
/// oracle: one `out[j] += a_ik * h_kj` per non-zero, `j` innermost. The
/// lane-blocked microkernel reorders *memory traffic* (feature blocks,
/// register accumulators) but must preserve the per-element f32 operation
/// sequence exactly, so `==` — not an epsilon — is the contract.
fn scalar_spmm(a: &Csr, h: &aires::sparse::spmm::Dense) -> aires::sparse::spmm::Dense {
    let f = h.ncols;
    let mut out = aires::sparse::spmm::Dense::zeros(a.nrows, f);
    for i in 0..a.nrows {
        let orow = &mut out.data[i * f..(i + 1) * f];
        for (k, av) in a.row(i) {
            let hrow = h.row(k as usize);
            for (o, &hv) in orow.iter_mut().zip(hrow.iter()) {
                *o += av * hv;
            }
        }
    }
    out
}

/// The pre-lane-blocking scalar transpose SpMM (scatter form), verbatim.
fn scalar_spmm_transpose(
    a: &Csr,
    h: &aires::sparse::spmm::Dense,
) -> aires::sparse::spmm::Dense {
    let f = h.ncols;
    let mut out = aires::sparse::spmm::Dense::zeros(a.ncols, f);
    for i in 0..a.nrows {
        let hrow = h.row(i);
        for (k, av) in a.row(i) {
            let orow = &mut out.data[k as usize * f..(k as usize + 1) * f];
            for (o, &hv) in orow.iter_mut().zip(hrow.iter()) {
                *o += av * hv;
            }
        }
    }
    out
}

#[test]
fn diff_lane_blocked_spmm_bit_equals_scalar_oracle() {
    check("lane-blocked spmm == pre-PR scalar kernel", 111, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 40) } else { gen::csr(rng, 40, 0.3) };
        // Sweep widths around the lane boundary: blocked body, tail, both.
        let f = rng.range(1, 21);
        let h = gen::dense(rng, a.ncols, f);
        if spmm(&a, &h) != scalar_spmm(&a, &h) {
            return Err(format!("spmm diverged at f={f} on {}x{}", a.nrows, a.ncols));
        }
        let ht = gen::dense(rng, a.nrows, f);
        if spmm_transpose(&a, &ht) != scalar_spmm_transpose(&a, &ht) {
            return Err(format!("spmm_transpose diverged at f={f}"));
        }
        Ok(())
    });
}

#[test]
fn diff_lane_blocked_spmm_graph_families() {
    let mut rng = Pcg::seed(15);
    for (name, g) in graph_cases() {
        for f in [1usize, 7, 8, 9, 16, 19] {
            let h = gen::dense(&mut rng, g.ncols, f);
            assert_eq!(spmm(&g, &h), scalar_spmm(&g, &h), "{name}: spmm diverged at f={f}");
            let ht = gen::dense(&mut rng, g.nrows, f);
            assert_eq!(
                spmm_transpose(&g, &ht),
                scalar_spmm_transpose(&g, &ht),
                "{name}: transpose diverged at f={f}"
            );
        }
    }
}

#[test]
fn diff_spmm_par_random_operands() {
    check("spmm_par == oracle (random)", 103, |rng| {
        let a = gen::csr(rng, 40, 0.3);
        let f = rng.range(1, 12);
        let h = gen::dense(rng, a.ncols, f);
        let want = spmm(&a, &h);
        for &t in &THREADS {
            if spmm_par(&a, &h, &Pool::new(t)) != want {
                return Err(format!("threads={t}: parallel SpMM diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spmm_par_pathological_operands() {
    check("spmm_par == oracle (pathological)", 104, |rng| {
        let a = gen::pathological(rng, 24);
        let f = rng.range(1, 12);
        let h = gen::dense(rng, a.ncols, f);
        let want = spmm(&a, &h);
        for &t in &THREADS {
            if spmm_par(&a, &h, &Pool::new(t)) != want {
                return Err(format!("threads={t}: diverged on {}x{}", a.nrows, a.ncols));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spmm_transpose_par_random_operands() {
    check("spmm_transpose_par == oracle", 105, |rng| {
        let a = gen::csr(rng, 40, 0.3);
        let f = rng.range(1, 12);
        let h = gen::dense(rng, a.nrows, f);
        let want = spmm_transpose(&a, &h);
        for &t in &THREADS {
            if spmm_transpose_par(&a, &h, &Pool::new(t)) != want {
                return Err(format!("threads={t}: parallel transpose SpMM diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spmm_par_graph_families() {
    let mut rng = Pcg::seed(8);
    for (name, g) in graph_cases() {
        let h = gen::dense(&mut rng, g.ncols, 16);
        let want = spmm(&g, &h);
        let want_t = spmm_transpose(&g, &h);
        for &t in &THREADS {
            let pool = Pool::new(t);
            assert_eq!(spmm_par(&g, &h, &pool), want, "{name}: SpMM diverged at {t} threads");
            assert_eq!(
                spmm_transpose_par(&g, &h, &pool),
                want_t,
                "{name}: transpose SpMM diverged at {t} threads"
            );
        }
    }
}

// ------------------------------------------------------- tile pack/execute

#[test]
fn diff_pack_par_equals_serial() {
    check("pack_csr_batches_par == serial", 106, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 32) } else { gen::csr(rng, 32, 0.25) };
        let bm = 1usize << rng.range(0, 4);
        let bk = 1usize << rng.range(0, 4);
        let r = rng.range(1, 9);
        let nb = rng.range(1, 9);
        let want = pack_csr_batches(&a, bm, bk, r, nb);
        for &t in &THREADS {
            let got = pack_csr_batches_par(&a, bm, bk, r, nb, &Pool::new(t));
            if !batches_eq(&want, &got) {
                return Err(format!(
                    "threads={t}: pack diverged (bm={bm} bk={bk} r={r} nb={nb})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_cpu_tile_exec_matches_spmm() {
    check("CpuTileSpmm == spmm", 107, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 32) } else { gen::csr(rng, 32, 0.2) };
        let f = rng.range(1, 10);
        let h = gen::dense(rng, a.ncols, f);
        let exec = CpuTileSpmm {
            bm: 1usize << rng.range(0, 4),
            bk: 1usize << rng.range(0, 4),
            r: rng.range(1, 7),
            nb: rng.range(1, 7),
        };
        let want = spmm(&a, &h);
        for &t in &THREADS {
            let got = exec.spmm(&a, &h, &Pool::new(t));
            if got != want {
                return Err(format!(
                    "threads={t}: tile executor diverged (bm={} bk={} r={} nb={})",
                    exec.bm, exec.bk, exec.r, exec.nb
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_cpu_tile_exec_graph_families() {
    let mut rng = Pcg::seed(9);
    let exec = CpuTileSpmm { bm: 8, bk: 8, r: 4, nb: 4 };
    for (name, g) in graph_cases() {
        let h = gen::dense(&mut rng, g.ncols, 8);
        let want = spmm(&g, &h);
        for &t in &THREADS {
            assert_eq!(
                exec.spmm(&g, &h, &Pool::new(t)),
                want,
                "{name}: tile executor diverged at {t} threads"
            );
        }
    }
}

// ------------------------------------------------------- RoBW planning

#[test]
fn diff_robw_parallel_plan_equals_serial() {
    check("robw_partition_par == robw_partition", 108, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 64) } else { gen::csr(rng, 64, 0.25) };
        let budget = rng.range(1, 4096) as u64;
        let want = robw_partition(&a, budget);
        for &t in &THREADS {
            let got = robw_partition_par(&a, budget, &Pool::new(t));
            if got != want {
                return Err(format!(
                    "threads={t}: plan diverged (budget={budget}, {}x{}, nnz {})",
                    a.nrows,
                    a.ncols,
                    a.nnz()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_robw_plan_graph_families() {
    for (name, g) in graph_cases() {
        for budget in [64u64, 1024, 1 << 20] {
            let want = robw_partition(&g, budget);
            for &t in &THREADS {
                assert_eq!(
                    robw_partition_par(&g, budget, &Pool::new(t)),
                    want,
                    "{name}: plan diverged at budget {budget}, {t} threads"
                );
            }
        }
    }
}

// --------------------------------------------------- prefetch pipeline

fn random_layer(rng: &mut Pcg, f: usize) -> OocGcnLayer {
    let h = rng.range(1, 9);
    OocGcnLayer {
        w: gen::dense(rng, f, h),
        b: (0..h).map(|_| rng.normal() as f32).collect(),
        relu: rng.chance(0.5),
        seg_budget: rng.range(64, 2049) as u64,
    }
}

#[test]
fn diff_forward_cpu_prefetch_matches_serial_oracle() {
    check("forward_cpu(depth, threads) == serial forward", 109, |rng| {
        let a_hat = normalize_adjacency(&gen::adjacency(rng, 48, 0.2));
        let f = rng.range(1, 10);
        let x = gen::dense(rng, a_hat.ncols, f);
        let layer = random_layer(rng, f);

        // The serial-staging serial-pool pass is the oracle...
        let mut mem = GpuMem::new(1 << 30);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .map_err(|e| e.to_string())?;
        // ...and it must itself equal the closed-form reference.
        let closed = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, layer.relu);
        if want != closed {
            return Err("serial forward_cpu diverged from dense_affine(spmm(..))".into());
        }

        for &depth in &PREFETCH_DEPTHS {
            for &t in &PREFETCH_THREADS {
                let mut mem = GpuMem::new(1 << 30);
                let (got, rep) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &StagingConfig::depth(depth))
                    .map_err(|e| e.to_string())?;
                if got != want {
                    return Err(format!("depth={depth} threads={t}: output diverged"));
                }
                if rep.segments != base.segments || rep.h2d_bytes != base.h2d_bytes {
                    return Err(format!("depth={depth} threads={t}: plan/traffic diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn diff_forward_cpu_prefetch_graph_families() {
    let mut rng = Pcg::seed(10);
    for (name, g) in graph_cases() {
        let a_hat = normalize_adjacency(&g);
        let x = gen::dense(&mut rng, a_hat.ncols, 8);
        let layer = random_layer(&mut rng, 8);
        let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, layer.relu);
        for &depth in &PREFETCH_DEPTHS {
            for &t in &PREFETCH_THREADS {
                let mut mem = GpuMem::new(1 << 30);
                let (got, _) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &StagingConfig::depth(depth))
                    .unwrap();
                assert_eq!(got, want, "{name}: diverged at depth {depth}, {t} threads");
            }
        }
    }
}

/// The acceptance sweep on the artifact path: `forward_staged` at depth
/// {1,2,4} × threads {1,2,8} against the serial `forward` oracle. Skips
/// cleanly when the PJRT artifacts are not built (the CPU-path sweeps
/// above enforce the same pipeline in that environment).
#[test]
fn diff_forward_staged_artifacts_match_serial_forward() {
    let Some(dir) = aires::runtime::find_artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut exec = aires::runtime::Executor::new(&dir).unwrap();
    let mut rng = Pcg::seed(12);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 500, 3.0));
    let x = gen::dense(&mut rng, 500, 64);
    let layer = OocGcnLayer {
        w: gen::dense(&mut rng, 64, 64),
        b: vec![0.05; 64],
        relu: true,
        seg_budget: 4096,
    };
    let mut mem = GpuMem::new(64 << 20);
    let (want, _) = layer.forward(&mut exec, &a_hat, &x, &mut mem).unwrap();
    for &depth in &PREFETCH_DEPTHS {
        for &t in &PREFETCH_THREADS {
            let mut mem = GpuMem::new(64 << 20);
            let pool = Pool::new(t);
            let staging = StagingConfig::depth(depth);
            let (got, _) = layer
                .forward_staged(&mut exec, &a_hat, &x, &mut mem, &pool, &staging)
                .unwrap();
            assert_eq!(got, want, "artifact path diverged at depth {depth}, {t} threads");
        }
    }
}

// --------------------------------------------- disk-backed segment staging

/// Host-cache byte bounds the disk sweeps cover: no cache (every read
/// hits disk), a tiny bound (~1.5 segments: constant eviction), and
/// unbounded (everything resident after first touch).
fn cache_points(segs: &[aires::partition::robw::RobwSegment]) -> [u64; 3] {
    let max_seg = segs.iter().map(|s| s.bytes).max().unwrap_or(0);
    [0, max_seg + max_seg / 2 + 1, UNBOUNDED_CACHE]
}

#[test]
fn diff_forward_cpu_disk_backed_matches_memory_oracle() {
    // Acceptance sweep: disk-backed forward_cpu must be byte-identical to
    // the in-memory serial oracle at every (depth, threads, cache-size)
    // point, with a balanced ledger, and with *identical measured I/O*
    // across depths and thread counts (the producer reads strictly in
    // index order, so cache behaviour may not depend on pipelining).
    check("forward_cpu(disk) == forward_cpu(memory)", 110, |rng| {
        let a_hat = normalize_adjacency(&gen::adjacency(rng, 48, 0.2));
        let f = rng.range(1, 10);
        let x = gen::dense(rng, a_hat.ncols, f);
        let layer = random_layer(rng, f);

        let mut mem = GpuMem::new(1 << 30);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .map_err(|e| e.to_string())?;

        let segs = robw_partition(&a_hat, layer.seg_budget);
        let dir = TempDir::new("diff-disk");
        // Spill once; every configuration below re-opens the same files
        // with a fresh cache, so cache stats are comparable across points.
        SegmentStore::spill(&a_hat, &segs, dir.path(), 0).map_err(|e| e.to_string())?;

        for cache in cache_points(&segs) {
            let mut expect_io = None;
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    let store = SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), cache)
                        .map_err(|e| e.to_string())?;
                    let mut mem = GpuMem::new(1 << 30);
                    let (got, rep) = layer
                        .forward_cpu(
                            &a_hat,
                            &x,
                            &mut mem,
                            &Pool::new(t),
                            &StagingConfig::disk(Arc::new(store), depth),
                        )
                        .map_err(|e| format!("cache={cache} depth={depth} threads={t}: {e}"))?;
                    if got != want {
                        return Err(format!(
                            "cache={cache} depth={depth} threads={t}: output diverged"
                        ));
                    }
                    if rep.segments != base.segments || rep.h2d_bytes != base.h2d_bytes {
                        return Err(format!(
                            "cache={cache} depth={depth} threads={t}: plan/traffic diverged"
                        ));
                    }
                    if mem.used != 0 {
                        return Err(format!(
                            "cache={cache} depth={depth} threads={t}: ledger unbalanced"
                        ));
                    }
                    let io = (rep.disk_bytes, rep.cache_hits, rep.cache_misses);
                    match expect_io {
                        None => expect_io = Some(io),
                        Some(w) if w != io => {
                            return Err(format!(
                                "cache={cache} depth={depth} threads={t}: measured I/O \
                                 {io:?} != {w:?} (must not depend on pipelining)"
                            ));
                        }
                        _ => {}
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn diff_forward_cpu_disk_backed_graph_families() {
    let mut rng = Pcg::seed(13);
    for (name, g) in graph_cases() {
        let a_hat = normalize_adjacency(&g);
        let x = gen::dense(&mut rng, a_hat.ncols, 8);
        let layer = random_layer(&mut rng, 8);
        let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, layer.relu);
        let segs = robw_partition(&a_hat, layer.seg_budget);
        let dir = TempDir::new("diff-disk-family");
        SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap();
        for cache in cache_points(&segs) {
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    let store =
                        SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), cache).unwrap();
                    let mut mem = GpuMem::new(1 << 30);
                    let (got, _) = layer
                        .forward_cpu(
                            &a_hat,
                            &x,
                            &mut mem,
                            &Pool::new(t),
                            &StagingConfig::disk(Arc::new(store), depth),
                        )
                        .unwrap();
                    assert_eq!(
                        got, want,
                        "{name}: diverged at cache {cache}, depth {depth}, {t} threads"
                    );
                    assert_eq!(mem.used, 0, "{name}: ledger unbalanced");
                }
            }
        }
    }
}

#[test]
fn diff_recycled_staging_matches_fresh_at_every_point() {
    // The acceptance sweep for buffer recycling: with one BufferPool
    // shared across *all* configurations (so later runs decode into
    // buffers drained by earlier, differently-shaped runs), the recycled
    // pass must stay byte-identical to the fresh pass — and to the serial
    // in-memory oracle — at every depth x threads x cache-size point, on
    // both backings, with identical measured I/O and a balanced ledger.
    check("forward_cpu(recycled) == forward_cpu(fresh)", 112, |rng| {
        let a_hat = normalize_adjacency(&gen::adjacency(rng, 48, 0.2));
        let f = rng.range(1, 10);
        let x = gen::dense(rng, a_hat.ncols, f);
        let layer = random_layer(rng, f);

        let mut mem = GpuMem::new(1 << 30);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .map_err(|e| e.to_string())?;

        let pool_shared = Arc::new(BufferPool::new(64 << 20));
        // In-memory backing, recycled.
        for &depth in &PREFETCH_DEPTHS {
            for &t in &[1usize, 8] {
                let staging = StagingConfig::depth(depth).with_recycle(pool_shared.clone());
                let mut mem = GpuMem::new(1 << 30);
                let (got, rep) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &staging)
                    .map_err(|e| format!("mem depth={depth} threads={t}: {e}"))?;
                if got != want {
                    return Err(format!("mem recycled depth={depth} threads={t}: diverged"));
                }
                if rep.h2d_bytes != base.h2d_bytes || rep.segments != base.segments {
                    return Err(format!("mem recycled depth={depth} threads={t}: traffic"));
                }
                if mem.used != 0 {
                    return Err(format!("mem recycled depth={depth} threads={t}: ledger"));
                }
            }
        }

        // Disk backing: recycled vs fresh under every cache point.
        let segs = robw_partition(&a_hat, layer.seg_budget);
        let dir = TempDir::new("diff-recycle");
        SegmentStore::spill(&a_hat, &segs, dir.path(), 0).map_err(|e| e.to_string())?;
        for cache in cache_points(&segs) {
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    let run = |recycle: Option<Arc<BufferPool>>| {
                        let store =
                            SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), cache)
                                .map_err(|e| e.to_string())?;
                        let mut staging = StagingConfig::disk(Arc::new(store), depth);
                        if let Some(rp) = recycle {
                            staging = staging.with_recycle(rp);
                        }
                        let mut mem = GpuMem::new(1 << 30);
                        let (got, rep) = layer
                            .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &staging)
                            .map_err(|e| e.to_string())?;
                        if mem.used != 0 {
                            return Err("ledger unbalanced".to_string());
                        }
                        Ok::<_, String>((got, rep.disk_bytes, rep.cache_hits, rep.cache_misses))
                    };
                    let fresh = run(None)
                        .map_err(|e| format!("cache={cache} depth={depth} t={t} fresh: {e}"))?;
                    let rec = run(Some(pool_shared.clone()))
                        .map_err(|e| format!("cache={cache} depth={depth} t={t} rec: {e}"))?;
                    if rec != fresh {
                        return Err(format!(
                            "cache={cache} depth={depth} threads={t}: recycled != fresh \
                             (output or measured I/O)"
                        ));
                    }
                    if fresh.0 != want {
                        return Err(format!(
                            "cache={cache} depth={depth} threads={t}: disk != oracle"
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------- cross-layer pipelined model

/// The multi-layer acceptance sweep: the cross-layer pipelined forward
/// (`OocGcnModel::forward_cpu` — one prefetch pipeline spanning every
/// layer's plan, no drain at layer boundaries) must be **byte-identical**
/// to the per-layer sequential oracle (a plain loop of single-layer
/// `forward_cpu` calls) at every layers × depth × threads × backing ×
/// cache point, with a balanced ledger and measured I/O that does not
/// depend on pipelining. Panel spilling and buffer recycling ride the
/// same sweep: both must leave the output bit-for-bit unchanged.
#[test]
fn diff_multilayer_pipeline_matches_per_layer_oracle() {
    let mut rng = Pcg::seed(18);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 300, 3.0));
    let budget = 2048u64;
    let f = 8usize;
    let x = gen::dense(&mut rng, a_hat.ncols, f);
    let segs = robw_partition(&a_hat, budget);
    assert!(segs.len() >= 4, "need a real stream per layer");
    let shared_recycle = Arc::new(BufferPool::new(64 << 20));

    for n_layers in [1usize, 2, 3] {
        let model = OocGcnModel::new(
            (0..n_layers)
                .map(|_| OocGcnLayer {
                    w: gen::dense(&mut rng, f, f),
                    b: (0..f).map(|_| rng.normal() as f32).collect(),
                    relu: true,
                    seg_budget: budget,
                })
                .collect(),
        )
        .unwrap();

        // The drain-at-boundary oracle: isolated single-layer passes.
        let mut mem = GpuMem::new(1 << 30);
        let mut cur = x.clone();
        let mut base = Vec::new();
        for layer in &model.layers {
            let (out, rep) = layer
                .forward_cpu(&a_hat, &cur, &mut mem, &Pool::serial(), &StagingConfig::serial())
                .unwrap();
            base.push(rep);
            cur = out;
        }
        let want = cur;
        assert_eq!(mem.used, 0);

        // In-memory backing: depth × threads, fresh and recycled.
        for &depth in &PREFETCH_DEPTHS {
            for &t in &[1usize, 8] {
                for recycled in [false, true] {
                    let mut staging = StagingConfig::depth(depth);
                    if recycled {
                        staging = staging.with_recycle(shared_recycle.clone());
                    }
                    let cfg = PipelineConfig::staged(staging);
                    let mut mem = GpuMem::new(1 << 30);
                    let (got, rep) =
                        model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &cfg).unwrap();
                    assert_eq!(
                        got, want,
                        "layers={n_layers} depth={depth} threads={t} recycled={recycled}"
                    );
                    assert_eq!(mem.used, 0, "ledger unbalanced");
                    assert_eq!(rep.per_layer.len(), n_layers);
                    for (l, (r, b)) in rep.per_layer.iter().zip(base.iter()).enumerate() {
                        assert_eq!(r.segments, b.segments, "layer {l} plan diverged");
                        assert_eq!(r.h2d_bytes, b.h2d_bytes, "layer {l} traffic diverged");
                    }
                }
            }
        }

        // Disk backing: cache points × depth × threads, measured I/O
        // identical across pipelining configurations.
        let dir = TempDir::new("diff-mlayer");
        SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap();
        for cache in cache_points(&segs) {
            let mut expect_io: Option<Vec<(u64, usize, usize)>> = None;
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    let store =
                        SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), cache).unwrap();
                    let cfg =
                        PipelineConfig::staged(StagingConfig::disk(Arc::new(store), depth));
                    let mut mem = GpuMem::new(1 << 30);
                    let (got, rep) =
                        model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &cfg).unwrap();
                    assert_eq!(got, want, "layers={n_layers} cache={cache} depth={depth} t={t}");
                    assert_eq!(mem.used, 0);
                    let io: Vec<_> = rep
                        .per_layer
                        .iter()
                        .map(|r| (r.disk_bytes, r.cache_hits, r.cache_misses))
                        .collect();
                    match &expect_io {
                        None => expect_io = Some(io),
                        Some(w) => assert_eq!(
                            &io, w,
                            "layers={n_layers} cache={cache} depth={depth} t={t}: \
                             measured I/O must not depend on pipelining"
                        ),
                    }
                }
            }
        }

        // Panel spilling (with recycling): intermediate panels round-trip
        // through the segio dense-panel record without disturbing a bit.
        for &depth in &PREFETCH_DEPTHS {
            let pdir = TempDir::new("diff-mlayer-panel");
            let pstore = Arc::new(PanelStore::new(pdir.path(), 0).unwrap());
            let staging = StagingConfig::depth(depth).with_recycle(shared_recycle.clone());
            let cfg = PipelineConfig::staged(staging).with_panel_spill(pstore.clone());
            let mut mem = GpuMem::new(1 << 30);
            let (got, rep) =
                model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &cfg).unwrap();
            assert_eq!(got, want, "panel-spilled layers={n_layers} depth={depth}");
            assert_eq!(mem.used, 0);
            assert_eq!(pstore.len(), n_layers - 1, "every intermediate panel spills");
            assert_eq!(rep.panel_cache_hits + rep.panel_cache_misses, n_layers - 1);
            if n_layers > 1 {
                assert!(rep.panel_spill_bytes > 0);
                assert_eq!(rep.panel_read_bytes, rep.panel_spill_bytes, "cacheless reads");
            } else {
                assert_eq!(rep.panel_spill_bytes, 0);
            }
        }
    }
}

// --------------------------------------------------- streamed training

/// The out-of-core training acceptance sweep: the streamed trainer
/// (forward AND backward through one concatenated RoBW plan, gradient /
/// activation panels through the tiered store) must produce **bitwise**
/// the dense CPU oracle's loss at every step and bitwise its final
/// parameters, at every depth × threads × backing × recycle ×
/// recompute-policy point, with a balanced ledger after every step.
#[test]
fn diff_train_stream_matches_dense_oracle() {
    use aires::gcn::train_stream::{dense_step_oracle, synthetic_labels};
    use aires::gcn::{RecomputePolicy, StreamedTrainer, TrainStreamConfig};

    let mut rng = Pcg::seed(21);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 240, 3.0));
    let n = a_hat.nrows;
    let budget = 1536u64;
    let (f0, classes) = (6usize, 4usize);
    let x = gen::dense(&mut rng, n, f0);
    let widths = [f0, 8, 8, classes];
    let layers: Vec<OocGcnLayer> = (0..3)
        .map(|l| {
            let mut w = gen::dense(&mut rng, widths[l], widths[l + 1]);
            for v in w.data.iter_mut() {
                *v *= 0.3;
            }
            OocGcnLayer {
                w,
                b: (0..widths[l + 1]).map(|_| (rng.normal() * 0.1) as f32).collect(),
                relu: l < 2,
                seg_budget: budget,
            }
        })
        .collect();
    let labels = synthetic_labels(&x, classes, &mut rng);
    let steps = 3usize;
    let lr = 0.5f32;

    // Dense CPU oracle: the per-step loss curve and the final parameters.
    let mut oracle = layers.clone();
    let mut want_losses = Vec::new();
    for _ in 0..steps {
        want_losses.push(dense_step_oracle(&mut oracle, &a_hat, &x, &labels, lr).unwrap());
    }
    assert!(want_losses.iter().all(|l| l.is_finite()), "oracle curve: {want_losses:?}");
    assert_ne!(
        want_losses[0].to_bits(),
        want_losses[steps - 1].to_bits(),
        "parameters must actually move: {want_losses:?}"
    );

    let segs = robw_partition(&a_hat, budget);
    assert!(segs.len() >= 3, "need a real stream per layer");
    let dir = TempDir::new("diff-train-segs");
    SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap();
    let shared_recycle = Arc::new(BufferPool::new(64 << 20));

    let bits = |layers: &[OocGcnLayer]| -> Vec<u32> {
        layers
            .iter()
            .flat_map(|l| l.w.data.iter().chain(l.b.iter()).map(|v| v.to_bits()))
            .collect()
    };
    let want_bits = bits(&oracle);

    for policy in [RecomputePolicy::Reload, RecomputePolicy::Recompute] {
        for disk in [false, true] {
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    for recycled in [false, true] {
                        let point = format!(
                            "policy={policy:?} disk={disk} depth={depth} t={t} \
                             recycled={recycled}"
                        );
                        let mut staging = if disk {
                            let store =
                                SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), 0)
                                    .unwrap();
                            StagingConfig::disk(Arc::new(store), depth)
                        } else {
                            StagingConfig::depth(depth)
                        };
                        if recycled {
                            staging = staging.with_recycle(shared_recycle.clone());
                        }
                        // Fresh panel store per point: panels are step
                        // state, not a shared fixture.
                        let pdir = TempDir::new("diff-train-panels");
                        let panels = Arc::new(PanelStore::new(pdir.path(), 0).unwrap());
                        let cfg = TrainStreamConfig::new(staging, panels).with_policy(policy);
                        let mut tr =
                            StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
                        let mut mem = GpuMem::new(1 << 30);
                        for (s, want) in want_losses.iter().enumerate() {
                            let rep = tr
                                .step(&a_hat, &x, &mut mem, &Pool::new(t), &cfg, lr)
                                .unwrap_or_else(|e| panic!("{point} step {s}: {e}"));
                            assert_eq!(
                                rep.loss.to_bits(),
                                want.to_bits(),
                                "{point} step {s}: loss {} != oracle {want}",
                                rep.loss
                            );
                            assert_eq!(rep.policy, policy, "{point}: resolved policy");
                            assert_eq!(mem.used, 0, "{point} step {s}: ledger unbalanced");
                            match policy {
                                RecomputePolicy::Reload => assert!(
                                    rep.agg_spill_bytes > 0 && rep.agg_read_bytes > 0,
                                    "{point}: reload must round-trip aggregation panels"
                                ),
                                _ => assert_eq!(
                                    rep.agg_spill_bytes, 0,
                                    "{point}: recompute must not spill aggregations"
                                ),
                            }
                        }
                        assert_eq!(
                            bits(&tr.layers),
                            want_bits,
                            "{point}: final parameters diverged from the oracle"
                        );
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------- fault injection

/// I/O faults injected into one segment file mid-stream.
#[derive(Debug, Clone, Copy)]
enum Fault {
    /// Cut the file in half (decoder sees a short payload).
    Truncate,
    /// Flip one payload byte (checksum must catch it).
    Corrupt,
    /// Delete the file entirely.
    Remove,
}

#[test]
fn diff_injected_io_faults_fail_cleanly_at_every_depth() {
    // Extends the PR 2 abort-cleanup coverage to real I/O: a truncated,
    // corrupted, or missing segment file mid-stream must surface a clean
    // typed error from the streamed forward pass, leave the GpuMem ledger
    // balanced, and join the producer (this test returning at all proves
    // no deadlock; the ledger assert proves no leaked staging).
    let mut rng = Pcg::seed(14);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 400, 3.0));
    let x = gen::dense(&mut rng, a_hat.ncols, 8);
    let layer = OocGcnLayer {
        w: gen::dense(&mut rng, 8, 8),
        b: vec![0.1; 8],
        relu: true,
        seg_budget: 2048,
    };
    let segs = robw_partition(&a_hat, layer.seg_budget);
    assert!(segs.len() >= 4, "need a real stream to fault mid-way");
    let victim = segs.len() / 2;

    let recycle = Arc::new(BufferPool::new(64 << 20));
    for fault in [Fault::Truncate, Fault::Corrupt, Fault::Remove] {
        for &depth in &PREFETCH_DEPTHS {
            for &t in &[1usize, 8] {
                for recycled in [false, true] {
                    let dir = TempDir::new("diff-fault");
                    let store = SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap();
                    let path = store.meta(victim).path.clone();
                    match fault {
                        Fault::Truncate => {
                            let bytes = std::fs::read(&path).unwrap();
                            std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
                        }
                        Fault::Corrupt => {
                            let mut bytes = std::fs::read(&path).unwrap();
                            let last = bytes.len() - 1;
                            bytes[last] ^= 0xff;
                            std::fs::write(&path, &bytes).unwrap();
                        }
                        Fault::Remove => std::fs::remove_file(&path).unwrap(),
                    }
                    let mut staging = StagingConfig::disk(Arc::new(store), depth);
                    if recycled {
                        staging = staging.with_recycle(recycle.clone());
                    }
                    let mut mem = GpuMem::new(1 << 30);
                    let err = layer
                        .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &staging)
                        .unwrap_err();
                    let msg = err.to_string();
                    assert!(
                        msg.contains(&format!("staging segment {victim} from disk")),
                        "{fault:?} depth={depth} threads={t} recycled={recycled}: \
                         error must name the segment: {msg}"
                    );
                    let detail = match fault {
                        Fault::Truncate => "truncated",
                        Fault::Corrupt => "checksum mismatch",
                        Fault::Remove => "segment I/O",
                    };
                    assert!(
                        msg.contains(detail),
                        "{fault:?} depth={depth} threads={t} recycled={recycled}: \
                         expected {detail:?} in: {msg}"
                    );
                    assert_eq!(
                        mem.used, 0,
                        "{fault:?} depth={depth} threads={t} recycled={recycled}: \
                         ledger must balance after the fault"
                    );
                }
            }
        }
    }

    // Control: the same store contents without a fault stream cleanly —
    // the faults above, not the harness, caused the failures.
    let dir = TempDir::new("diff-fault-control");
    let store = SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap();
    let mut mem = GpuMem::new(1 << 30);
    let (got, _) = layer
        .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &StagingConfig::disk(Arc::new(store), 2))
        .unwrap();
    let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, layer.relu);
    assert_eq!(got, want);
    assert_eq!(mem.used, 0);
}

// ------------------------------------------------------- self-healing reads

#[test]
fn diff_healed_transient_faults_match_fault_free_oracle() {
    // The healed-vs-oracle acceptance sweep: with a seeded chaos plan
    // injecting transient I/O faults and a slow read into the disk-backed
    // stream (the chaos tier wraps store reads, so the disk backing is
    // the faulted surface), a retry-enabled pass must produce output
    // **byte-identical** to the fault-free oracle at every depth ×
    // threads × fresh/recycled point — same measured I/O meters, same
    // plan, balanced ledger — with *exactly* the predicted HealStats as
    // the only difference (the house determinism rule for recovery).
    use aires::runtime::{FaultKind, FaultPlan, FaultSpec, HealPolicy, HealStats, Tier};

    let mut rng = Pcg::seed(25);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 400, 3.0));
    let x = gen::dense(&mut rng, a_hat.ncols, 8);
    let layer = OocGcnLayer {
        w: gen::dense(&mut rng, 8, 8),
        b: vec![0.1; 8],
        relu: true,
        seg_budget: 2048,
    };
    let segs = robw_partition(&a_hat, layer.seg_budget);
    assert!(segs.len() >= 4, "need distinct victims in a real stream");
    let (v1, v2, v3) = (0usize, segs.len() / 2, segs.len() - 1);

    let dir = TempDir::new("diff-heal-transient");
    let store0 = SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap();
    let (fb1, fb3) = (store0.meta(v1).file_bytes, store0.meta(v3).file_bytes);

    // Fault-free oracle (cache 0: every read measured at the disk tier).
    let mut mem = GpuMem::new(1 << 30);
    let oracle_staging = StagingConfig::disk(Arc::new(store0), 1);
    let (want, base) = layer
        .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &oracle_staging)
        .unwrap();
    assert!(!base.heal.any(), "the oracle heals nothing: {:?}", base.heal);
    let base_io = (base.disk_bytes, base.cache_hits, base.cache_misses);

    let policy = HealPolicy { retry_max: 3, backoff_ios: 2, rebuild: false };
    let charge = 4096u64;
    // Exact ledger prediction: TransientIo{2} on v1 = 2 injected + 2
    // retries charging 2·fb1·(2^0 + 2^1); FailOnceThenHeal on v3 = 1
    // injected + 1 retry charging 2·fb3; SlowRead on v2 = 1 injected +
    // 1 slow read charging its flat `charge_bytes`.
    let expect = HealStats {
        injected: 4,
        retries: 3,
        slow_reads: 1,
        quarantined: 0,
        rebuilt: 0,
        backoff_bytes: 6 * fb1 + 2 * fb3 + charge,
    };

    let recycle = Arc::new(BufferPool::new(64 << 20));
    for &depth in &PREFETCH_DEPTHS {
        for &t in &[1usize, 8] {
            for recycled in [false, true] {
                let point = format!("depth={depth} threads={t} recycled={recycled}");
                // Fresh plan per run: chaos plans carry consumed fault
                // counters. Fresh store per run: comparable cache stats.
                let plan = Arc::new(FaultPlan::new(vec![
                    FaultSpec {
                        tier: Tier::Segment,
                        index: v1,
                        kind: FaultKind::TransientIo { times: 2 },
                    },
                    FaultSpec {
                        tier: Tier::Segment,
                        index: v2,
                        kind: FaultKind::SlowRead { times: 1, charge_bytes: charge },
                    },
                    FaultSpec {
                        tier: Tier::Segment,
                        index: v3,
                        kind: FaultKind::FailOnceThenHeal,
                    },
                ]));
                let store =
                    SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), 0).unwrap();
                let mut staging = StagingConfig::disk(Arc::new(store), depth)
                    .with_heal(policy)
                    .with_chaos(plan);
                if recycled {
                    staging = staging.with_recycle(recycle.clone());
                }
                let mut mem = GpuMem::new(1 << 30);
                let (got, rep) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &staging)
                    .unwrap_or_else(|e| panic!("{point}: healed pass failed: {e}"));
                assert_eq!(got, want, "{point}: healed output diverged from oracle");
                assert_eq!(rep.heal, expect, "{point}: HealStats ledger");
                assert_eq!(
                    (rep.disk_bytes, rep.cache_hits, rep.cache_misses),
                    base_io,
                    "{point}: healed measured I/O must equal the oracle's"
                );
                assert_eq!(rep.segments, base.segments, "{point}: plan diverged");
                assert_eq!(rep.h2d_bytes, base.h2d_bytes, "{point}: traffic diverged");
                assert_eq!(mem.used, 0, "{point}: ledger unbalanced");
            }
        }
    }
}

#[test]
fn diff_corruption_heals_by_quarantine_and_rebuild() {
    // Persistent single-segment corruption: a rebuild-enabled pass must
    // quarantine the poisoned file (preserving the evidence), rebuild it
    // from the source matrix + RoBW plan, and serve output byte-identical
    // to the fault-free oracle at every encoding × mmap × depth ×
    // threads × fresh/recycled point. The file is re-corrupted before
    // every run — a successful rebuild repairs the medium, and the sweep
    // must prove each configuration heals from the *corrupt* state, not
    // from a predecessor's repair. The rebuild must also re-encode in the
    // segment's *original* encoding (raw stays raw, packed stays packed).
    use aires::runtime::{HealPolicy, HealStats};
    use aires::sparse::segio::{SegEncoding, KIND_CSR, KIND_CSR_PACKED};

    let mut rng = Pcg::seed(26);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 400, 3.0));
    let x = gen::dense(&mut rng, a_hat.ncols, 8);
    let layer = OocGcnLayer {
        w: gen::dense(&mut rng, 8, 8),
        b: vec![0.1; 8],
        relu: true,
        seg_budget: 2048,
    };
    let segs = robw_partition(&a_hat, layer.seg_budget);
    assert!(segs.len() >= 4, "need a real stream to corrupt mid-way");
    let victim = segs.len() / 2;

    let policy = HealPolicy { retry_max: 1, backoff_ios: 1, rebuild: true };
    let expect = HealStats { quarantined: 1, rebuilt: 1, ..HealStats::default() };
    let recycle = Arc::new(BufferPool::new(64 << 20));
    for (enc, want_kind) in
        [(SegEncoding::Raw, KIND_CSR), (SegEncoding::Packed, KIND_CSR_PACKED)]
    {
        let dir = TempDir::new("diff-heal-rebuild");
        let store0 = SegmentStore::spill_encoded(&a_hat, &segs, dir.path(), 0, enc).unwrap();
        assert_eq!(store0.meta(victim).kind, want_kind, "spill chose the forced encoding");
        let vpath = store0.meta(victim).path.clone();
        let qpath = vpath.with_extension("bin.quarantined");
        let mut mem = GpuMem::new(1 << 30);
        let oracle_staging = StagingConfig::disk(Arc::new(store0), 1);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &oracle_staging)
            .unwrap();
        let base_io = (base.disk_bytes, base.cache_hits, base.cache_misses);

        for mmap in [false, true] {
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    for recycled in [false, true] {
                        let point = format!(
                            "enc={enc} mmap={mmap} depth={depth} threads={t} \
                             recycled={recycled}"
                        );
                        // Re-poison the (by now rebuilt) file and clear the
                        // prior run's quarantine evidence so the
                        // exists-check below is this run's, not a leftover.
                        let mut bytes = std::fs::read(&vpath).unwrap();
                        let last = bytes.len() - 1;
                        bytes[last] ^= 0xff;
                        std::fs::write(&vpath, &bytes).unwrap();
                        let _ = std::fs::remove_file(&qpath);

                        let store =
                            SegmentStore::open_or_spill_encoded(&a_hat, &segs, dir.path(), 0, enc)
                                .unwrap();
                        let mut staging = StagingConfig::disk(Arc::new(store), depth)
                            .with_heal(policy)
                            .with_mmap(mmap);
                        if recycled {
                            staging = staging.with_recycle(recycle.clone());
                        }
                        let mut mem = GpuMem::new(1 << 30);
                        let (got, rep) = layer
                            .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &staging)
                            .unwrap_or_else(|e| panic!("{point}: rebuild pass failed: {e}"));
                        assert_eq!(got, want, "{point}: rebuilt output diverged from oracle");
                        assert_eq!(rep.heal, expect, "{point}: HealStats ledger");
                        assert_eq!(
                            (rep.disk_bytes, rep.cache_hits, rep.cache_misses),
                            base_io,
                            "{point}: healed measured I/O must equal the oracle's"
                        );
                        assert_eq!(mem.used, 0, "{point}: ledger unbalanced");
                        assert!(
                            qpath.exists(),
                            "{point}: corrupt bytes must be preserved at {}",
                            qpath.display()
                        );
                        // The rebuilt record keeps the original encoding:
                        // the on-disk kind word must survive the heal.
                        let hdr = std::fs::read(&vpath).unwrap();
                        let kind = u32::from_le_bytes(hdr[12..16].try_into().unwrap());
                        assert_eq!(
                            kind, want_kind,
                            "{point}: rebuild changed the on-disk encoding"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn diff_checkpoint_resume_is_bitwise_identical() {
    // Kill/resume acceptance: a streamed training run checkpointed after
    // every step, killed after step k, and resumed by a *fresh* trainer
    // from the published checkpoint must finish with parameters and loss
    // history bitwise identical to the uninterrupted run — at every kill
    // point, on both recompute policies.
    use aires::gcn::checkpoint::{load, save};
    use aires::gcn::train_stream::synthetic_labels;
    use aires::gcn::{Checkpoint, RecomputePolicy, StreamedTrainer, TrainStreamConfig};

    let mut rng = Pcg::seed(27);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 240, 3.0));
    let n = a_hat.nrows;
    let budget = 1536u64;
    let (f0, classes) = (6usize, 4usize);
    let x = gen::dense(&mut rng, n, f0);
    let widths = [f0, 8, classes];
    let layers: Vec<OocGcnLayer> = (0..2)
        .map(|l| {
            let mut w = gen::dense(&mut rng, widths[l], widths[l + 1]);
            for v in w.data.iter_mut() {
                *v *= 0.3;
            }
            OocGcnLayer {
                w,
                b: (0..widths[l + 1]).map(|_| (rng.normal() * 0.1) as f32).collect(),
                relu: l == 0,
                seg_budget: budget,
            }
        })
        .collect();
    let labels = synthetic_labels(&x, classes, &mut rng);
    let (steps, lr) = (4usize, 0.5f32);

    let bits = |layers: &[OocGcnLayer]| -> Vec<u32> {
        layers
            .iter()
            .flat_map(|l| l.w.data.iter().chain(l.b.iter()).map(|v| v.to_bits()))
            .collect()
    };
    let run = |tr: &mut StreamedTrainer, from: usize, to: usize, ckdir: Option<&std::path::Path>| {
        let pdir = TempDir::new("diff-resume-panels");
        let panels = Arc::new(PanelStore::new(pdir.path(), 0).unwrap());
        let cfg = TrainStreamConfig::new(StagingConfig::depth(2), panels);
        let mut mem = GpuMem::new(1 << 30);
        for s in from..to {
            tr.step(&a_hat, &x, &mut mem, &Pool::new(2), &cfg, lr)
                .unwrap_or_else(|e| panic!("step {s}: {e}"));
            if let Some(dir) = ckdir {
                let ck = Checkpoint {
                    step: (s + 1) as u64,
                    policy: RecomputePolicy::Auto,
                    rng: (0, 0),
                    losses: tr.losses.clone(),
                    layers: tr.layers.clone(),
                };
                save(dir, &ck).unwrap_or_else(|e| panic!("publish step {s}: {e}"));
            }
        }
        assert_eq!(mem.used, 0, "ledger unbalanced after steps {from}..{to}");
    };

    // Uninterrupted reference run.
    let mut full = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
    run(&mut full, 0, steps, None);
    let want_bits = bits(&full.layers);
    let want_losses: Vec<u32> = full.losses.iter().map(|l| l.to_bits()).collect();

    for kill_after in 1..steps {
        let ckdir = TempDir::new("diff-resume-ck");
        // Phase 1: train to the kill point, checkpointing every step,
        // then "die" (drop the trainer).
        let mut victim = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
        run(&mut victim, 0, kill_after, Some(ckdir.path()));
        drop(victim);
        // Phase 2: a fresh process resumes from the published checkpoint.
        let ck = load(ckdir.path()).unwrap().expect("checkpoint was published");
        assert_eq!(ck.step, kill_after as u64, "checkpoint records the kill point");
        let mut resumed = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
        let done = resumed.restore(&ck).unwrap();
        assert_eq!(done, kill_after as u64);
        run(&mut resumed, kill_after, steps, Some(ckdir.path()));
        assert_eq!(
            bits(&resumed.layers),
            want_bits,
            "kill_after={kill_after}: resumed parameters diverged"
        );
        let got_losses: Vec<u32> = resumed.losses.iter().map(|l| l.to_bits()).collect();
        assert_eq!(
            got_losses, want_losses,
            "kill_after={kill_after}: resumed loss history diverged"
        );
    }
}

// ------------------------------------------------------------- edge shapes

#[test]
fn diff_more_workers_than_rows() {
    // Thread counts far beyond the row count must degrade gracefully.
    let mut rng = Pcg::seed(11);
    let a = gen::csr_with_shape(&mut rng, 3, 40, 0.4);
    let b = gen::csr_with_shape(&mut rng, 40, 5, 0.4);
    let h = gen::dense(&mut rng, 40, 6);
    let pool = Pool::new(64);
    assert_eq!(spgemm_gustavson_par(&a, &b, &pool), spgemm_gustavson(&a, &b));
    assert_eq!(spmm_par(&a, &h, &pool), spmm(&a, &h));
}

#[test]
fn diff_empty_operands() {
    let a = Csr::empty(6, 9);
    let b = Csr::empty(9, 4);
    let h = aires::sparse::spmm::Dense::zeros(9, 3);
    for &t in &THREADS {
        let pool = Pool::new(t);
        assert_eq!(spgemm_gustavson_par(&a, &b, &pool), spgemm_gustavson(&a, &b));
        assert_eq!(spmm_par(&a, &h, &pool), spmm(&a, &h));
        assert_eq!(spmm_transpose_par(&a, &aires::sparse::spmm::Dense::zeros(6, 3), &pool),
            spmm_transpose(&a, &aires::sparse::spmm::Dense::zeros(6, 3)));
    }
}

// ------------------------------------------------------- multi-tenant serve

#[test]
fn diff_multitenant_matches_solo() {
    // The fan-out serving acceptance sweep: a batch of N tenants through
    // `serve_batch` must give every tenant output byte-identical to its
    // solo `forward_cpu` pass at every tenants x depth x threads x
    // backing x recycle point, with a balanced ledger — and, on the disk
    // backing, with staged I/O charged exactly once per segment (the
    // StagingMeter counts equal ONE solo pass's, independent of N).
    check("serve_batch(N tenants) == N solo passes", 113, |rng| {
        let a_hat = normalize_adjacency(&gen::adjacency(rng, 48, 0.2));
        let budget = rng.range(64, 2049) as u64;
        let queries: Vec<TenantQuery> = (0..4)
            .map(|_| {
                let f = rng.range(1, 10);
                let mut layer = random_layer(rng, f);
                // One staged pass serves the whole batch, so every tenant
                // rides the same RoBW plan.
                layer.seg_budget = budget;
                TenantQuery { x: gen::dense(rng, a_hat.ncols, f), layer }
            })
            .collect();

        // Solo oracles: each tenant alone, serial staging, serial pool.
        let solos: Vec<_> = queries
            .iter()
            .map(|q| {
                let mut mem = GpuMem::new(1 << 30);
                q.layer
                    .forward_cpu(&a_hat, &q.x, &mut mem, &Pool::serial(), &StagingConfig::serial())
                    .map(|(out, _)| out)
                    .map_err(|e| e.to_string())
            })
            .collect::<Result<_, _>>()?;

        // Solo disk-I/O baseline (cache 0: every staged read hits disk).
        let segs = robw_partition(&a_hat, budget);
        let dir = TempDir::new("diff-serve");
        SegmentStore::spill(&a_hat, &segs, dir.path(), 0).map_err(|e| e.to_string())?;
        let solo_io = {
            let store = SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), 0)
                .map_err(|e| e.to_string())?;
            let mut mem = GpuMem::new(1 << 30);
            let (_, rep) = queries[0]
                .layer
                .forward_cpu(
                    &a_hat,
                    &queries[0].x,
                    &mut mem,
                    &Pool::serial(),
                    &StagingConfig::disk(Arc::new(store), 1),
                )
                .map_err(|e| e.to_string())?;
            (rep.disk_bytes, rep.cache_hits, rep.cache_misses)
        };

        for &nt in &[1usize, 2, 4] {
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    for &recycled in &[false, true] {
                        let point = format!("nt={nt} depth={depth} threads={t} recycled={recycled}");
                        let recycle = recycled.then(|| Arc::new(BufferPool::new(64 << 20)));
                        let verify = |results: Vec<Result<aires::sparse::spmm::Dense, _>>,
                                      rep: &aires::gcn::BatchReport,
                                      used: u64,
                                      backing: &str|
                         -> Result<(), String> {
                            if rep.tenants_admitted != nt || rep.tenants_rejected != 0 {
                                return Err(format!("{point} {backing}: admission diverged"));
                            }
                            for (k, r) in results.iter().enumerate() {
                                match r {
                                    Ok(out) if *out == solos[k] => {}
                                    Ok(_) => {
                                        return Err(format!(
                                            "{point} {backing}: tenant {k} diverged from solo"
                                        ))
                                    }
                                    Err(e) => {
                                        return Err(format!("{point} {backing}: tenant {k}: {e}"))
                                    }
                                }
                            }
                            if used != 0 {
                                return Err(format!("{point} {backing}: ledger unbalanced"));
                            }
                            Ok(())
                        };

                        // In-memory backing.
                        let mut staging = StagingConfig::depth(depth);
                        if let Some(rp) = &recycle {
                            staging = staging.with_recycle(rp.clone());
                        }
                        let mut mem = GpuMem::new(1 << 30);
                        let (results, rep) =
                            serve_batch(&a_hat, &queries[..nt], &mut mem, &Pool::new(t), &staging);
                        verify(results, &rep, mem.used, "memory")?;

                        // Disk backing, cache 0: one fresh store per run so
                        // the meter counts are comparable across points.
                        let store = SegmentStore::open_or_spill(&a_hat, &segs, dir.path(), 0)
                            .map_err(|e| e.to_string())?;
                        let mut staging = StagingConfig::disk(Arc::new(store), depth);
                        if let Some(rp) = &recycle {
                            staging = staging.with_recycle(rp.clone());
                        }
                        let mut mem = GpuMem::new(1 << 30);
                        let (results, rep) =
                            serve_batch(&a_hat, &queries[..nt], &mut mem, &Pool::new(t), &staging);
                        verify(results, &rep, mem.used, "disk")?;
                        let io = (rep.disk_bytes, rep.cache_hits, rep.cache_misses);
                        if io != solo_io {
                            return Err(format!(
                                "{point} disk: staged I/O {io:?} != one solo pass's {solo_io:?} \
                                 (must be charged once per segment, not once per tenant)"
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------- storage engine v2

/// The storage-engine-v2 acceptance sweep: with the segment files spilled
/// at every colidx encoding (raw, forced packed, per-segment auto) and
/// read both by copy-decode and by zero-copy mapping, the streamed
/// forward pass must stay **byte-identical** to the raw serial in-memory
/// oracle at every encoding × mmap × depth × threads × fresh/recycled
/// point, with a balanced ledger — and the StagingMeter must charge the
/// *encoded* file bytes (what actually moved off the medium), so packed
/// passes report measurably less disk traffic than raw ones.
#[test]
fn diff_storage_engine_v2_matches_raw_serial_oracle() {
    use aires::sparse::segio::SegEncoding;

    let mut rng = Pcg::seed(29);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 400, 3.0));
    let x = gen::dense(&mut rng, a_hat.ncols, 8);
    let layer = OocGcnLayer {
        w: gen::dense(&mut rng, 8, 8),
        b: vec![0.1; 8],
        relu: true,
        seg_budget: 2048,
    };
    let segs = robw_partition(&a_hat, layer.seg_budget);
    assert!(segs.len() >= 4, "need a real stream");

    // Raw serial in-memory pass: THE oracle every configuration pins to.
    let mut mem = GpuMem::new(1 << 30);
    let (want, base) = layer
        .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
        .unwrap();

    // In-memory backing: --mmap is a no-op (there are no files to map)
    // and must not disturb a bit.
    for &depth in &PREFETCH_DEPTHS {
        let mut mem = GpuMem::new(1 << 30);
        let (got, _) = layer
            .forward_cpu(
                &a_hat,
                &x,
                &mut mem,
                &Pool::new(2),
                &StagingConfig::depth(depth).with_mmap(true),
            )
            .unwrap();
        assert_eq!(got, want, "memory backing with mmap requested: depth={depth}");
        assert_eq!(mem.used, 0);
    }

    let recycle = Arc::new(BufferPool::new(64 << 20));
    let mut totals = std::collections::BTreeMap::new();
    for enc in [SegEncoding::Raw, SegEncoding::Packed, SegEncoding::Auto] {
        let dir = TempDir::new("diff-storage");
        let store0 = SegmentStore::spill_encoded(&a_hat, &segs, dir.path(), 0, enc).unwrap();
        let encoded_total: u64 = (0..store0.len()).map(|i| store0.meta(i).file_bytes).sum();
        totals.insert(format!("{enc}"), encoded_total);
        drop(store0);

        for mmap in [false, true] {
            for &depth in &PREFETCH_DEPTHS {
                for &t in &[1usize, 8] {
                    for recycled in [false, true] {
                        let point =
                            format!("enc={enc} mmap={mmap} depth={depth} t={t} rec={recycled}");
                        // Cache 0: every staged read is measured at the
                        // disk tier, so the meter totals are exact.
                        let store =
                            SegmentStore::open_or_spill_encoded(&a_hat, &segs, dir.path(), 0, enc)
                                .unwrap();
                        let mut staging =
                            StagingConfig::disk(Arc::new(store), depth).with_mmap(mmap);
                        if recycled {
                            staging = staging.with_recycle(recycle.clone());
                        }
                        let mut mem = GpuMem::new(1 << 30);
                        let (got, rep) = layer
                            .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &staging)
                            .unwrap_or_else(|e| panic!("{point}: {e}"));
                        assert_eq!(got, want, "{point}: output diverged from raw serial oracle");
                        assert_eq!(rep.segments, base.segments, "{point}: plan diverged");
                        assert_eq!(rep.h2d_bytes, base.h2d_bytes, "{point}: traffic diverged");
                        assert_eq!(mem.used, 0, "{point}: ledger unbalanced");
                        assert_eq!(
                            rep.disk_bytes, encoded_total,
                            "{point}: meter must charge the encoded file bytes"
                        );
                        assert_eq!(rep.cache_hits, 0, "{point}: cacheless store");
                        assert_eq!(rep.cache_misses, segs.len(), "{point}: one read per segment");
                    }
                }
            }
        }
    }

    // The encodings must actually differ on the medium: forced packing
    // shrinks this graph's colidx sections, and auto never does worse
    // than either forced choice (it takes the per-segment minimum).
    let (raw, packed, auto) = (totals["raw"], totals["packed"], totals["auto"]);
    assert!(packed < raw, "packed ({packed}) must beat raw ({raw}) on this graph");
    assert!(auto <= packed.min(raw), "auto ({auto}) must take the per-segment minimum");
}
