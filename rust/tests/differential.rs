//! Differential-testing oracle suite for the parallel execution engine.
//!
//! Contract: every parallel row-range kernel produces output **exactly
//! equal** (same structure, same f32 bits up to `==`) to its serial oracle
//! at every thread count in {1, 2, 4, 8} — determinism comes from fixed
//! row-range partitioning plus ordered merges, never atomics-ordered
//! accumulation, so equality is structural, not statistical.
//!
//! Operands come from three sources: random CSR/CSC via `testing::gen`
//! (density-floored so properties cannot pass vacuously), pathological
//! shapes (empty rows, hub row, 1×N, N×1), and the graphgen families the
//! paper's datasets map to (rmat, road, kmer adjacencies).
//!
//! Beyond the kernels, the same contract covers the *planning* and
//! *streaming* layers: `robw_partition_par` must emit the exact serial
//! plan, and the `runtime::prefetch` pipeline (`OocGcnLayer::forward_cpu`
//! / `forward_staged`) must produce byte-identical layer output at every
//! prefetch depth × thread count combination.
//!
//! Case count per property: `AIRES_PROP_CASES` (default 64).

use aires::gcn::model::dense_affine;
use aires::gcn::{OocGcnLayer, StagingConfig};
use aires::memsim::GpuMem;
use aires::partition::robw::{robw_partition, robw_partition_par};
use aires::runtime::pool::Pool;
use aires::runtime::tile_exec::CpuTileSpmm;
use aires::sparse::block::{pack_csr_batches, pack_csr_batches_par, SpmmBatch};
use aires::sparse::norm::normalize_adjacency;
use aires::sparse::spgemm::{spgemm_gustavson, spgemm_gustavson_par};
use aires::sparse::spmm::{spmm, spmm_par, spmm_transpose, spmm_transpose_par};
use aires::sparse::Csr;
use aires::testing::{check, gen};
use aires::util::rng::Pcg;

const THREADS: [usize; 4] = [1, 2, 4, 8];
/// Prefetch-pipeline sweep: depth {1,2,4} × threads {1,2,8}.
const PREFETCH_DEPTHS: [usize; 3] = [1, 2, 4];
const PREFETCH_THREADS: [usize; 3] = [1, 2, 8];

fn batches_eq(a: &[SpmmBatch], b: &[SpmmBatch]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b.iter()).all(|(x, y)| {
            x.slot_block_row == y.slot_block_row
                && x.nblk == y.nblk
                && x.colidx == y.colidx
                && x.blocks == y.blocks
        })
}

/// The paper-family graphs at test scale (square adjacencies).
fn graph_cases() -> Vec<(&'static str, Csr)> {
    let mut rng = Pcg::seed(7);
    vec![
        ("rmat-9", aires::graphgen::rmat::generate(&mut rng, 9, 8, Default::default())),
        ("road-500", aires::graphgen::road::generate(&mut rng, 500)),
        ("kmer-600", aires::graphgen::kmer::generate(&mut rng, 600, 3.2)),
    ]
}

// ------------------------------------------------------------------ SpGEMM

#[test]
fn diff_spgemm_par_random_operands() {
    check("spgemm_gustavson_par == oracle (random)", 101, |rng| {
        let a = gen::csr(rng, 40, 0.35);
        let n = rng.range(1, 41);
        let b = gen::csr_with_shape(rng, a.ncols, n, 0.35);
        let want = spgemm_gustavson(&a, &b);
        for &t in &THREADS {
            let got = spgemm_gustavson_par(&a, &b, &Pool::new(t));
            got.validate()?;
            if got != want {
                return Err(format!("threads={t}: parallel SpGEMM diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spgemm_par_pathological_operands() {
    check("spgemm_gustavson_par == oracle (pathological)", 102, |rng| {
        let a = gen::pathological(rng, 24);
        let n = rng.range(1, 25);
        let b = gen::csr_with_shape(rng, a.ncols, n, 0.3);
        let want = spgemm_gustavson(&a, &b);
        for &t in &THREADS {
            if spgemm_gustavson_par(&a, &b, &Pool::new(t)) != want {
                return Err(format!(
                    "threads={t}: diverged on pathological {}x{} (nnz {})",
                    a.nrows,
                    a.ncols,
                    a.nnz()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spgemm_par_graph_families() {
    for (name, g) in graph_cases() {
        let want = spgemm_gustavson(&g, &g);
        for &t in &THREADS {
            let got = spgemm_gustavson_par(&g, &g, &Pool::new(t));
            assert_eq!(got, want, "{name}: A*A diverged at {t} threads");
        }
    }
}

// -------------------------------------------------------------------- SpMM

#[test]
fn diff_spmm_par_random_operands() {
    check("spmm_par == oracle (random)", 103, |rng| {
        let a = gen::csr(rng, 40, 0.3);
        let f = rng.range(1, 12);
        let h = gen::dense(rng, a.ncols, f);
        let want = spmm(&a, &h);
        for &t in &THREADS {
            if spmm_par(&a, &h, &Pool::new(t)) != want {
                return Err(format!("threads={t}: parallel SpMM diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spmm_par_pathological_operands() {
    check("spmm_par == oracle (pathological)", 104, |rng| {
        let a = gen::pathological(rng, 24);
        let f = rng.range(1, 12);
        let h = gen::dense(rng, a.ncols, f);
        let want = spmm(&a, &h);
        for &t in &THREADS {
            if spmm_par(&a, &h, &Pool::new(t)) != want {
                return Err(format!("threads={t}: diverged on {}x{}", a.nrows, a.ncols));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spmm_transpose_par_random_operands() {
    check("spmm_transpose_par == oracle", 105, |rng| {
        let a = gen::csr(rng, 40, 0.3);
        let f = rng.range(1, 12);
        let h = gen::dense(rng, a.nrows, f);
        let want = spmm_transpose(&a, &h);
        for &t in &THREADS {
            if spmm_transpose_par(&a, &h, &Pool::new(t)) != want {
                return Err(format!("threads={t}: parallel transpose SpMM diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_spmm_par_graph_families() {
    let mut rng = Pcg::seed(8);
    for (name, g) in graph_cases() {
        let h = gen::dense(&mut rng, g.ncols, 16);
        let want = spmm(&g, &h);
        let want_t = spmm_transpose(&g, &h);
        for &t in &THREADS {
            let pool = Pool::new(t);
            assert_eq!(spmm_par(&g, &h, &pool), want, "{name}: SpMM diverged at {t} threads");
            assert_eq!(
                spmm_transpose_par(&g, &h, &pool),
                want_t,
                "{name}: transpose SpMM diverged at {t} threads"
            );
        }
    }
}

// ------------------------------------------------------- tile pack/execute

#[test]
fn diff_pack_par_equals_serial() {
    check("pack_csr_batches_par == serial", 106, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 32) } else { gen::csr(rng, 32, 0.25) };
        let bm = 1usize << rng.range(0, 4);
        let bk = 1usize << rng.range(0, 4);
        let r = rng.range(1, 9);
        let nb = rng.range(1, 9);
        let want = pack_csr_batches(&a, bm, bk, r, nb);
        for &t in &THREADS {
            let got = pack_csr_batches_par(&a, bm, bk, r, nb, &Pool::new(t));
            if !batches_eq(&want, &got) {
                return Err(format!(
                    "threads={t}: pack diverged (bm={bm} bk={bk} r={r} nb={nb})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_cpu_tile_exec_matches_spmm() {
    check("CpuTileSpmm == spmm", 107, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 32) } else { gen::csr(rng, 32, 0.2) };
        let f = rng.range(1, 10);
        let h = gen::dense(rng, a.ncols, f);
        let exec = CpuTileSpmm {
            bm: 1usize << rng.range(0, 4),
            bk: 1usize << rng.range(0, 4),
            r: rng.range(1, 7),
            nb: rng.range(1, 7),
        };
        let want = spmm(&a, &h);
        for &t in &THREADS {
            let got = exec.spmm(&a, &h, &Pool::new(t));
            if got != want {
                return Err(format!(
                    "threads={t}: tile executor diverged (bm={} bk={} r={} nb={})",
                    exec.bm, exec.bk, exec.r, exec.nb
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_cpu_tile_exec_graph_families() {
    let mut rng = Pcg::seed(9);
    let exec = CpuTileSpmm { bm: 8, bk: 8, r: 4, nb: 4 };
    for (name, g) in graph_cases() {
        let h = gen::dense(&mut rng, g.ncols, 8);
        let want = spmm(&g, &h);
        for &t in &THREADS {
            assert_eq!(
                exec.spmm(&g, &h, &Pool::new(t)),
                want,
                "{name}: tile executor diverged at {t} threads"
            );
        }
    }
}

// ------------------------------------------------------- RoBW planning

#[test]
fn diff_robw_parallel_plan_equals_serial() {
    check("robw_partition_par == robw_partition", 108, |rng| {
        let a = if rng.chance(0.3) { gen::pathological(rng, 64) } else { gen::csr(rng, 64, 0.25) };
        let budget = rng.range(1, 4096) as u64;
        let want = robw_partition(&a, budget);
        for &t in &THREADS {
            let got = robw_partition_par(&a, budget, &Pool::new(t));
            if got != want {
                return Err(format!(
                    "threads={t}: plan diverged (budget={budget}, {}x{}, nnz {})",
                    a.nrows,
                    a.ncols,
                    a.nnz()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn diff_robw_plan_graph_families() {
    for (name, g) in graph_cases() {
        for budget in [64u64, 1024, 1 << 20] {
            let want = robw_partition(&g, budget);
            for &t in &THREADS {
                assert_eq!(
                    robw_partition_par(&g, budget, &Pool::new(t)),
                    want,
                    "{name}: plan diverged at budget {budget}, {t} threads"
                );
            }
        }
    }
}

// --------------------------------------------------- prefetch pipeline

fn random_layer(rng: &mut Pcg, f: usize) -> OocGcnLayer {
    let h = rng.range(1, 9);
    OocGcnLayer {
        w: gen::dense(rng, f, h),
        b: (0..h).map(|_| rng.normal() as f32).collect(),
        relu: rng.chance(0.5),
        seg_budget: rng.range(64, 2049) as u64,
    }
}

#[test]
fn diff_forward_cpu_prefetch_matches_serial_oracle() {
    check("forward_cpu(depth, threads) == serial forward", 109, |rng| {
        let a_hat = normalize_adjacency(&gen::adjacency(rng, 48, 0.2));
        let f = rng.range(1, 10);
        let x = gen::dense(rng, a_hat.ncols, f);
        let layer = random_layer(rng, f);

        // The serial-staging serial-pool pass is the oracle...
        let mut mem = GpuMem::new(1 << 30);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .map_err(|e| e.to_string())?;
        // ...and it must itself equal the closed-form reference.
        let closed = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, layer.relu);
        if want != closed {
            return Err("serial forward_cpu diverged from dense_affine(spmm(..))".into());
        }

        for &depth in &PREFETCH_DEPTHS {
            for &t in &PREFETCH_THREADS {
                let mut mem = GpuMem::new(1 << 30);
                let (got, rep) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &StagingConfig::depth(depth))
                    .map_err(|e| e.to_string())?;
                if got != want {
                    return Err(format!("depth={depth} threads={t}: output diverged"));
                }
                if rep.segments != base.segments || rep.h2d_bytes != base.h2d_bytes {
                    return Err(format!("depth={depth} threads={t}: plan/traffic diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn diff_forward_cpu_prefetch_graph_families() {
    let mut rng = Pcg::seed(10);
    for (name, g) in graph_cases() {
        let a_hat = normalize_adjacency(&g);
        let x = gen::dense(&mut rng, a_hat.ncols, 8);
        let layer = random_layer(&mut rng, 8);
        let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, layer.relu);
        for &depth in &PREFETCH_DEPTHS {
            for &t in &PREFETCH_THREADS {
                let mut mem = GpuMem::new(1 << 30);
                let (got, _) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &Pool::new(t), &StagingConfig::depth(depth))
                    .unwrap();
                assert_eq!(got, want, "{name}: diverged at depth {depth}, {t} threads");
            }
        }
    }
}

/// The acceptance sweep on the artifact path: `forward_staged` at depth
/// {1,2,4} × threads {1,2,8} against the serial `forward` oracle. Skips
/// cleanly when the PJRT artifacts are not built (the CPU-path sweeps
/// above enforce the same pipeline in that environment).
#[test]
fn diff_forward_staged_artifacts_match_serial_forward() {
    let Some(dir) = aires::runtime::find_artifact_dir() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let mut exec = aires::runtime::Executor::new(&dir).unwrap();
    let mut rng = Pcg::seed(12);
    let a_hat = normalize_adjacency(&aires::graphgen::kmer::generate(&mut rng, 500, 3.0));
    let x = gen::dense(&mut rng, 500, 64);
    let layer = OocGcnLayer {
        w: gen::dense(&mut rng, 64, 64),
        b: vec![0.05; 64],
        relu: true,
        seg_budget: 4096,
    };
    let mut mem = GpuMem::new(64 << 20);
    let (want, _) = layer.forward(&mut exec, &a_hat, &x, &mut mem).unwrap();
    for &depth in &PREFETCH_DEPTHS {
        for &t in &PREFETCH_THREADS {
            let mut mem = GpuMem::new(64 << 20);
            let pool = Pool::new(t);
            let staging = StagingConfig::depth(depth);
            let (got, _) = layer
                .forward_staged(&mut exec, &a_hat, &x, &mut mem, &pool, &staging)
                .unwrap();
            assert_eq!(got, want, "artifact path diverged at depth {depth}, {t} threads");
        }
    }
}

// ------------------------------------------------------------- edge shapes

#[test]
fn diff_more_workers_than_rows() {
    // Thread counts far beyond the row count must degrade gracefully.
    let mut rng = Pcg::seed(11);
    let a = gen::csr_with_shape(&mut rng, 3, 40, 0.4);
    let b = gen::csr_with_shape(&mut rng, 40, 5, 0.4);
    let h = gen::dense(&mut rng, 40, 6);
    let pool = Pool::new(64);
    assert_eq!(spgemm_gustavson_par(&a, &b, &pool), spgemm_gustavson(&a, &b));
    assert_eq!(spmm_par(&a, &h, &pool), spmm(&a, &h));
}

#[test]
fn diff_empty_operands() {
    let a = Csr::empty(6, 9);
    let b = Csr::empty(9, 4);
    let h = aires::sparse::spmm::Dense::zeros(9, 3);
    for &t in &THREADS {
        let pool = Pool::new(t);
        assert_eq!(spgemm_gustavson_par(&a, &b, &pool), spgemm_gustavson(&a, &b));
        assert_eq!(spmm_par(&a, &h, &pool), spmm(&a, &h));
        assert_eq!(spmm_transpose_par(&a, &aires::sparse::spmm::Dense::zeros(6, 3), &pool),
            spmm_transpose(&a, &aires::sparse::spmm::Dense::zeros(6, 3)));
    }
}
