//! Minimal in-tree shim of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so we vendor exactly the
//! surface this repository uses: [`Error`], [`Result`], the [`anyhow!`] and
//! [`bail!`] macros, and the [`Context`] extension trait. Errors are plain
//! strings — no backtraces, no downcasting — which is all the callers need
//! (every error here is formatted for a human and propagated with `?`).

use std::fmt;

/// String-backed error value (the shim's stand-in for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent and
// lets `?` convert any standard error into an `Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a failing `Result`/`Option` (subset of anyhow's trait).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// `anyhow!("fmt", args...)` — build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// `bail!("fmt", args...)` — early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broken {}", 7);
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broken 7");
        assert_eq!(format!("{e:?}"), "broken 7");
        let e2 = anyhow!("plain");
        assert_eq!(e2.to_string(), "plain");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.with_context(|| "while formatting").unwrap_err();
        assert!(e.to_string().starts_with("while formatting: "));
        let n: Option<u32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }
}
