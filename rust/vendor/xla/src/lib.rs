//! In-tree stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The offline build environment carries no XLA runtime, so this crate
//! provides the exact type surface `aires::runtime::executor` compiles
//! against — `PjRtClient`, `HloModuleProto`, `XlaComputation`,
//! `PjRtLoadedExecutable`, `PjRtBuffer`, `Literal` — with every operation
//! that would need the real backend failing at *runtime* with a clear
//! message. Because client construction itself fails, no artifact path is
//! ever half-executed: `Executor::new` errors out up front and the
//! artifact-dependent tests/benches skip (there is no `manifest.json`
//! without `make artifacts` anyway). Swapping this path dependency for the
//! real `xla-rs` crate re-enables the PJRT path with no source changes.

use std::borrow::Borrow;
use std::fmt;

/// Stub error type (mirrors `xla::Error` in formatting contexts).
#[derive(Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend unavailable (in-tree stub; link the real xla-rs crate to execute artifacts)"
    ))
}

/// Element dtypes the stub can carry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElemType {
    F32,
    S32,
}

/// Host types storable in a [`Literal`].
pub trait NativeType: Copy + Sized {
    fn literal_from(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn literal_from(data: &[Self]) -> Literal {
        Literal { elem: ElemType::F32, dims: vec![data.len() as i64], f32s: data.to_vec(), i32s: Vec::new() }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        if lit.elem == ElemType::F32 { Ok(lit.f32s.clone()) } else { Err(unavailable("Literal::to_vec<f32> on s32 literal")) }
    }
}

impl NativeType for i32 {
    fn literal_from(data: &[Self]) -> Literal {
        Literal { elem: ElemType::S32, dims: vec![data.len() as i64], f32s: Vec::new(), i32s: data.to_vec() }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>> {
        if lit.elem == ElemType::S32 { Ok(lit.i32s.clone()) } else { Err(unavailable("Literal::to_vec<i32> on f32 literal")) }
    }
}

/// Host-side tensor literal. Construction and reshape work (they are pure
/// host bookkeeping); tuple decomposition only ever applies to execution
/// results, which the stub cannot produce.
#[derive(Debug, Clone)]
pub struct Literal {
    elem: ElemType,
    dims: Vec<i64>,
    f32s: Vec<f32>,
    i32s: Vec<i32>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_from(data)
    }

    pub fn element_count(&self) -> usize {
        self.f32s.len().max(self.i32s.len())
    }

    /// Reshape to `dims` (empty = rank-0 scalar). Element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = if dims.is_empty() { 1 } else { dims.iter().product() };
        if want < 0 || want as usize != self.element_count() {
            return Err(Error(format!(
                "reshape: cannot view {} elements as {:?}",
                self.element_count(),
                dims
            )));
        }
        let mut out = self.clone();
        out.dims = dims.to_vec();
        Ok(out)
    }

    /// Decompose a tuple literal (execution results only — stub fails).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    /// Copy out as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }
}

/// Parsed HLO module (stub: parsing requires the real bindings).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// PJRT client handle (stub: construction fails — no backend).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_roundtrip_on_host() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(lit.reshape(&[7]).is_err());
        let scalar = Literal::vec1(&[42i32]).reshape(&[]).unwrap();
        assert_eq!(scalar.to_vec::<i32>().unwrap(), vec![42]);
        assert!(scalar.to_vec::<f32>().is_err());
    }

    #[test]
    fn backend_entry_points_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let msg = format!("{:?}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("stub"), "{msg}");
    }
}
