//! Minimal read-only memory-mapping shim, vendored in-tree (no crates.io).
//!
//! The only export is [`Mmap`]: map a whole file `PROT_READ`/`MAP_PRIVATE`
//! and hand out its bytes as a `&[u8]`. On unix this is a thin FFI
//! binding to `mmap(2)`/`munmap(2)` declared here directly (no `libc`
//! crate); elsewhere — and for zero-length files, which `mmap(2)`
//! rejects — it degrades to reading the file into an owned buffer, so
//! callers never need a platform branch.
//!
//! The mapping is private and read-only, so sharing across threads is
//! sound; concurrent *writes to the underlying file* by other processes
//! are outside the contract (the segment store never rewrites a live
//! file in place — rebuilds go through a rename).

#[cfg(unix)]
mod sys {
    use std::ffi::{c_int, c_long, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            length: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: c_long,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, length: usize) -> c_int;
    }
}

/// A read-only mapping (or owned copy, on the fallback paths) of one
/// file's contents.
#[derive(Debug)]
pub struct Mmap {
    backing: Backing,
}

#[derive(Debug)]
enum Backing {
    /// A live `mmap(2)` region, unmapped on drop.
    #[cfg(unix)]
    Mapped { ptr: *mut u8, len: usize },
    /// Owned bytes: zero-length files and non-unix platforms.
    Owned(Vec<u8>),
}

// SAFETY: the region is PROT_READ/MAP_PRIVATE — immutable for the life
// of the value — and the raw pointer is never handed out mutably.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only. Zero-length files (and non-unix builds)
    /// fall back to an owned read; the caller sees no difference.
    pub fn map(path: &std::path::Path) -> std::io::Result<Mmap> {
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        let len = usize::try_from(len)
            .map_err(|_| std::io::Error::new(std::io::ErrorKind::OutOfMemory, "file too large"))?;
        if len == 0 {
            return Ok(Mmap { backing: Backing::Owned(Vec::new()) });
        }
        Self::map_file(&file, len)
    }

    #[cfg(unix)]
    fn map_file(file: &std::fs::File, len: usize) -> std::io::Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1; a null return would be equally unusable.
        if ptr as isize == -1 || ptr.is_null() {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Mmap { backing: Backing::Mapped { ptr: ptr as *mut u8, len } })
    }

    #[cfg(not(unix))]
    fn map_file(file: &std::fs::File, len: usize) -> std::io::Result<Mmap> {
        use std::io::Read;
        let mut buf = Vec::with_capacity(len);
        let mut f = file;
        f.read_to_end(&mut buf)?;
        Ok(Mmap { backing: Backing::Owned(buf) })
    }

    /// The mapped (or owned) file contents.
    pub fn as_slice(&self) -> &[u8] {
        match &self.backing {
            #[cfg(unix)]
            // SAFETY: ptr/len come from a successful mmap of exactly
            // `len` bytes, live until Drop, and are never mutated.
            Backing::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            Backing::Owned(v) => v,
        }
    }

    /// Length of the mapping in bytes.
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    /// Whether the mapping is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = self.backing {
            // SAFETY: maps 1:1 with the successful mmap in map_file; a
            // failed munmap leaks the region, which is the only safe
            // response in a destructor.
            unsafe {
                let _ = sys::munmap(ptr as *mut std::ffi::c_void, len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::Mmap;

    #[test]
    fn maps_file_contents_exactly() {
        let dir = std::env::temp_dir().join(format!("mmap-shim-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("case.bin");
        let payload: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        std::fs::write(&path, &payload).unwrap();
        let m = Mmap::map(&path).unwrap();
        assert_eq!(m.as_slice(), &payload[..]);
        assert_eq!(m.len(), payload.len());

        let empty = dir.join("empty.bin");
        std::fs::write(&empty, b"").unwrap();
        let m = Mmap::map(&empty).unwrap();
        assert!(m.is_empty());

        assert!(Mmap::map(&dir.join("missing.bin")).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
