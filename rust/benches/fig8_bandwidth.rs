//! Bench: regenerates paper Fig. 8 (GPU/CPU-SSD achieved bandwidth: AIRES's
//! GDS direct path vs the baselines' host-mediated NVMe path).
//!
//! Run: `cargo bench --bench fig8_bandwidth`

use aires::coordinator::{fig8_bandwidth, report::fig8_md};
use aires::memsim::CostModel;

fn main() {
    let cm = CostModel::default();
    println!("== Fig. 8: storage-path bandwidth ==\n");
    let rows = fig8_bandwidth(&cm);
    print!("{}", fig8_md(&rows));
    println!("\npaper: AIRES sustains GPU-SSD (GDS) bandwidth on every dataset while the");
    println!("baselines only exercise the CPU-SSD path through the PCIe bounce buffer.");

    for r in &rows {
        if r.scheduler == "AIRES" {
            assert!(r.gpu_ssd_gbps > 0.0, "{}: AIRES GDS bandwidth missing", r.dataset);
        }
    }
    // AIRES moves more total storage traffic per epoch at HIGHER achieved
    // utilization of the NVMe (the dual-way point).
    let aires_util: f64 = rows
        .iter()
        .filter(|r| r.scheduler == "AIRES")
        .map(|r| r.gpu_ssd_gbps / cm.gds_read_gbps)
        .sum::<f64>()
        / 7.0;
    println!("\nmean AIRES GDS utilization: {:.0}%", aires_util * 100.0);
}
