//! Bench: regenerates paper Fig. 3 (merging overhead of non-aligned
//! segmentation) and times the real partitioners it is built on.
//!
//! Run: `cargo bench --bench fig3_merging`

use aires::benchlib::bench;
use aires::coordinator::{fig3_merging, report::fig3_md};
use aires::memsim::CostModel;
use aires::partition::naive::naive_partition;
use aires::partition::robw::robw_partition;
use aires::util::rng::Pcg;

fn main() {
    let cm = CostModel::default();
    println!("== Fig. 3: merging overhead (naive segmentation) ==\n");
    print!("{}", fig3_md(&fig3_merging(&cm)));
    println!("\npaper: kV2a ~50% of compute latency, ~6x the overhead of kP1a;");
    println!("RoBW alignment removes the merge round-trip entirely.\n");

    // Micro: the partitioners themselves on a scaled kmer graph.
    let mut rng = Pcg::seed(33);
    let g = aires::graphgen::kmer::generate(&mut rng, 200_000, 3.4);
    let bytes = g.size_bytes();
    println!("partitioner micro-bench on {} CSR:", aires::util::human_bytes(bytes));
    let r = bench("robw_partition(200k nodes)", 2, 10, || {
        std::hint::black_box(robw_partition(&g, 1 << 20));
    });
    aires::benchlib::report_throughput(&r, bytes);
    let r = bench("naive_partition(200k nodes)", 2, 10, || {
        std::hint::black_box(naive_partition(&g, 1 << 20));
    });
    aires::benchlib::report_throughput(&r, bytes);
}
