//! Bench: regenerates paper Fig. 7 (GPU-CPU I/O breakdown by memcpy kind:
//! data moved and latency per scheduler per dataset).
//!
//! Run: `cargo bench --bench fig7_io_breakdown`

use aires::coordinator::{fig7_io_breakdown, report::fig7_md};
use aires::memsim::CostModel;
use aires::util::human_bytes;

fn main() {
    let cm = CostModel::default();
    println!("== Fig. 7: GPU-CPU I/O breakdown ==\n");
    let rows = fig7_io_breakdown(&cm);
    print!("{}", fig7_md(&rows));

    // The paper's headline for this figure: kA2a traffic reduction vs
    // MaxMemory (30.4 GB -> 4.83 GB, -84.2%).
    let total = |ds: &str, sched: &str| {
        rows.iter()
            .find(|r| r.dataset == ds && r.scheduler == sched)
            .map(|r| r.htod_bytes + r.dtoh_bytes + r.um_bytes)
            .unwrap_or(0)
    };
    let mm = total("kA2a", "MaxMemory");
    let aires_b = total("kA2a", "AIRES");
    println!(
        "\nkA2a: MaxMemory {} vs AIRES {} => {:.1}% reduction (paper: 30.4 GB -> 4.83 GB, 84.2%)",
        human_bytes(mm),
        human_bytes(aires_b),
        100.0 * (1.0 - aires_b as f64 / mm as f64)
    );
    assert!(aires_b * 3 < mm, "AIRES must move far less GPU-CPU data");
}
