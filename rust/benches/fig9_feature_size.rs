//! Bench: regenerates paper Fig. 9 (per-epoch latency vs GCN feature size,
//! 16..256) for a representative kmer dataset and for socLJ1.
//!
//! Run: `cargo bench --bench fig9_feature_size`

use aires::coordinator::{fig9_feature_size, report::fig9_md};
use aires::memsim::CostModel;

fn main() {
    let cm = CostModel::default();
    println!("== Fig. 9: feature-size ablation ==\n");
    for ds in ["kP1a", "socLJ1"] {
        let rows = fig9_feature_size(&cm, ds);
        print!("{}", fig9_md(&rows));
        // AIRES fastest at every feature size (the paper's claim).
        for r in &rows {
            let aires_t = r
                .results
                .iter()
                .find(|x| x.scheduler == "AIRES")
                .and_then(|x| x.makespan_s)
                .unwrap();
            for x in &r.results {
                if let Some(t) = x.makespan_s {
                    assert!(t >= aires_t, "{} f={}: {} beat AIRES", ds, r.feat_dim, x.scheduler);
                }
            }
        }
        println!();
    }
    println!("paper: consistent AIRES speedup across feature sizes 16-256.");
}
