//! Bench: regenerates paper Fig. 6 (end-to-end per-epoch latency and AIRES
//! speedups across all Table II datasets x all four schedulers).
//!
//! Run: `cargo bench --bench fig6_e2e`

use aires::benchlib::bench;
use aires::coordinator::{fig6_speedup, mean_speedup, report::fig6_md};
use aires::memsim::CostModel;

fn main() {
    let cm = CostModel::default();
    println!("== Fig. 6: end-to-end per-epoch latency ==\n");
    let rows = fig6_speedup(&cm);
    print!("{}", fig6_md(&rows));
    println!(
        "paper: 1.8x / 1.7x / 1.5x average over MaxMemory / UCG / ETC; \"up to 1.8x\" peak.\n"
    );
    // Shape assertions, loud in bench output.
    assert!(mean_speedup(&rows, "MaxMemory") > mean_speedup(&rows, "UCG"));
    assert!(mean_speedup(&rows, "UCG") > mean_speedup(&rows, "ETC"));
    println!("ordering MaxMemory > UCG > ETC > AIRES: OK\n");

    // Simulator cost: a full 7x4 sweep per iteration.
    bench("fig6 full sweep (7 datasets x 4 schedulers)", 1, 10, || {
        std::hint::black_box(fig6_speedup(&cm));
    });
}
