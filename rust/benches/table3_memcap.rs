//! Bench: regenerates paper Table III (impact of GPU memory constraints;
//! '-' marks OOM) plus the AIRES-ablation rows DESIGN.md calls out.
//!
//! Run: `cargo bench --bench table3_memcap`

use aires::coordinator::{ablation_row, report::table3_md, table3_memcap};
use aires::memsim::CostModel;

fn main() {
    let cm = CostModel::default();
    println!("== Table III: memory-constraint ablation ==\n");
    let rows = table3_memcap(&cm);
    print!("{}", table3_md(&rows));
    println!("\npaper pattern: baselines OOM one level down, ETC two levels, AIRES never;");
    println!("AIRES latency degrades only a few percent per level (paper 4.95/5.01/5.05 s).\n");

    // Feature ablations (design-choice benches from DESIGN.md).
    println!("== AIRES feature ablations (kP1a) ==\n");
    let d = aires::graphgen::catalog::by_name("kP1a").unwrap();
    for (name, t) in ablation_row(d, &cm) {
        println!(
            "{:<32} {}",
            name,
            t.map_or("OOM".into(), |s| format!("{s:.2} s"))
        );
    }
}
