//! Micro-benchmarks of the hot paths (§Perf in EXPERIMENTS.md):
//! RoBW partitioning, BSR extraction + batch packing, SpGEMM oracle,
//! the simulator event loop, the PJRT artifact call path, and the
//! streaming pipeline (prefetch overlap, disk staging, buffer recycling,
//! and the cross-layer multi-layer pipeline vs its drain-at-boundary
//! oracle — ns/layer + allocs/segment).
//!
//! Run: `cargo bench --bench micro_hotpath`
//!
//! Fast mode (`AIRES_BENCH_FAST=1`) runs only the streaming section on a
//! smaller graph — the CI bench-smoke configuration. The streaming
//! section **self-checks**: every benched configuration's output is
//! asserted byte-identical to the in-memory serial oracle (and recycled
//! against fresh), so a perf run can never silently diverge; it then
//! emits `BENCH_streaming.json` (ns/segment + allocations/segment for
//! the recycled vs fresh disk paths, ns/segment + bytes/segment for the
//! raw vs packed segment stores, the serve open-loop latency
//! percentiles, the streamed-training `ns_per_step`, and — outside fast
//! mode — the `rmat_large` 2^21-node scenario) to `AIRES_BENCH_JSON` or
//! ./BENCH_streaming.json. Feed the
//! emission into the perf-trajectory store with `aires bench ingest`
//! and gate regressions with `aires bench gate` (see `src/benchdb/`).

use aires::benchlib::{allocation_count, bench, report_speedup, report_throughput, result_json};
use aires::gcn::{
    serve_batch, serve_open_loop, OocGcnLayer, OocGcnModel, OpenLoopConfig, PipelineConfig,
    StagingConfig, TenantQuery,
};
use aires::memsim::{CostModel, GpuMem, Op, Sim};
use aires::partition::robw::{robw_partition, robw_partition_par};
use aires::runtime::pool::Pool;
use aires::runtime::prefetch::Prefetch;
use aires::runtime::recycle::BufferPool;
use aires::sparse::block::{pack_artifact_batches, pack_csr_batches_par, Bsr};
use aires::sparse::spgemm::{spgemm_gustavson, spgemm_gustavson_par};
use aires::sparse::spmm::{spmm, spmm_par, Dense};
use aires::util::json::Json;
use aires::util::rng::Pcg;
use std::collections::BTreeMap;
use std::sync::Arc;

/// Count heap allocations across the whole bench so the streaming section
/// can report allocations/segment for the recycled vs fresh paths.
#[global_allocator]
static COUNTING: aires::benchlib::CountingAlloc = aires::benchlib::CountingAlloc;

fn main() {
    let fast = std::env::var("AIRES_BENCH_FAST").map(|v| v != "0").unwrap_or(false);
    if !fast {
        kernel_benches();
    }
    streaming_benches(fast);
}

/// The original kernel/bridge/simulator benches (skipped in fast mode).
fn kernel_benches() {
    let cm = CostModel::default();
    let mut rng = Pcg::seed(77);

    // --- L3: RoBW partitioner (the Phase-I CPU pass) --------------------
    let g = aires::graphgen::kmer::generate(&mut rng, 500_000, 3.4);
    let bytes = g.size_bytes();
    println!("RoBW partitioner on {} ({} nnz):", aires::util::human_bytes(bytes), g.nnz());
    let r = bench("robw_partition(500k)", 2, 10, || {
        std::hint::black_box(robw_partition(&g, 1 << 20));
    });
    report_throughput(&r, bytes);
    // Parallel planner: chunk-local greedy plans (binary-search boundaries)
    // + ordered segment-boundary merge; plan identical to serial.
    assert_eq!(
        robw_partition_par(&g, 1 << 20, &Pool::new(4)),
        robw_partition(&g, 1 << 20),
        "parallel RoBW plan must match the serial planner"
    );
    for t in [1usize, 2, 4, 8] {
        let pool = Pool::new(t);
        let rp = bench(&format!("robw_partition_par(500k, {t}t)"), 2, 10, || {
            std::hint::black_box(robw_partition_par(&g, 1 << 20, &pool));
        });
        report_speedup(&r, &rp);
    }

    // --- L3: SpGEMM oracle ----------------------------------------------
    let a = {
        let mut rng2 = Pcg::seed(78);
        aires::graphgen::rmat::generate(&mut rng2, 12, 8, Default::default())
    };
    let flops = 2 * a.nnz() as u64 * (a.nnz() as u64 / a.nrows as u64);
    let spgemm_serial = bench("spgemm_gustavson(rmat-12, A*A)", 1, 5, || {
        std::hint::black_box(spgemm_gustavson(&a, &a));
    });
    println!(
        "BENCH spgemm: ~{:.2} Mflop/s equivalent",
        flops as f64 / spgemm_serial.mean_s / 1e6
    );

    // --- L3: SpMM (aggregation oracle, lane-blocked microkernel) --------
    let h = Dense::from_vec(a.ncols, 64, (0..a.ncols * 64).map(|_| 0.5f32).collect());
    let spmm_serial = bench("spmm(rmat-12 x 64)", 1, 5, || {
        std::hint::black_box(spmm(&a, &h));
    });
    report_throughput(&spmm_serial, (a.nnz() * 64 * 4) as u64);

    // --- runtime::pool: parallel row-range kernels vs the serial oracles.
    // The RMAT workload is the acceptance target: >= 2x at 4 threads.
    // Outputs are byte-identical (asserted once here; exhaustively in
    // rust/tests/differential.rs), so the speedup is not bought with drift.
    assert_eq!(
        spgemm_gustavson_par(&a, &a, &Pool::new(4)),
        spgemm_gustavson(&a, &a),
        "parallel spgemm must match the serial oracle"
    );
    for t in [1usize, 2, 4, 8] {
        let pool = Pool::new(t);
        let rp = bench(&format!("spgemm_gustavson_par(rmat-12, {t}t)"), 1, 5, || {
            std::hint::black_box(spgemm_gustavson_par(&a, &a, &pool));
        });
        report_speedup(&spgemm_serial, &rp);
        let rp = bench(&format!("spmm_par(rmat-12 x 64, {t}t)"), 1, 5, || {
            std::hint::black_box(spmm_par(&a, &h, &pool));
        });
        report_speedup(&spmm_serial, &rp);
    }

    // --- Bridge: BSR extraction + artifact batch packing ----------------
    let seg = g.slice_rows(0, 20_000);
    let r = bench("Bsr::from_csr(20k-row segment, 32x32)", 2, 10, || {
        std::hint::black_box(Bsr::from_csr(&seg, 32, 32));
    });
    report_throughput(&r, seg.size_bytes());
    let bsr = Bsr::from_csr(&seg, 32, 32);
    bench("pack_artifact_batches(r8, nb16)", 2, 10, || {
        std::hint::black_box(pack_artifact_batches(&bsr, 8, 16));
    });
    let pack_serial = bench("pack_csr_batches fused (r8, nb16)", 2, 10, || {
        std::hint::black_box(aires::sparse::block::pack_csr_batches(&seg, 32, 32, 8, 16));
    });
    let env_pool = aires::benchlib::pool_from_env();
    let rp = bench(
        &format!("pack_csr_batches_par (r8, nb16, {}t)", env_pool.threads()),
        2,
        10,
        || {
            std::hint::black_box(pack_csr_batches_par(&seg, 32, 32, 8, 16, &env_pool));
        },
    );
    report_speedup(&pack_serial, &rp);

    // --- Reordering: the tile-fill lever (§Perf) -------------------------
    let small = g.slice_rows(0, 50_000);
    let small_sq = {
        // re-square the slice for RCM (keep only cols < 50k)
        let mut coo = aires::sparse::Coo::new(50_000, 50_000);
        for i in 0..small.nrows {
            for (c, v) in small.row(i) {
                if (c as usize) < 50_000 {
                    coo.push(i as u32, c, v);
                }
            }
        }
        coo.to_csr()
    };
    let fill_before = Bsr::from_csr(&small_sq, 32, 32).tile_fill_ratio(small_sq.nnz());
    let perm = aires::sparse::reorder::rcm(&small_sq);
    let reordered = aires::sparse::reorder::permute_symmetric(&small_sq, &perm);
    let fill_after = Bsr::from_csr(&reordered, 32, 32).tile_fill_ratio(reordered.nnz());
    println!(
        "BENCH rcm tile fill (50k kmer, 32x32): {:.4} -> {:.4} ({:.1}x)",
        fill_before,
        fill_after,
        fill_after / fill_before
    );
    bench("rcm(50k kmer)", 1, 5, || {
        std::hint::black_box(aires::sparse::reorder::rcm(&small_sq));
    });

    // --- memsim: event throughput ----------------------------------------
    let r = bench("sim 100k transfer ops", 1, 5, || {
        let mut sim = Sim::new();
        let mut t = 0.0;
        for i in 0..100_000u64 {
            t = sim.transfer(&cm, if i % 2 == 0 { Op::HtoD } else { Op::DtoH }, 1 << 20, t, "x");
        }
        std::hint::black_box(sim.makespan());
    });
    println!("BENCH sim: {:.2} M events/s", 0.1 / r.mean_s);

    // --- Runtime: PJRT artifact call path --------------------------------
    match aires::runtime::Executor::from_env() {
        Ok(mut exec) => {
            let spmm_exec =
                aires::runtime::tile_exec::BsrSpmmExec::for_feature_width(&exec, 64).unwrap();
            let mut rng3 = Pcg::seed(79);
            let a_small = aires::graphgen::kmer::generate(&mut rng3, 1000, 3.0);
            let h = Dense::from_vec(1000, 64, (0..1000 * 64).map(|_| 0.25f32).collect());
            // Warm the compile cache before timing.
            let _ = spmm_exec.spmm(&mut exec, &a_small, &h).unwrap();
            bench("PJRT bsr_spmm (1k-node graph)", 1, 10, || {
                std::hint::black_box(spmm_exec.spmm(&mut exec, &a_small, &h).unwrap());
            });
            let comb =
                aires::runtime::tile_exec::CombineExec::for_widths(&exec, 64, 64, true).unwrap();
            let x = Dense::from_vec(1024, 64, (0..1024 * 64).map(|_| 0.1f32).collect());
            let w = Dense::from_vec(64, 64, (0..64 * 64).map(|_| 0.1f32).collect());
            let _ = comb.combine(&mut exec, &x, &w, &vec![0.0; 64]).unwrap();
            bench("PJRT gcn_combine (1024x64x64)", 1, 10, || {
                std::hint::black_box(comb.combine(&mut exec, &x, &w, &vec![0.0; 64]).unwrap());
            });
        }
        Err(e) => println!("skipping PJRT benches: {e}"),
    }
}

/// runtime::prefetch + runtime::segstore + runtime::recycle: staged
/// segment I/O overlapped with compute, disk-backed vs in-memory staging,
/// and the recycled vs fresh disk paths. Self-checking: every benched
/// configuration is asserted byte-identical to the in-memory serial
/// oracle before any number is reported.
fn streaming_benches(fast: bool) {
    let nodes = if fast { 12_000 } else { 60_000 };
    let seg_budget: u64 = if fast { 32 << 10 } else { 128 << 10 };
    let iters = if fast { 3 } else { 5 };

    let mut rngp = Pcg::seed(80);
    let ga = aires::sparse::norm::normalize_adjacency(
        &aires::graphgen::kmer::generate(&mut rngp, nodes, 3.2),
    );
    let x = Dense::from_vec(ga.ncols, 32, vec![0.5f32; ga.ncols * 32]);
    let layer = OocGcnLayer {
        w: Dense::from_vec(32, 32, vec![0.1f32; 32 * 32]),
        b: vec![0.0; 32],
        relu: true,
        seg_budget,
    };
    let pool = aires::benchlib::pool_from_env();

    // --- Phase II overlap: staged I/O (simulated H2D latency) hidden by
    // double buffering. The cost model makes the pass deliberately
    // I/O-bound-ish (a saturated link) so the overlap is visible.
    let mut io = CostModel::default();
    io.pcie_h2d_gbps = 0.16; // ~0.8 ms per 128 KiB segment staged
    let run = |depth: usize| {
        let staging = StagingConfig {
            prefetch: Prefetch::new(depth),
            io_cost: Some(io.clone()),
            ..StagingConfig::default()
        };
        let mut mem = GpuMem::new(1 << 30);
        layer.forward_cpu(&ga, &x, &mut mem, &pool, &staging).expect("forward_cpu").0
    };
    let segments = robw_partition(&ga, layer.seg_budget).len();
    println!(
        "prefetch overlap on kmer-{nodes} ({segments} segments, {}t pool):",
        pool.threads()
    );
    let serial = bench("forward_cpu staged I/O, depth 1 (serial)", 1, iters, || {
        std::hint::black_box(run(1));
    });
    let piped = bench("forward_cpu staged I/O, depth 2 (double-buffered)", 1, iters, || {
        std::hint::black_box(run(2));
    });
    report_speedup(&serial, &piped);
    assert_eq!(run(2), run(1), "prefetch must not change the output");

    // --- segstore: disk-backed vs in-memory staging, fresh vs recycled.
    // Segments spill once to a fixture directory (AIRES_SEG_FIXTURE_DIR
    // lets CI cache it between steps/runs — open_or_spill validates file
    // sizes and every read is checksum-verified, so a stale cache respills
    // instead of serving wrong bytes) and the forward pass streams from
    // the files through a disabled host cache, i.e. every staged segment
    // is a real read.
    let segs = robw_partition(&ga, layer.seg_budget);
    // _scratch keeps the RAII temp dir alive (and removed on every exit
    // path, panics included) when no fixture dir is configured.
    let fixture = format!("kmer-{nodes}");
    let (fix_dir, _scratch) = match std::env::var("AIRES_SEG_FIXTURE_DIR") {
        Ok(d) => (std::path::PathBuf::from(d).join(&fixture), None),
        Err(_) => {
            let t = aires::testing::TempDir::new("bench-seg");
            (t.path().join(&fixture), Some(t))
        }
    };
    let store = Arc::new(
        aires::runtime::SegmentStore::open_or_spill(&ga, &segs, &fix_dir, 0)
            .expect("spill segment fixture"),
    );
    let spilled: u64 = (0..store.len()).map(|i| store.meta(i).file_bytes).sum();
    println!(
        "disk-backed staging on kmer-{nodes} ({} segments, {} on disk):",
        store.len(),
        aires::util::human_bytes(spilled)
    );
    let run_mem = |depth: usize| {
        let mut mem = GpuMem::new(1 << 30);
        layer
            .forward_cpu(&ga, &x, &mut mem, &pool, &StagingConfig::depth(depth))
            .expect("forward_cpu")
            .0
    };
    // The recycle pool is shared across iterations: after the first pass
    // its slabs are at the plan's high-water capacities, so the timed
    // iterations measure the allocation-free steady state.
    let recycle = Arc::new(BufferPool::new(64 << 20));
    let run_disk = |depth: usize, recycled: bool| {
        let mut staging = StagingConfig::disk(store.clone(), depth);
        if recycled {
            staging = staging.with_recycle(recycle.clone());
        }
        let mut mem = GpuMem::new(1 << 30);
        layer.forward_cpu(&ga, &x, &mut mem, &pool, &staging).expect("forward_cpu disk").0
    };

    // Self-check before timing: every configuration that will be benched
    // must equal the in-memory serial oracle, and the recycled path must
    // equal the fresh one bit for bit.
    let oracle = run_mem(1);
    for depth in [1usize, 2] {
        let fresh = run_disk(depth, false);
        let recycled = run_disk(depth, true);
        assert_eq!(fresh, oracle, "disk fresh depth {depth} diverged from the oracle");
        assert_eq!(recycled, fresh, "recycled depth {depth} diverged from fresh");
    }
    assert_eq!(run_mem(2), oracle, "in-memory depth 2 diverged from the oracle");
    println!("BENCH streaming self-check: all staging configurations byte-identical OK");

    let mem_d1 = bench("forward_cpu in-memory staging, depth 1", 1, iters, || {
        std::hint::black_box(run_mem(1));
    });
    bench("forward_cpu in-memory staging, depth 2", 1, iters, || {
        std::hint::black_box(run_mem(2));
    });
    let mut results = BTreeMap::new();
    for (label, recycled) in [("fresh", false), ("recycled", true)] {
        for depth in [1usize, 2] {
            // Warm outside the counted window (bench warmup = 0), so the
            // allocation delta covers exactly the timed passes.
            std::hint::black_box(run_disk(depth, recycled));
            let allocs_before = allocation_count();
            let r = bench(
                &format!("forward_cpu disk {label} staging, depth {depth}"),
                0,
                iters,
                || {
                    std::hint::black_box(run_disk(depth, recycled));
                },
            );
            let allocs = allocation_count() - allocs_before;
            let allocs_per_segment = allocs as f64 / iters as f64 / store.len() as f64;
            let ns_per_segment = r.mean_s / store.len() as f64 * 1e9;
            println!(
                "BENCH forward_cpu disk {label} depth {depth}: {:.0} ns/segment, \
                 {allocs_per_segment:.1} allocs/segment",
                ns_per_segment
            );
            report_speedup(&mem_d1, &r);
            results.insert(
                format!("{label}_depth{depth}"),
                result_json(
                    &r,
                    &[
                        ("ns_per_segment", ns_per_segment),
                        ("allocs_per_segment", allocs_per_segment),
                    ],
                ),
            );
        }
    }
    let st = recycle.stats();
    println!(
        "BENCH recycle pool: {} hits / {} misses over the run ({} dropped by the cap)",
        st.hits, st.misses, st.drops
    );

    // --- Storage engine v2: raw vs packed segment stores. The packed
    // fixture spills the SAME plan as delta+bitpacked colidx records
    // (keyed separately — switching encodings must never reuse the other
    // fixture's bytes), every read is a real file read + decode at cache
    // 0, and the self-check pins both stores to identical matrices
    // before any number is reported. Emits the `bytes_per_segment` +
    // `ns_per_segment` series the bench gate trends at both encodings.
    let packed_fixture = format!("kmer-{nodes}-packed");
    let packed_dir = match std::env::var("AIRES_SEG_FIXTURE_DIR") {
        Ok(d) => std::path::PathBuf::from(d).join(&packed_fixture),
        Err(_) => _scratch
            .as_ref()
            .expect("scratch temp dir exists when no fixture dir is configured")
            .path()
            .join(&packed_fixture),
    };
    let packed_store = Arc::new(
        aires::runtime::SegmentStore::open_or_spill_encoded(
            &ga,
            &segs,
            &packed_dir,
            0,
            aires::sparse::segio::SegEncoding::Packed,
        )
        .expect("spill packed segment fixture"),
    );
    let packed_bytes: u64 =
        (0..packed_store.len()).map(|i| packed_store.meta(i).file_bytes).sum();
    println!(
        "packed colidx store on kmer-{nodes}: {} on disk vs {} raw ({:.2}x smaller)",
        aires::util::human_bytes(packed_bytes),
        aires::util::human_bytes(spilled),
        spilled as f64 / packed_bytes as f64
    );
    assert!(packed_bytes < spilled, "packed store must be smaller than raw");
    for i in 0..store.len() {
        let (raw_seg, _) = store.read(i).expect("raw segment read");
        let (packed_seg, _) = packed_store.read(i).expect("packed segment read");
        assert_eq!(raw_seg.csr(), packed_seg.csr(), "packed segment {i} diverged from raw");
    }
    println!("BENCH segread self-check: packed store byte-identical to raw OK");
    for (key, seg_store, total_bytes) in
        [("segread_raw", &store, spilled), ("segread_packed", &packed_store, packed_bytes)]
    {
        let r = bench(&format!("{key}: read+decode every segment"), 1, iters, || {
            for i in 0..seg_store.len() {
                std::hint::black_box(seg_store.read(i).expect("segment read"));
            }
        });
        let ns_per_segment = r.mean_s / seg_store.len() as f64 * 1e9;
        let bytes_per_segment = total_bytes as f64 / seg_store.len() as f64;
        println!(
            "BENCH {key}: {ns_per_segment:.0} ns/segment, {bytes_per_segment:.0} bytes/segment"
        );
        results.insert(
            key.to_string(),
            result_json(
                &r,
                &[("ns_per_segment", ns_per_segment), ("bytes_per_segment", bytes_per_segment)],
            ),
        );
    }

    // --- Cross-layer pipeline: a 3-layer forward, pipelined (one
    // scheduler, the producer rolls onto the next layer's plan) vs
    // drain-at-boundary (isolated single-layer passes). The same charged
    // staging latency as the overlap bench makes the per-boundary drain —
    // the cold re-fill of the pipeline at each layer — visible wall-clock.
    const BENCH_LAYERS: usize = 3;
    let model = OocGcnModel::new(
        (0..BENCH_LAYERS)
            .map(|_| OocGcnLayer {
                w: Dense::from_vec(32, 32, vec![0.1f32; 32 * 32]),
                b: vec![0.0; 32],
                relu: true,
                seg_budget,
            })
            .collect(),
    )
    .expect("equal-width layers chain");
    let run_multi = |pipelined: bool| {
        let staging = StagingConfig {
            prefetch: Prefetch::new(2),
            io_cost: Some(io.clone()),
            ..StagingConfig::default()
        };
        let cfg = PipelineConfig::staged(staging);
        let mut mem = GpuMem::new(1 << 30);
        if pipelined {
            model.forward_cpu(&ga, &x, &mut mem, &pool, &cfg).expect("pipelined model").0
        } else {
            model
                .forward_cpu_sequential(&ga, &x, &mut mem, &pool, &cfg)
                .expect("sequential model")
                .0
        }
    };
    // Self-check: the pipelined pass must equal the drain-at-boundary
    // oracle bit for bit before any number is reported.
    let multi_want = run_multi(false);
    assert_eq!(run_multi(true), multi_want, "cross-layer pipeline diverged");
    println!(
        "cross-layer pipeline on kmer-{nodes} ({BENCH_LAYERS} layers x {} segments):",
        segments
    );
    let seq = bench("model forward, drain at every layer boundary", 1, iters, || {
        std::hint::black_box(run_multi(false));
    });
    let piped = bench("model forward, one cross-layer pipeline", 1, iters, || {
        std::hint::black_box(run_multi(true));
    });
    report_speedup(&seq, &piped);
    let ns_per_layer_seq = seq.mean_s / BENCH_LAYERS as f64 * 1e9;
    let ns_per_layer_piped = piped.mean_s / BENCH_LAYERS as f64 * 1e9;
    println!(
        "BENCH multilayer: {ns_per_layer_seq:.0} ns/layer drained, \
         {ns_per_layer_piped:.0} ns/layer pipelined"
    );

    // Allocations/segment of the recycled cross-layer disk path (the
    // alloc-free CI gate's bench counterpart; warmed outside the window).
    let multi_cfg = PipelineConfig::staged(
        StagingConfig::disk(store.clone(), 1).with_recycle(recycle.clone()),
    );
    let run_multi_disk = || {
        let mut mem = GpuMem::new(1 << 30);
        model.forward_cpu(&ga, &x, &mut mem, &pool, &multi_cfg).expect("model disk").0
    };
    // Warm the pool at model scale; the warm pass doubles as the disk
    // path's self-check against the drained oracle.
    assert_eq!(run_multi_disk(), multi_want, "cross-layer disk path diverged");
    let allocs_before = allocation_count();
    let rm = bench("model forward disk recycled, depth 1", 0, iters, || {
        std::hint::black_box(run_multi_disk());
    });
    let multi_allocs = allocation_count() - allocs_before;
    let multi_segments = (store.len() * BENCH_LAYERS) as f64;
    let multi_allocs_per_segment = multi_allocs as f64 / iters as f64 / multi_segments;
    let multi_ns_per_layer = rm.mean_s / BENCH_LAYERS as f64 * 1e9;
    println!(
        "BENCH model disk recycled: {multi_ns_per_layer:.0} ns/layer, \
         {multi_allocs_per_segment:.2} allocs/segment over {multi_segments:.0} segments"
    );

    // Machine-readable cross-layer numbers ride the same JSON artifact.
    for (key, r, allocs_per_seg) in [
        ("multilayer_drained_depth2", &seq, None),
        ("multilayer_pipelined_depth2", &piped, None),
        ("multilayer_disk_recycled_depth1", &rm, Some(multi_allocs_per_segment)),
    ] {
        let mut extras = vec![("ns_per_layer", r.mean_s / BENCH_LAYERS as f64 * 1e9)];
        if let Some(a) = allocs_per_seg {
            extras.push(("allocs_per_segment", a));
        }
        results.insert(key.to_string(), result_json(r, &extras));
    }

    // --- Multi-tenant fan-out serving: N tenants share one staged pass
    // of the adjacency per batch (gcn::serve). Self-checking like the
    // rest of the section: every served tenant must equal the solo
    // oracle bit for bit, staged I/O must be charged once per segment
    // (not per tenant), and the ledger must balance — before any
    // latency number is reported.
    const TENANTS: usize = 4;
    let queries: Vec<TenantQuery> =
        (0..TENANTS).map(|_| TenantQuery { x: x.clone(), layer: layer.clone() }).collect();
    let serve_staging = StagingConfig::disk(store.clone(), 2).with_recycle(recycle.clone());
    let mut mem = GpuMem::new(1 << 30);
    let (batch_out, batch_rep) = serve_batch(&ga, &queries, &mut mem, &pool, &serve_staging);
    for (t, r) in batch_out.iter().enumerate() {
        let got = r.as_ref().unwrap_or_else(|e| panic!("served tenant {t}: {e}"));
        assert_eq!(got, &oracle, "served tenant {t} diverged from the solo oracle");
    }
    assert_eq!(mem.used, 0, "serve ledger must balance");
    assert_eq!(
        batch_rep.cache_misses,
        store.len(),
        "staged I/O must be charged once per segment, not once per tenant"
    );
    println!(
        "BENCH serve self-check: {TENANTS} tenants byte-identical to solo, \
         {} segments staged once OK",
        batch_rep.segments
    );
    let olc = OpenLoopConfig {
        requests_per_tenant: iters.max(2),
        rate_hz: 1000.0,
        max_batch: TENANTS,
    };
    let mut mem = GpuMem::new(1 << 30);
    let srep = serve_open_loop(&ga, &queries, &mut mem, &pool, &serve_staging, &olc);
    assert!(srep.ledger_balanced, "serve ledger must balance after every batch");
    println!(
        "BENCH serve open-loop: {TENANTS} tenants x {} requests, {} batches, \
         {:.1} segments/s",
        olc.requests_per_tenant, srep.batches, srep.segments_per_s
    );
    for t in &srep.per_tenant {
        println!(
            "BENCH serve tenant {}: p50 {:.2} ms, p99 {:.2} ms ({} completed, {} rejected)",
            t.tenant,
            t.p50_s * 1e3,
            t.p99_s * 1e3,
            t.completed,
            t.rejected
        );
    }
    // The full ServeReport (per-tenant latency percentiles included)
    // rides the same JSON artifact CI already uploads.
    results.insert("serve_open_loop".to_string(), srep.to_json());

    // --- Streamed training: one SGD step = forward + streamed backward
    // through the recycled disk path, gradient/activation panels through
    // the tiered panel store (gcn::train_stream). Self-checking like the
    // rest of the section: the streamed loss must be byte-identical to
    // the dense CPU oracle on the warm-up steps before any number is
    // reported. Emits the `ns_per_step` the bench gate trends.
    {
        use aires::gcn::train_stream::{dense_step_oracle, synthetic_labels};
        use aires::gcn::{RecomputePolicy, StreamedTrainer, TrainStreamConfig};

        let classes = 4usize;
        let mut rngt = Pcg::seed(82);
        let labels = synthetic_labels(&x, classes, &mut rngt);
        let widths = [32usize, 32, 32, classes];
        let train_layers: Vec<OocGcnLayer> = (0..3)
            .map(|l| OocGcnLayer {
                w: Dense::from_vec(
                    widths[l],
                    widths[l + 1],
                    (0..widths[l] * widths[l + 1])
                        .map(|_| (rngt.normal() * 0.2) as f32)
                        .collect(),
                ),
                b: vec![0.0; widths[l + 1]],
                relu: l < 2,
                seg_budget,
            })
            .collect();
        let pdir = aires::testing::TempDir::new("bench-train-panels");
        let panels =
            Arc::new(aires::runtime::segstore::PanelStore::new(pdir.path(), 0).expect("panels"));
        let tstaging = StagingConfig::disk(store.clone(), 2).with_recycle(recycle.clone());
        let tcfg = TrainStreamConfig::new(tstaging, panels).with_policy(RecomputePolicy::Reload);
        let mut tr = StreamedTrainer::new(train_layers.clone(), labels.clone()).expect("trainer");
        let mut oracle_layers = train_layers;
        let lr = 0.1f32;
        // Self-check + pool/panel warm-up: two steps against the oracle.
        let mut backward_segments = 0usize;
        for step in 0..2 {
            let mut mem = GpuMem::new(1 << 30);
            let rep = tr.step(&ga, &x, &mut mem, &pool, &tcfg, lr).expect("streamed step");
            let want =
                dense_step_oracle(&mut oracle_layers, &ga, &x, &labels, lr).expect("dense oracle");
            assert_eq!(
                rep.loss.to_bits(),
                want.to_bits(),
                "streamed training step {step} diverged from the dense oracle"
            );
            assert_eq!(mem.used, 0, "train ledger must balance");
            backward_segments = rep.backward_segments;
        }
        println!("BENCH train_stream self-check: streamed loss matches dense oracle OK");
        let allocs_before = allocation_count();
        let rt = bench("train_stream step (3 layers, disk recycled, depth 2)", 0, iters, || {
            let mut m = GpuMem::new(1 << 30);
            std::hint::black_box(tr.step(&ga, &x, &mut m, &pool, &tcfg, lr).expect("train step"));
        });
        let train_allocs = allocation_count() - allocs_before;
        let ns_per_step = rt.mean_s * 1e9;
        let allocs_per_step = train_allocs as f64 / iters as f64;
        println!(
            "BENCH train_stream: {ns_per_step:.0} ns/step, {allocs_per_step:.0} allocs/step \
             over {} forward + {backward_segments} backward segments",
            store.len() * BENCH_LAYERS
        );
        results.insert(
            "train_stream".to_string(),
            result_json(
                &rt,
                &[("ns_per_step", ns_per_step), ("allocs_per_step", allocs_per_step)],
            ),
        );
    }

    // --- rmat_large: a 2^21-node RMAT graph under a tight segment
    // budget — the out-of-core regime (hundreds of segments) that the
    // small kmer workload cannot exercise. Skipped in fast mode
    // (AIRES_BENCH_FAST): the graph alone takes seconds to generate.
    // Self-checking like the rest of the section: depth 2 must equal
    // the depth-1 serial pass bit for bit before the number is kept.
    if !fast {
        let mut rngl = Pcg::seed(81);
        let gl = aires::sparse::norm::normalize_adjacency(&aires::graphgen::rmat::generate(
            &mut rngl,
            21,
            4,
            Default::default(),
        ));
        let xl = Dense::from_vec(gl.ncols, 16, vec![0.5f32; gl.ncols * 16]);
        let large_budget: u64 = 256 << 10;
        let large_layer = OocGcnLayer {
            w: Dense::from_vec(16, 16, vec![0.1f32; 16 * 16]),
            b: vec![0.0; 16],
            relu: true,
            seg_budget: large_budget,
        };
        let large_segments = robw_partition(&gl, large_budget).len();
        let run_large = |depth: usize| {
            let mut mem = GpuMem::new(4u64 << 30);
            large_layer
                .forward_cpu(&gl, &xl, &mut mem, &pool, &StagingConfig::depth(depth))
                .expect("rmat_large forward")
                .0
        };
        assert_eq!(run_large(2), run_large(1), "rmat_large depth 2 diverged from serial");
        println!(
            "rmat_large on rmat-21 ({} nodes, {} nnz, {large_segments} segments):",
            gl.nrows,
            gl.nnz()
        );
        let rl = bench("forward_cpu rmat_large in-memory, depth 2", 1, iters, || {
            std::hint::black_box(run_large(2));
        });
        let large_ns = rl.mean_s / large_segments as f64 * 1e9;
        println!("BENCH rmat_large: {large_ns:.0} ns/segment over {large_segments} segments");
        results.insert(
            "rmat_large".to_string(),
            result_json(&rl, &[("ns_per_segment", large_ns), ("segments", large_segments as f64)]),
        );
    }

    // Seed/extend the perf trajectory: machine-readable streaming numbers.
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("micro_hotpath/streaming".to_string()));
    root.insert("graph".to_string(), Json::Str(fixture));
    root.insert("segments".to_string(), Json::Num(store.len() as f64));
    root.insert("iters".to_string(), Json::Num(iters as f64));
    root.insert("threads".to_string(), Json::Num(pool.threads() as f64));
    root.insert("fast_mode".to_string(), Json::Num(if fast { 1.0 } else { 0.0 }));
    root.insert("self_check".to_string(), Json::Str("ok".to_string()));
    root.insert("recycle_pool_hits".to_string(), Json::Num(st.hits as f64));
    root.insert("recycle_pool_misses".to_string(), Json::Num(st.misses as f64));
    root.insert("results".to_string(), Json::Obj(results));
    let out = std::env::var("AIRES_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_streaming.json".to_string());
    std::fs::write(&out, format!("{}\n", Json::Obj(root))).expect("write bench json");
    println!("BENCH wrote {out}");
}
