//! Append-only JSONL store: writer, skip-and-report reader, run index.

use super::{BenchDbError, RunId, RunRecord};
use crate::util::json;
use std::collections::BTreeSet;
use std::io::Write;
use std::path::Path;

/// A defective line the reader skipped, with its 1-based line number
/// and the typed reason. Reported, never fatal.
#[derive(Debug, Clone, PartialEq)]
pub struct SkippedLine {
    /// 1-based line number in the trajectory file.
    pub line: usize,
    /// Why the line was skipped.
    pub error: BenchDbError,
}

/// The parsed trajectory: every valid record plus a report of every
/// line that was skipped. An empty file parses to an empty trajectory.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trajectory {
    /// Valid records in file order.
    pub records: Vec<RunRecord>,
    /// Defective lines, in file order, with typed reasons.
    pub skipped: Vec<SkippedLine>,
}

impl Trajectory {
    /// Distinct run identities, sorted by `(ts, commit)` — oldest
    /// first. The last entry is the newest run.
    pub fn runs(&self) -> Vec<RunId> {
        let set: BTreeSet<RunId> = self
            .records
            .iter()
            .map(|r| (r.ts, r.commit.clone()))
            .collect();
        set.into_iter().collect()
    }

    /// The newest run's identity, or `None` for an empty trajectory.
    pub fn latest_run(&self) -> Option<RunId> {
        self.runs().pop()
    }

    /// Records belonging to one run, in file order.
    pub fn run_records(&self, run: &RunId) -> Vec<&RunRecord> {
        self.records
            .iter()
            .filter(|r| r.ts == run.0 && r.commit == run.1)
            .collect()
    }
}

/// Parse trajectory text. Blank lines are ignored; every other line
/// must be one canonical record. Lines that fail to parse or validate
/// are collected in [`Trajectory::skipped`] with 1-based line numbers —
/// a torn trailing line from an interrupted append surfaces here as a
/// [`BenchDbError::Malformed`] skip, never a panic or a lost prefix.
pub fn parse_trajectory(text: &str) -> Trajectory {
    let mut out = Trajectory::default();
    for (idx, line) in text.lines().enumerate() {
        let lineno = idx + 1;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match json::parse(line) {
            Ok(v) => v,
            Err(msg) => {
                out.skipped.push(SkippedLine {
                    line: lineno,
                    error: BenchDbError::Malformed(msg),
                });
                continue;
            }
        };
        match RunRecord::from_json(&parsed) {
            Ok(rec) => out.records.push(rec),
            Err(error) => out.skipped.push(SkippedLine {
                line: lineno,
                error,
            }),
        }
    }
    out
}

/// Read and parse a trajectory file. A missing or unreadable file is
/// the one fatal case ([`BenchDbError::Io`]); per-line defects are
/// reported via [`Trajectory::skipped`] as in [`parse_trajectory`].
pub fn read_trajectory(path: &Path) -> Result<Trajectory, BenchDbError> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| BenchDbError::Io(format!("read {}: {e}", path.display())))?;
    Ok(parse_trajectory(&text))
}

/// Append records to the trajectory file (creating it, and any parent
/// directories, on first use). Each record becomes one canonical line;
/// the batch is written with a single `write_all` so a crash tears at
/// most the final line — which the reader then skips-and-reports. If
/// the existing file ends mid-line (a previous torn write), a newline
/// is inserted first so the torn fragment stays isolated on its own
/// line instead of corrupting the first new record.
pub fn append_records(path: &Path, records: &[RunRecord]) -> Result<(), BenchDbError> {
    if records.is_empty() {
        return Ok(());
    }
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)
                .map_err(|e| BenchDbError::Io(format!("create {}: {e}", parent.display())))?;
        }
    }
    let mut buf = String::new();
    if tail_is_torn(path)? {
        buf.push('\n');
    }
    for rec in records {
        buf.push_str(&rec.to_line());
        buf.push('\n');
    }
    let mut file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
        .map_err(|e| BenchDbError::Io(format!("open {}: {e}", path.display())))?;
    file.write_all(buf.as_bytes())
        .map_err(|e| BenchDbError::Io(format!("append {}: {e}", path.display())))?;
    Ok(())
}

/// Whether the file exists, is non-empty, and does not end with a
/// newline — i.e. a previous append was torn mid-line.
fn tail_is_torn(path: &Path) -> Result<bool, BenchDbError> {
    use std::io::{Read, Seek, SeekFrom};
    let mut file = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(false),
        Err(e) => return Err(BenchDbError::Io(format!("open {}: {e}", path.display()))),
    };
    let len = file
        .metadata()
        .map_err(|e| BenchDbError::Io(format!("stat {}: {e}", path.display())))?
        .len();
    if len == 0 {
        return Ok(false);
    }
    file.seek(SeekFrom::End(-1))
        .map_err(|e| BenchDbError::Io(format!("seek {}: {e}", path.display())))?;
    let mut last = [0u8; 1];
    file.read_exact(&mut last)
        .map_err(|e| BenchDbError::Io(format!("read {}: {e}", path.display())))?;
    Ok(last[0] != b'\n')
}
