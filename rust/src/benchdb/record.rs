//! Schema-versioned run records and their canonical JSONL encoding.

use super::BenchDbError;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// On-disk record schema version this build reads and writes. Bump when
/// a field is added, removed, or reinterpreted; the reader skips (never
/// mis-parses) records from other versions.
pub const SCHEMA_VERSION: u32 = 1;

/// One datapoint in the perf trajectory: a single `(scenario, metric)`
/// measurement taken at `(ts, commit)`.
///
/// The canonical line encoding ([`RunRecord::to_line`]) is a
/// sorted-key, no-whitespace JSON object — byte-stable across builds
/// and pinned by a golden-vector test, like `segio`'s segment headers.
#[derive(Debug, Clone, PartialEq)]
pub struct RunRecord {
    /// Commit the bench ran at (short or full hash; `"unknown"` when
    /// outside a checkout).
    pub commit: String,
    /// Ingest time, seconds since the Unix epoch. Together with
    /// `commit` this identifies the run (see [`RunId`](super::RunId)).
    pub ts: u64,
    /// Scenario identifier, e.g. `fresh_depth1` or `serve_open_loop`.
    pub scenario: String,
    /// Metric name within the scenario — a '.'-joined path for nested
    /// emissions, e.g. `ns_per_segment` or `per_tenant.tenant_0.p99_s`.
    pub metric: String,
    /// Measured value. Always finite (enforced on parse and ingest).
    pub value: f64,
    /// Unit label for display, e.g. `ns`, `s`, `allocs`, `seg/s`.
    pub unit: String,
}

impl RunRecord {
    /// Canonical single-line encoding (no trailing newline). Keys are
    /// emitted sorted by `Json`'s `BTreeMap` backing, so the same
    /// record always produces the same bytes.
    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("schema".to_string(), Json::Num(f64::from(SCHEMA_VERSION)));
        obj.insert("commit".to_string(), Json::Str(self.commit.clone()));
        obj.insert("ts".to_string(), Json::Num(self.ts as f64));
        obj.insert("scenario".to_string(), Json::Str(self.scenario.clone()));
        obj.insert("metric".to_string(), Json::Str(self.metric.clone()));
        obj.insert("value".to_string(), Json::Num(self.value));
        obj.insert("unit".to_string(), Json::Str(self.unit.clone()));
        Json::Obj(obj).to_string()
    }

    /// Validate a parsed JSON value as a record. Checks, in order:
    /// object shape, schema version, then each field's presence and
    /// type. All failures are typed [`BenchDbError`]s — the store
    /// reader turns them into skip-and-report entries.
    pub fn from_json(json: &Json) -> Result<RunRecord, BenchDbError> {
        let obj = match json {
            Json::Obj(obj) => obj,
            other => {
                return Err(BenchDbError::Malformed(format!(
                    "expected a JSON object, got {other}"
                )))
            }
        };
        let schema = require_u64(obj, "schema")?;
        if schema != u64::from(SCHEMA_VERSION) {
            return Err(BenchDbError::WrongSchema {
                found: schema.min(u64::from(u32::MAX)) as u32,
                expected: SCHEMA_VERSION,
            });
        }
        let commit = require_str(obj, "commit")?;
        let ts = require_u64(obj, "ts")?;
        let scenario = require_str(obj, "scenario")?;
        let metric = require_str(obj, "metric")?;
        let unit = require_str(obj, "unit")?;
        let value = require_num(obj, "value")?;
        if !value.is_finite() {
            return Err(BenchDbError::BadField {
                field: "value",
                msg: format!("must be finite, got {value}"),
            });
        }
        Ok(RunRecord {
            commit,
            ts,
            scenario,
            metric,
            value,
            unit,
        })
    }
}

fn require_field<'a>(
    obj: &'a BTreeMap<String, Json>,
    field: &'static str,
) -> Result<&'a Json, BenchDbError> {
    obj.get(field).ok_or(BenchDbError::MissingField(field))
}

fn require_str(obj: &BTreeMap<String, Json>, field: &'static str) -> Result<String, BenchDbError> {
    match require_field(obj, field)? {
        Json::Str(s) => Ok(s.clone()),
        other => Err(BenchDbError::BadField {
            field,
            msg: format!("expected a string, got {other}"),
        }),
    }
}

fn require_num(obj: &BTreeMap<String, Json>, field: &'static str) -> Result<f64, BenchDbError> {
    match require_field(obj, field)? {
        Json::Num(n) => Ok(*n),
        other => Err(BenchDbError::BadField {
            field,
            msg: format!("expected a number, got {other}"),
        }),
    }
}

fn require_u64(obj: &BTreeMap<String, Json>, field: &'static str) -> Result<u64, BenchDbError> {
    let n = require_num(obj, field)?;
    if !n.is_finite() || n.fract() != 0.0 || n < 0.0 || n > u64::MAX as f64 {
        return Err(BenchDbError::BadField {
            field,
            msg: format!("expected a non-negative integer, got {n}"),
        });
    }
    Ok(n as u64)
}
