//! Perf-trajectory database: the append-only results store that makes
//! "fast as the hardware allows" *enforceable* across commits.
//!
//! The `micro_hotpath` bench self-checks byte-identity and then emits one
//! `BENCH_streaming.json` per run — but a JSON file per run is a
//! snapshot, not a trajectory. This module accumulates those snapshots
//! into an on-disk store and turns them into a regression gate:
//!
//! * **[`RunRecord`]** — one schema-versioned datapoint: `(schema,
//!   commit, ts, scenario, metric, value, unit)`. A *run* is the set of
//!   records sharing `(ts, commit)`; one `bench ingest` writes one run.
//! * **Store** ([`append_records`] / [`read_trajectory`]) — append-only
//!   JSONL, one canonical record per line (sorted keys, byte-stable —
//!   pinned by a golden vector like `segio`'s). The reader is
//!   *skip-and-report*: a torn trailing line, an interleaved garbage
//!   line, or a wrong-schema-version line becomes a typed
//!   [`BenchDbError`] in [`Trajectory::skipped`] — never a panic, and
//!   never a reason to drop the valid prefix.
//! * **Ingest** ([`records_from_bench_json`]) — flattens a
//!   `BENCH_streaming.json` emission (every numeric leaf under
//!   `results`, dotted-path metric names) into records, folding the
//!   kernel numbers (ns/segment, allocs/segment) and the open-loop
//!   [`ServeReport`](crate::gcn::ServeReport) latency percentiles into
//!   the *same* record stream.
//! * **Stats + gate** ([`scenario_stats`] / [`gate`]) — per-scenario
//!   min/p50/p99 tables across stored runs (nearest-rank
//!   [`percentile`](crate::util::percentile), the same function `serve`
//!   reports with), and a regression gate: the newest run's
//!   lower-is-better metrics ([`gated_metric`]: `ns_per_segment`,
//!   `ns_per_layer`, `ns_per_step`, `bytes_per_segment`, any `p99_s`
//!   leaf) are compared
//!   against the *median of all prior runs*; any regression beyond the
//!   configured percentage fails the gate. No baseline (empty store,
//!   first run) passes vacuously — the run seeds the baseline instead.
//!   [`trend_lines`] renders the commit-to-commit view of the same
//!   gated series: one point per run, each with its delta vs the
//!   previous commit.
//!
//! The CLI surface is the `bench` subcommand family (`bench ingest`,
//! `bench report`, `bench gate --max-regress-pct X`); CI's `bench-smoke`
//! job runs the full ingest → report → gate loop against a cached
//! trajectory store. std-only, like everything else in the crate.

mod ingest;
mod record;
mod stats;
mod store;

pub use ingest::{records_from_bench_json, unit_for};
pub use record::{RunRecord, SCHEMA_VERSION};
pub use stats::{
    gate, gated_metric, scenario_stats, trend_lines, GateCheck, GateOutcome, MetricStats,
    TrendLine, TrendPoint,
};
pub use store::{append_records, parse_trajectory, read_trajectory, SkippedLine, Trajectory};

/// A run's identity inside the trajectory: `(ts, commit)`. Runs are
/// ordered by timestamp, ties broken by the commit string, so "the
/// newest run" is deterministic even when two ingests land in the same
/// second.
pub type RunId = (u64, String);

/// Typed trajectory-store failure. Per-line defects are *reported*, not
/// fatal: the reader records them in [`Trajectory::skipped`] and keeps
/// the valid records — the same skip-and-report discipline `segio`
/// applies to on-disk segments.
#[derive(Debug, Clone, PartialEq)]
pub enum BenchDbError {
    /// Underlying filesystem error (with path context). The only fatal
    /// variant: without the file there is nothing to skip *to*.
    Io(String),
    /// The line is not a JSON object (torn trailing line, interleaved
    /// garbage, or a non-object value).
    Malformed(String),
    /// The record's schema version differs from [`SCHEMA_VERSION`] —
    /// a valid line written by an incompatible build.
    WrongSchema {
        /// Version the record claims.
        found: u32,
        /// Version this build understands.
        expected: u32,
    },
    /// A required record field is absent.
    MissingField(&'static str),
    /// A record field is present but has the wrong type or an invalid
    /// value (non-integer timestamp, non-finite value, ...).
    BadField {
        /// Field that failed validation.
        field: &'static str,
        /// What was wrong with it.
        msg: String,
    },
    /// The ingest source (`BENCH_streaming.json`) is not a bench
    /// emission this build understands.
    BadSource(String),
}

impl std::fmt::Display for BenchDbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BenchDbError::Io(msg) => write!(f, "trajectory I/O: {msg}"),
            BenchDbError::Malformed(msg) => {
                write!(f, "not a JSONL record: {msg}")
            }
            BenchDbError::WrongSchema { found, expected } => write!(
                f,
                "unsupported record schema version {found} (expected {expected})"
            ),
            BenchDbError::MissingField(field) => {
                write!(f, "record is missing the {field:?} field")
            }
            BenchDbError::BadField { field, msg } => {
                write!(f, "record field {field:?} is invalid: {msg}")
            }
            BenchDbError::BadSource(msg) => {
                write!(f, "not a bench emission: {msg}")
            }
        }
    }
}

impl std::error::Error for BenchDbError {}
