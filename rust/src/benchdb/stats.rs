//! Per-scenario statistics and the regression gate.

use super::{RunId, Trajectory};
use crate::util::percentile;
use std::collections::BTreeMap;

/// Summary of one `(scenario, metric)` series across all stored runs.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricStats {
    /// Scenario identifier.
    pub scenario: String,
    /// Metric path within the scenario.
    pub metric: String,
    /// Unit label (taken from the newest record of the series).
    pub unit: String,
    /// Number of stored samples.
    pub samples: usize,
    /// Smallest stored value.
    pub min: f64,
    /// Nearest-rank median across stored values.
    pub p50: f64,
    /// Nearest-rank 99th percentile across stored values.
    pub p99: f64,
    /// Value from the newest run that recorded this metric.
    pub latest: f64,
}

/// Per-scenario min/p50/p99/latest for every metric series in the
/// trajectory, sorted by `(scenario, metric)`. Percentiles use the
/// same nearest-rank [`percentile`] the serve report uses.
pub fn scenario_stats(traj: &Trajectory) -> Vec<MetricStats> {
    let mut series: BTreeMap<(String, String), Vec<(RunId, f64, String)>> = BTreeMap::new();
    for rec in &traj.records {
        series
            .entry((rec.scenario.clone(), rec.metric.clone()))
            .or_default()
            .push(((rec.ts, rec.commit.clone()), rec.value, rec.unit.clone()));
    }
    let mut out = Vec::with_capacity(series.len());
    for ((scenario, metric), samples) in series {
        let mut sorted: Vec<f64> = samples.iter().map(|(_, v, _)| *v).collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
        // Newest run wins for `latest`/`unit`; max_by_key over the run id
        // keeps the *last* maximal element, so a duplicated metric within
        // one run resolves to its final record in file order.
        let (_, latest, unit) = samples
            .iter()
            .max_by(|a, b| a.0.cmp(&b.0))
            .expect("series is non-empty")
            .clone();
        out.push(MetricStats {
            scenario,
            metric,
            unit,
            samples: sorted.len(),
            min: sorted[0],
            p50: percentile(&sorted, 50.0),
            p99: percentile(&sorted, 99.0),
            latest,
        });
    }
    out
}

/// Whether a metric participates in the regression gate. Gated metrics
/// are the lower-is-better series: per-segment, per-layer and
/// per-training-step kernel time, any open-loop `p99_s` latency leaf
/// (tenant or aggregate), and the encoded on-disk footprint
/// (`bytes_per_segment` — a compression regression is a perf regression
/// for an I/O-bound pipeline). Throughput, allocation counts, and
/// self-check flags are reported but not gated.
pub fn gated_metric(metric: &str) -> bool {
    let leaf = metric.rsplit('.').next().unwrap_or(metric);
    leaf == "ns_per_segment"
        || leaf == "ns_per_layer"
        || leaf == "ns_per_step"
        || leaf == "p99_s"
        || leaf == "bytes_per_segment"
}

/// One run's sample within a [`TrendLine`].
#[derive(Debug, Clone, PartialEq)]
pub struct TrendPoint {
    /// The run this sample belongs to.
    pub run: RunId,
    /// The metric's value in that run (last record wins within a run).
    pub value: f64,
    /// Relative change vs the previous point in percent (positive =
    /// slower). `None` for the first point of a series, or when the
    /// previous value is zero or negative (nothing to divide by).
    pub delta_pct: Option<f64>,
}

/// Cross-commit trend of one gated `(scenario, metric)` series.
#[derive(Debug, Clone, PartialEq)]
pub struct TrendLine {
    /// Scenario identifier.
    pub scenario: String,
    /// Metric path within the scenario.
    pub metric: String,
    /// Unit label (taken from the newest record of the series).
    pub unit: String,
    /// One point per run that recorded the metric, oldest first.
    pub points: Vec<TrendPoint>,
}

/// Cross-commit trend of every *gated* metric series: one point per
/// run, ordered oldest-first, each stamped with its delta against the
/// previous run's value. This is the commit-to-commit view the `bench
/// report` table (an all-runs aggregate) cannot show: where in the
/// trajectory a metric moved, not just that it did.
pub fn trend_lines(traj: &Trajectory) -> Vec<TrendLine> {
    let mut series: BTreeMap<(String, String), (BTreeMap<RunId, f64>, String)> = BTreeMap::new();
    for rec in &traj.records {
        if !gated_metric(&rec.metric) {
            continue;
        }
        let entry = series
            .entry((rec.scenario.clone(), rec.metric.clone()))
            .or_insert_with(|| (BTreeMap::new(), rec.unit.clone()));
        // Last record in file order wins within a run (same resolution
        // rule as `scenario_stats`' `latest`); newest unit wins.
        entry.0.insert((rec.ts, rec.commit.clone()), rec.value);
        entry.1 = rec.unit.clone();
    }
    series
        .into_iter()
        .map(|((scenario, metric), (runs, unit))| {
            let mut points = Vec::with_capacity(runs.len());
            let mut prev: Option<f64> = None;
            for (run, value) in runs {
                let delta_pct = match prev {
                    Some(p) if p > 0.0 => Some((value - p) / p * 100.0),
                    _ => None,
                };
                points.push(TrendPoint { run, value, delta_pct });
                prev = Some(value);
            }
            TrendLine { scenario, metric, unit, points }
        })
        .collect()
}

/// One gated comparison: the newest run's value against the baseline
/// median of all prior runs for the same `(scenario, metric)`.
#[derive(Debug, Clone, PartialEq)]
pub struct GateCheck {
    /// Scenario identifier.
    pub scenario: String,
    /// Metric path within the scenario.
    pub metric: String,
    /// Unit label.
    pub unit: String,
    /// Median of the metric across all runs *before* the newest one.
    pub baseline_median: f64,
    /// The newest run's value.
    pub latest: f64,
    /// Relative change in percent: `(latest - median) / median * 100`.
    /// Positive means slower. `0.0` when the baseline median is zero
    /// or negative (the check is then skipped, never divided).
    pub regress_pct: f64,
    /// Whether this check exceeded the allowed regression.
    pub failed: bool,
}

/// Gate verdict: every gated comparison plus the context needed to
/// explain a vacuous pass.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct GateOutcome {
    /// Newest run's identity, if the store holds any runs.
    pub latest_run: Option<RunId>,
    /// Number of baseline runs the newest run was compared against.
    /// `0` means the gate passed vacuously (empty store or first run —
    /// it seeds the baseline instead of being judged).
    pub baseline_runs: usize,
    /// Per-metric comparisons, sorted by `(scenario, metric)`.
    pub checks: Vec<GateCheck>,
    /// Gated metrics skipped because their baseline median was zero or
    /// negative — comparing against those would divide by zero.
    pub skipped_zero_baseline: usize,
}

impl GateOutcome {
    /// `true` when no check failed (including the vacuous cases).
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| !c.failed)
    }
}

/// Compare the newest run's gated metrics against the median of all
/// prior runs, failing any metric that regressed by more than
/// `max_regress_pct` percent. With fewer than two runs there is no
/// baseline: the outcome has no checks and passes vacuously.
pub fn gate(traj: &Trajectory, max_regress_pct: f64) -> GateOutcome {
    let runs = traj.runs();
    let mut outcome = GateOutcome {
        latest_run: runs.last().cloned(),
        ..GateOutcome::default()
    };
    let latest = match runs.last() {
        Some(latest) if runs.len() >= 2 => latest.clone(),
        _ => return outcome,
    };
    outcome.baseline_runs = runs.len() - 1;
    // Baseline series: per gated (scenario, metric), one value per
    // prior run (last record wins within a run, matching `latest`).
    let mut baseline: BTreeMap<(String, String), BTreeMap<RunId, f64>> = BTreeMap::new();
    let mut newest: BTreeMap<(String, String), (f64, String)> = BTreeMap::new();
    for rec in &traj.records {
        if !gated_metric(&rec.metric) {
            continue;
        }
        let key = (rec.scenario.clone(), rec.metric.clone());
        let run: RunId = (rec.ts, rec.commit.clone());
        if run == latest {
            newest.insert(key, (rec.value, rec.unit.clone()));
        } else {
            baseline.entry(key).or_default().insert(run, rec.value);
        }
    }
    for ((scenario, metric), (value, unit)) in newest {
        let priors = match baseline.get(&(scenario.clone(), metric.clone())) {
            Some(priors) if !priors.is_empty() => priors,
            // Metric is new in this run: nothing to compare against.
            _ => continue,
        };
        let mut sorted: Vec<f64> = priors.values().copied().collect();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite values"));
        let median = percentile(&sorted, 50.0);
        if median <= 0.0 {
            outcome.skipped_zero_baseline += 1;
            continue;
        }
        let regress_pct = (value - median) / median * 100.0;
        outcome.checks.push(GateCheck {
            scenario,
            metric,
            unit,
            baseline_median: median,
            latest: value,
            regress_pct,
            failed: regress_pct > max_regress_pct,
        });
    }
    outcome
}
