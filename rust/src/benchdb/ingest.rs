//! Flatten a `BENCH_streaming.json` emission into run records.

use super::{BenchDbError, RunRecord};
use crate::util::json::{self, Json};

/// Unit label for a flattened metric path, keyed on its last
/// '.'-separated component: `segments_per_s` → `seg/s`,
/// `ns_per_segment`/`ns_per_layer` → `ns`, `allocs_per_segment` →
/// `allocs`, `bytes_per_segment` (the encoded-store footprint the
/// segread scenarios emit at both encodings) → `bytes`, any `*_s` leaf
/// (latency seconds: `mean_s`, `min_s`, `p50_s`, `p99_s`, ...) → `s`,
/// everything else → `count`.
pub fn unit_for(metric: &str) -> &'static str {
    let leaf = metric.rsplit('.').next().unwrap_or(metric);
    if leaf == "segments_per_s" {
        "seg/s"
    } else if leaf == "ns_per_segment" || leaf == "ns_per_layer" || leaf == "ns_per_step" {
        "ns"
    } else if leaf == "allocs_per_segment" || leaf == "allocs_per_step" {
        "allocs"
    } else if leaf == "bytes_per_segment" {
        "bytes"
    } else if leaf.ends_with("_s") {
        "s"
    } else {
        "count"
    }
}

/// Parse a `BENCH_streaming.json` emission and flatten every numeric
/// leaf under its `results` object into [`RunRecord`]s stamped with
/// `(commit, ts)`.
///
/// The top-level key of `results` is the scenario; nested objects
/// (e.g. the serve report's `per_tenant.tenant_0.p99_s`) become
/// '.'-joined metric paths, so open-loop latency percentiles land in
/// the same record stream as the kernel numbers. Booleans ingest as
/// `0.0`/`1.0` (so self-check flags like `ledger_balanced` are
/// trended too); strings, nulls, arrays, and non-finite numbers are
/// skipped. A source without a `results` object, or whose `results`
/// yields no records at all, is a [`BenchDbError::BadSource`].
pub fn records_from_bench_json(
    text: &str,
    commit: &str,
    ts: u64,
) -> Result<Vec<RunRecord>, BenchDbError> {
    let parsed = json::parse(text).map_err(BenchDbError::BadSource)?;
    let obj = match &parsed {
        Json::Obj(obj) => obj,
        other => {
            return Err(BenchDbError::BadSource(format!(
                "expected a JSON object, got {other}"
            )))
        }
    };
    let results = match obj.get("results") {
        Some(Json::Obj(results)) => results,
        Some(other) => {
            return Err(BenchDbError::BadSource(format!(
                "\"results\" must be an object, got {other}"
            )))
        }
        None => {
            return Err(BenchDbError::BadSource(
                "missing top-level \"results\" object".to_string(),
            ))
        }
    };
    let mut out = Vec::new();
    for (scenario, value) in results {
        flatten(scenario, "", value, commit, ts, &mut out);
    }
    if out.is_empty() {
        return Err(BenchDbError::BadSource(
            "\"results\" contains no numeric leaves".to_string(),
        ));
    }
    Ok(out)
}

/// Depth-first flatten of one scenario's value tree. `prefix` is the
/// '.'-joined path so far ("" at the scenario root); a numeric leaf at
/// the root itself gets the metric name `value`.
fn flatten(
    scenario: &str,
    prefix: &str,
    value: &Json,
    commit: &str,
    ts: u64,
    out: &mut Vec<RunRecord>,
) {
    match value {
        Json::Num(n) => {
            if n.is_finite() {
                push_leaf(scenario, prefix, *n, commit, ts, out);
            }
        }
        Json::Bool(b) => {
            push_leaf(scenario, prefix, if *b { 1.0 } else { 0.0 }, commit, ts, out);
        }
        Json::Obj(obj) => {
            for (key, child) in obj {
                let path = if prefix.is_empty() {
                    key.clone()
                } else {
                    format!("{prefix}.{key}")
                };
                flatten(scenario, &path, child, commit, ts, out);
            }
        }
        // Strings, nulls, and arrays carry no trendable scalar.
        Json::Str(_) | Json::Null | Json::Arr(_) => {}
    }
}

fn push_leaf(
    scenario: &str,
    prefix: &str,
    value: f64,
    commit: &str,
    ts: u64,
    out: &mut Vec<RunRecord>,
) {
    let metric = if prefix.is_empty() { "value" } else { prefix };
    out.push(RunRecord {
        commit: commit.to_string(),
        ts,
        scenario: scenario.to_string(),
        metric: metric.to_string(),
        value,
        unit: unit_for(metric).to_string(),
    });
}
