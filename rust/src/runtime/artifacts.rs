//! Artifact manifest: the typed contract between `python/compile/aot.py`
//! and the rust executor. Parsed with the in-tree JSON reader; shapes and
//! dtypes are validated at load time and again per execution.

use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Element type of a tensor (the two the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit signed integer.
    S32,
}

impl DType {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "s32" => Ok(DType::S32),
            other => bail!("unknown dtype {other:?}"),
        }
    }
}

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Tensor dimensions.
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
}

impl TensorSpec {
    /// Flat element count (1 for a scalar).
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::from_str(
            j.get("dtype").and_then(Json::as_str).ok_or_else(|| anyhow!("missing dtype"))?,
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (the executor's lookup key).
    pub name: String,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    /// Input tensor contracts, in parameter order.
    pub inputs: Vec<TensorSpec>,
    /// Output tensor contracts, in result order.
    pub outputs: Vec<TensorSpec>,
    /// Free-form numeric metadata (tile shapes etc).
    pub meta: BTreeMap<String, f64>,
}

/// The parsed manifest.json.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and HLO files) live in.
    pub dir: PathBuf,
    /// Every artifact the manifest declares.
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let root = parse(&text).map_err(|e| anyhow!("manifest parse error: {e}"))?;
        let arr = root.as_arr().ok_or_else(|| anyhow!("manifest root must be an array"))?;
        let mut artifacts = Vec::new();
        for item in arr {
            let name = item
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = item
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("{name}: missing file"))?
                .to_string();
            if !dir.join(&file).exists() {
                bail!("{name}: artifact file {file} missing (run `make artifacts`)");
            }
            let inputs = item
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing inputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = item
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: missing outputs"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let mut meta = BTreeMap::new();
            if let Some(m) = item.get("meta").and_then(Json::as_obj) {
                for (k, v) in m {
                    if let Some(n) = v.as_f64() {
                        meta.insert(k.clone(), n);
                    } else if let Some(b) = v.as_bool() {
                        meta.insert(k.clone(), if b { 1.0 } else { 0.0 });
                    }
                }
            }
            artifacts.push(ArtifactSpec { name, file, inputs, outputs, meta });
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    /// Look up an artifact by name.
    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find the first artifact whose name starts with `prefix`.
    pub fn find_prefix(&self, prefix: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name.starts_with(prefix))
    }

    /// All `bsr_spmm_*` variants.
    pub fn spmm_variants(&self) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.name.starts_with("bsr_spmm_")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_valid_manifest() {
        let dir = std::env::temp_dir().join("aires_manifest_ok");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.hlo.txt"), "HloModule x").unwrap();
        write_manifest(
            &dir,
            r#"[{"name":"x","file":"x.hlo.txt",
                 "inputs":[{"shape":[2,3],"dtype":"f32"}],
                 "outputs":[{"shape":[2],"dtype":"s32"}],
                 "meta":{"bm":32,"relu":true}}]"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("x").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.outputs[0].dtype, DType::S32);
        assert_eq!(a.meta["bm"], 32.0);
        assert_eq!(a.meta["relu"], 1.0);
    }

    #[test]
    fn rejects_missing_file() {
        let dir = std::env::temp_dir().join("aires_manifest_missing");
        write_manifest(
            &dir,
            r#"[{"name":"gone","file":"gone.hlo.txt","inputs":[],"outputs":[]}]"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // When artifacts exist (make artifacts), the real manifest must
        // parse and contain the four entry-point families.
        let Some(dir) = crate::runtime::find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        for prefix in ["bsr_spmm_", "gcn_combine_", "gcn2_fwd_", "gcn2_train_step_"] {
            assert!(m.find_prefix(prefix).is_some(), "missing {prefix}*");
        }
        for a in &m.artifacts {
            assert!(!a.inputs.is_empty());
            assert!(!a.outputs.is_empty());
        }
    }
}
