//! Double-buffered asynchronous prefetch pipeline (paper §III-B, Phase II).
//!
//! AIRES's core system claim is that RoBW segment *transfers* overlap
//! segment *compute*: while the kernel consumes segment `i`, the staging
//! path (host-side pack + H2D transfer) prepares segment `i+1`. The
//! scheduler simulation always modelled that overlap; this module makes
//! the execution engine actually perform it.
//!
//! Shape: one **producer task** (spawned on [`Pool::scoped`]) runs the
//! `stage` closure for successive indices and hands results through a
//! bounded [`Handoff`] queue; the **calling thread** consumes them
//! strictly in index order. The queue capacity is `depth - 1` and the
//! producer reserves its slot *before* staging, so at most `depth` items
//! are live at once — the one being consumed, the queued ones, and the
//! one in production — which is exactly the headroom callers budget
//! (e.g. the `GpuMem` ledger in `gcn::oocgcn`). `depth == 2` is classic
//! double buffering; `depth == 1` degrades to the fully serial loop (no
//! producer task, no queue) and is the neutral setting every oracle
//! comparison uses.
//!
//! Determinism rule (same as the rest of `runtime::pool`): consumption
//! order is the index order regardless of staging timing, so merges done
//! in the consumer are ordered by construction and pipeline output is
//! byte-identical to the serial loop at every depth and thread count
//! (enforced by `rust/tests/differential.rs`). Errors keep the same rule:
//! the error reported is always the lowest-index failure, whether it came
//! from `stage` or `consume`.

use super::pool::{Handoff, Pool};

/// Configuration of one prefetch pipeline run.
#[derive(Debug, Clone)]
pub struct Prefetch {
    /// Segment buffers resident at once: 1 = serial staging (neutral),
    /// 2 = double buffering (default), higher values stage further ahead.
    pub depth: usize,
}

impl Default for Prefetch {
    fn default() -> Prefetch {
        Prefetch { depth: 2 }
    }
}

impl Prefetch {
    /// Pipeline with the given depth (floored to 1).
    pub fn new(depth: usize) -> Prefetch {
        Prefetch { depth: depth.max(1) }
    }

    /// Run the pipeline over indices `0..n`.
    ///
    /// `stage(i)` prepares item `i` — on the calling thread at depth 1, on
    /// the producer task otherwise. The producer reserves a queue slot
    /// *before* staging, so across the consumed item, the queue, and the
    /// item in production at most `depth` items are ever live. `consume(i,
    /// item)` always runs on the calling thread, strictly in index order.
    /// The first `Err` (lowest index, whether staged or consumed) aborts
    /// the pipeline and is returned; a cancelled producer stops at its
    /// next reservation or hand-off.
    pub fn run<T, E, P, C>(&self, pool: &Pool, n: usize, stage: P, mut consume: C) -> Result<(), E>
    where
        T: Send,
        E: Send,
        P: Fn(usize) -> Result<T, E> + Sync,
        C: FnMut(usize, T) -> Result<(), E>,
    {
        if n == 0 {
            return Ok(());
        }
        if self.depth <= 1 || n == 1 {
            for i in 0..n {
                consume(i, stage(i)?)?;
            }
            return Ok(());
        }
        let chan: Handoff<Result<T, E>> = Handoff::bounded(self.depth - 1);
        pool.scoped(|s| {
            let chan = &chan;
            let stage = &stage;
            s.spawn(move || {
                // Close on every exit path (including an unwinding stage
                // panic) so the consumer can never block forever.
                struct CloseOnExit<'a, T>(&'a Handoff<T>);
                impl<T> Drop for CloseOnExit<'_, T> {
                    fn drop(&mut self) {
                        self.0.close();
                    }
                }
                let _close = CloseOnExit(chan);
                for i in 0..n {
                    // Reserve the slot before staging: production never
                    // runs ahead of the depth bound.
                    if !chan.reserve() {
                        return;
                    }
                    let item = stage(i);
                    let failed = item.is_err();
                    if !chan.push(item) || failed {
                        return;
                    }
                }
            });
            // Cancel on every consumer exit path (early error return AND
            // an unwinding consume panic): a producer blocked on a full
            // queue must always be released before the scope joins it.
            struct CancelOnExit<'a, T>(&'a Handoff<T>);
            impl<T> Drop for CancelOnExit<'_, T> {
                fn drop(&mut self) {
                    self.0.cancel();
                }
            }
            let _cancel = CancelOnExit(chan);
            (0..n).try_for_each(|i| {
                let item = chan.pop().expect("producer stages every index before closing");
                consume(i, item?)
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn consumes_in_index_order_at_every_depth() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 3, 8] {
            let mut seen = Vec::new();
            let ok: Result<(), ()> = Prefetch::new(depth).run(
                &pool,
                25,
                |i| Ok(i * 3),
                |i, v| {
                    seen.push((i, v));
                    Ok(())
                },
            );
            assert!(ok.is_ok());
            assert_eq!(seen, (0..25).map(|i| (i, i * 3)).collect::<Vec<_>>(), "depth={depth}");
        }
    }

    #[test]
    fn zero_and_single_item_runs() {
        let pool = Pool::new(2);
        let mut hits = 0;
        let ok: Result<(), ()> = Prefetch::new(4).run(&pool, 0, |_| Ok(()), |_, _| {
            hits += 1;
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(hits, 0);
        let ok: Result<(), ()> = Prefetch::new(4).run(&pool, 1, |i| Ok(i), |i, v| {
            hits += 1;
            assert_eq!((i, v), (0, 0));
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(hits, 1);
    }

    #[test]
    fn stage_error_reports_lowest_index_and_stops() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 4] {
            let staged = AtomicUsize::new(0);
            let mut consumed = Vec::new();
            let r = Prefetch::new(depth).run(
                &pool,
                20,
                |i| {
                    staged.fetch_add(1, Ordering::Relaxed);
                    if i == 5 {
                        Err(format!("stage {i} failed"))
                    } else {
                        Ok(i)
                    }
                },
                |i, v| {
                    consumed.push((i, v));
                    Ok(())
                },
            );
            assert_eq!(r.unwrap_err(), "stage 5 failed", "depth={depth}");
            assert_eq!(consumed, (0..5).map(|i| (i, i)).collect::<Vec<_>>());
            // The producer stops at the failed stage; nothing past it runs.
            assert!(staged.load(Ordering::Relaxed) <= 6, "depth={depth}");
        }
    }

    #[test]
    fn consume_error_cancels_producer() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 4] {
            let staged = AtomicUsize::new(0);
            let r = Prefetch::new(depth).run(
                &pool,
                100,
                |i| {
                    staged.fetch_add(1, Ordering::Relaxed);
                    Ok(i)
                },
                |i, _| if i == 3 { Err("consume 3 failed") } else { Ok(()) },
            );
            assert_eq!(r.unwrap_err(), "consume 3 failed", "depth={depth}");
            // The producer stages at most depth ahead of the failure point
            // plus the hand-off in flight, never the whole stream.
            assert!(
                staged.load(Ordering::Relaxed) <= 4 + depth + 1,
                "depth={depth}: staged {}",
                staged.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn run_ahead_never_exceeds_depth() {
        // Reserve-before-stage: live items (consumed-but-unfinished +
        // queued + in production) never exceed depth. Track via a counter
        // incremented at stage entry and decremented at consume exit.
        for depth in [2usize, 3, 5] {
            let live = AtomicUsize::new(0);
            let peak = AtomicUsize::new(0);
            let ok: Result<(), ()> = Prefetch::new(depth).run(
                &Pool::new(4),
                60,
                |i| {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    Ok(i)
                },
                |_, _| {
                    live.fetch_sub(1, Ordering::SeqCst);
                    Ok(())
                },
            );
            assert!(ok.is_ok());
            assert!(
                peak.load(Ordering::SeqCst) <= depth,
                "depth={depth}: peak {} live items",
                peak.load(Ordering::SeqCst)
            );
        }
    }

    #[test]
    #[should_panic(expected = "consumer exploded")]
    fn consume_panic_propagates_instead_of_deadlocking() {
        // Regression: a consume panic must release the blocked producer
        // (cancel-on-unwind) and propagate, not hang the join.
        let _: Result<(), ()> = Prefetch::new(2).run(
            &Pool::new(2),
            100,
            |i| Ok(i),
            |i, _| {
                if i == 3 {
                    panic!("consumer exploded");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn depth_zero_behaves_like_serial() {
        let mut seen = Vec::new();
        let ok: Result<(), ()> =
            Prefetch::new(0).run(&Pool::serial(), 5, |i| Ok(i), |_, v| {
                seen.push(v);
                Ok(())
            });
        assert!(ok.is_ok());
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
