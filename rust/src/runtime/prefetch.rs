//! Double-buffered asynchronous prefetch pipeline (paper §III-B, Phase II).
//!
//! AIRES's core system claim is that RoBW segment *transfers* overlap
//! segment *compute*: while the kernel consumes segment `i`, the staging
//! path (host-side pack + H2D transfer) prepares segment `i+1`. The
//! scheduler simulation always modelled that overlap; this module makes
//! the execution engine actually perform it.
//!
//! Shape: one **producer task** (spawned on [`Pool::scoped`]) runs the
//! `stage` closure for successive indices and hands results through a
//! bounded [`Handoff`] queue; the **calling thread** consumes them
//! strictly in index order. The queue capacity is `depth - 1` and the
//! producer reserves its slot *before* staging, so at most `depth` items
//! are live at once — the one being consumed, the queued ones, and the
//! one in production — which is exactly the headroom callers budget
//! (e.g. the `GpuMem` ledger in `gcn::oocgcn`). `depth == 2` is classic
//! double buffering; `depth == 1` degrades to the fully serial loop (no
//! producer task, no queue) and is the neutral setting every oracle
//! comparison uses.
//!
//! Determinism rule (same as the rest of `runtime::pool`): consumption
//! order is the index order regardless of staging timing, so merges done
//! in the consumer are ordered by construction and pipeline output is
//! byte-identical to the serial loop at every depth and thread count
//! (enforced by `rust/tests/differential.rs`). Errors keep the same rule:
//! the error reported is always the lowest-index failure, whether it came
//! from `stage` or `consume`.
//!
//! Fan-out ([`Prefetch::run_fanout`]) keeps the same shape but hands each
//! staged item to N independent consumers before retiring it — one staged
//! pass of the adjacency serving a whole batch of tenant queries
//! (`gcn::serve`), with the scope join acting as the "last drainer" that
//! gates slab retirement.

use super::pool::{chunk_ranges, Handoff, Pool};

/// Configuration of one prefetch pipeline run.
#[derive(Debug, Clone)]
pub struct Prefetch {
    /// Segment buffers resident at once: 1 = serial staging (neutral),
    /// 2 = double buffering (default), higher values stage further ahead.
    pub depth: usize,
}

impl Default for Prefetch {
    fn default() -> Prefetch {
        Prefetch { depth: 2 }
    }
}

impl Prefetch {
    /// Pipeline with the given depth (floored to 1).
    pub fn new(depth: usize) -> Prefetch {
        Prefetch { depth: depth.max(1) }
    }

    /// Run the pipeline over indices `0..n`.
    ///
    /// `stage(i)` prepares item `i` — on the calling thread at depth 1, on
    /// the producer task otherwise. The producer reserves a queue slot
    /// *before* staging, so across the consumed item, the queue, and the
    /// item in production at most `depth` items are ever live. `consume(i,
    /// item)` always runs on the calling thread, strictly in index order.
    /// The first `Err` (lowest index, whether staged or consumed) aborts
    /// the pipeline and is returned; a cancelled producer stops at its
    /// next reservation or hand-off.
    pub fn run<T, E, P, C>(&self, pool: &Pool, n: usize, stage: P, mut consume: C) -> Result<(), E>
    where
        T: Send,
        E: Send,
        P: Fn(usize) -> Result<T, E> + Sync,
        C: FnMut(usize, T) -> Result<(), E>,
    {
        // One pipeline implementation: `run` is the no-hand-back special
        // case of [`Self::run_recycling`] (the return lane stays empty).
        self.run_recycling::<T, (), E, _, _>(
            pool,
            n,
            |i, _| stage(i),
            |i, item| {
                consume(i, item)?;
                Ok(None)
            },
        )
        .map(|_| ())
    }

    /// [`Self::run`] with a **return channel**: the consumer hands each
    /// drained per-item buffer back to the producer, which reuses it for a
    /// later stage instead of allocating afresh — the steady-state
    /// allocation-free contract of the recycled staging path
    /// (`rust/tests/alloc_free.rs`).
    ///
    /// `stage(i, reuse)` receives a previously drained buffer when one has
    /// come back in time (`None` otherwise — at most the first
    /// `depth + 1` stages, so a warmed pipeline never misses);
    /// `consume(i, item)` returns `Ok(Some(buffer))` to send the drained
    /// buffer back, `Ok(None)` to drop it (the fresh-allocation oracle
    /// does this). On success the buffers still in flight at end-of-stream
    /// are returned so the caller can retire them to a pool; on error they
    /// are dropped with the aborted items.
    ///
    /// Determinism is unchanged from [`Self::run`]: consumption is
    /// strictly index-ordered and the reported error is the lowest-index
    /// failure. Buffer hand-back affects *allocation provenance only* —
    /// every staged item is fully overwritten before the consumer sees it,
    /// so output is byte-identical to the non-recycling pipeline
    /// (`rust/tests/differential.rs`).
    pub fn run_recycling<T, U, E, P, C>(
        &self,
        pool: &Pool,
        n: usize,
        stage: P,
        mut consume: C,
    ) -> Result<Vec<U>, E>
    where
        T: Send,
        U: Send,
        E: Send,
        P: Fn(usize, Option<U>) -> Result<T, E> + Sync,
        C: FnMut(usize, T) -> Result<Option<U>, E>,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        if self.depth <= 1 || n == 1 {
            // Serial staging: the drained buffer is carried straight into
            // the next stage — perfect recycling, zero channel machinery.
            let mut spare: Option<U> = None;
            for i in 0..n {
                let item = stage(i, spare.take())?;
                spare = consume(i, item)?;
            }
            return Ok(spare.into_iter().collect());
        }
        let chan: Handoff<Result<T, E>> = Handoff::bounded(self.depth - 1);
        // The return lane is sized to the whole stream, so the consumer's
        // push can never block: a blocked return-push while the producer
        // waits in reserve() would deadlock the pipeline. Memory stays
        // bounded by the items actually in flight (at most `depth` exist
        // at once), not by this capacity.
        let returns: Handoff<U> = Handoff::bounded(n);
        let result = pool.scoped(|s| {
            let chan = &chan;
            let returns = &returns;
            let stage = &stage;
            s.spawn(move || {
                struct CloseOnExit<'a, T>(&'a Handoff<T>);
                impl<T> Drop for CloseOnExit<'_, T> {
                    fn drop(&mut self) {
                        self.0.close();
                    }
                }
                let _close = CloseOnExit(chan);
                for i in 0..n {
                    if !chan.reserve() {
                        return;
                    }
                    // Pick up a drained buffer if the consumer has sent
                    // one back; never wait for it (staging ahead matters
                    // more than reuse on a cold pipeline).
                    let item = stage(i, returns.try_pop());
                    let failed = item.is_err();
                    if chan.push(item).is_err() || failed {
                        return;
                    }
                }
            });
            struct CancelOnExit<'a, T>(&'a Handoff<T>);
            impl<T> Drop for CancelOnExit<'_, T> {
                fn drop(&mut self) {
                    // The drained items are aborted stage results; dropping
                    // them here (outside the channel lock) is deliberate.
                    drop(self.0.cancel());
                }
            }
            let _cancel = CancelOnExit(chan);
            (0..n).try_for_each(|i| {
                let item = chan.pop().expect("producer stages every index before closing");
                if let Some(buf) = consume(i, item?)? {
                    // Capacity n: never blocks (see above). The return lane
                    // is never cancelled, so the hand-back cannot fail.
                    let given_back = returns.push(buf);
                    debug_assert!(given_back.is_ok(), "return lane is never cancelled");
                }
                Ok(())
            })
        });
        result?;
        // The producer has joined; whatever it did not reuse flows back to
        // the caller for retirement.
        let mut leftovers = Vec::new();
        while let Some(buf) = returns.try_pop() {
            leftovers.push(buf);
        }
        Ok(leftovers)
    }

    /// [`Self::run_recycling`] with **fan-out**: every staged item is
    /// handed to *each* of the `consumers` (shared, by reference) before
    /// `retire` sees it — one staged pass of the stream serving N
    /// consumers, the multi-tenant batched-inference shape of
    /// `gcn::serve`.
    ///
    /// Consumers are independent: consumer `t` observes exactly the
    /// `(i, &item)` sequence it would observe running the stream alone, so
    /// a per-consumer merge that is deterministic solo stays byte-identical
    /// under fan-out. When the pool has more than one worker and there is
    /// more than one consumer, consumers run concurrently on staged item
    /// `i` (chunked by [`super::pool::chunk_ranges`], each chunk walking
    /// its consumers in index order); with a serial pool or a single
    /// consumer, the fan-out is a plain in-order loop with no extra
    /// machinery.
    ///
    /// `retire(i, item)` runs on the calling thread strictly after every
    /// consumer has finished with item `i` — the scope join is the
    /// "last drainer", so retiring the item's buffer (e.g. reclaiming a
    /// segment slab into the return lane by returning `Ok(Some(buf))`) can
    /// never race a consumer still reading it. Error priority is
    /// deterministic: the reported error is the lowest-index failure, and
    /// for a given item the lowest-index consumer's error wins over higher
    /// consumers and over `retire`.
    pub fn run_fanout<T, U, E, P, C, R>(
        &self,
        pool: &Pool,
        n: usize,
        stage: P,
        consumers: &mut [C],
        mut retire: R,
    ) -> Result<Vec<U>, E>
    where
        T: Send + Sync,
        U: Send,
        E: Send,
        P: Fn(usize, Option<U>) -> Result<T, E> + Sync,
        C: FnMut(usize, &T) -> Result<(), E> + Send,
        R: FnMut(usize, T) -> Result<Option<U>, E>,
    {
        let serial_fanout = pool.threads() <= 1 || consumers.len() <= 1;
        // Chunking and error slots are fixed for the whole stream and
        // allocated once up front — the steady state stays allocation-free
        // on the serial path and allocates only for thread spawns on the
        // parallel one.
        let ranges = chunk_ranges(consumers.len(), pool.threads());
        let mut errs: Vec<Option<E>> = (0..ranges.len()).map(|_| None).collect();
        self.run_recycling(
            pool,
            n,
            stage,
            |i, item: T| {
                if serial_fanout {
                    for c in consumers.iter_mut() {
                        c(i, &item)?;
                    }
                } else {
                    pool.scoped(|s| {
                        let mut rest: &mut [C] = consumers;
                        let mut err_rest: &mut [Option<E>] = &mut errs;
                        for r in &ranges {
                            let (chunk, tail) = rest.split_at_mut(r.len());
                            rest = tail;
                            let (slot, etail) = err_rest.split_at_mut(1);
                            err_rest = etail;
                            let item = &item;
                            s.spawn(move || {
                                for c in chunk.iter_mut() {
                                    if let Err(e) = c(i, item) {
                                        slot[0] = Some(e);
                                        return;
                                    }
                                }
                            });
                        }
                    });
                    // Chunks cover contiguous ascending consumer ranges and
                    // each stops at its first failure, so the first
                    // non-empty slot holds the lowest-index consumer error.
                    for slot in errs.iter_mut() {
                        if let Some(e) = slot.take() {
                            return Err(e);
                        }
                    }
                }
                retire(i, item)
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn consumes_in_index_order_at_every_depth() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 3, 8] {
            let mut seen = Vec::new();
            let ok: Result<(), ()> = Prefetch::new(depth).run(
                &pool,
                25,
                |i| Ok(i * 3),
                |i, v| {
                    seen.push((i, v));
                    Ok(())
                },
            );
            assert!(ok.is_ok());
            assert_eq!(seen, (0..25).map(|i| (i, i * 3)).collect::<Vec<_>>(), "depth={depth}");
        }
    }

    #[test]
    fn zero_and_single_item_runs() {
        let pool = Pool::new(2);
        let mut hits = 0;
        let ok: Result<(), ()> = Prefetch::new(4).run(&pool, 0, |_| Ok(()), |_, _| {
            hits += 1;
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(hits, 0);
        let ok: Result<(), ()> = Prefetch::new(4).run(&pool, 1, |i| Ok(i), |i, v| {
            hits += 1;
            assert_eq!((i, v), (0, 0));
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(hits, 1);
    }

    #[test]
    fn stage_error_reports_lowest_index_and_stops() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 4] {
            let staged = AtomicUsize::new(0);
            let mut consumed = Vec::new();
            let r = Prefetch::new(depth).run(
                &pool,
                20,
                |i| {
                    staged.fetch_add(1, Ordering::Relaxed);
                    if i == 5 {
                        Err(format!("stage {i} failed"))
                    } else {
                        Ok(i)
                    }
                },
                |i, v| {
                    consumed.push((i, v));
                    Ok(())
                },
            );
            assert_eq!(r.unwrap_err(), "stage 5 failed", "depth={depth}");
            assert_eq!(consumed, (0..5).map(|i| (i, i)).collect::<Vec<_>>());
            // The producer stops at the failed stage; nothing past it runs.
            assert!(staged.load(Ordering::Relaxed) <= 6, "depth={depth}");
        }
    }

    #[test]
    fn consume_error_cancels_producer() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 4] {
            let staged = AtomicUsize::new(0);
            let r = Prefetch::new(depth).run(
                &pool,
                100,
                |i| {
                    staged.fetch_add(1, Ordering::Relaxed);
                    Ok(i)
                },
                |i, _| if i == 3 { Err("consume 3 failed") } else { Ok(()) },
            );
            assert_eq!(r.unwrap_err(), "consume 3 failed", "depth={depth}");
            // The producer stages at most depth ahead of the failure point
            // plus the hand-off in flight, never the whole stream.
            assert!(
                staged.load(Ordering::Relaxed) <= 4 + depth + 1,
                "depth={depth}: staged {}",
                staged.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn run_ahead_never_exceeds_depth() {
        // Reserve-before-stage: live items (consumed-but-unfinished +
        // queued + in production) never exceed depth. Track via a counter
        // incremented at stage entry and decremented at consume exit.
        for depth in [2usize, 3, 5] {
            let live = AtomicUsize::new(0);
            let peak = AtomicUsize::new(0);
            let ok: Result<(), ()> = Prefetch::new(depth).run(
                &Pool::new(4),
                60,
                |i| {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    Ok(i)
                },
                |_, _| {
                    live.fetch_sub(1, Ordering::SeqCst);
                    Ok(())
                },
            );
            assert!(ok.is_ok());
            assert!(
                peak.load(Ordering::SeqCst) <= depth,
                "depth={depth}: peak {} live items",
                peak.load(Ordering::SeqCst)
            );
        }
    }

    #[test]
    #[should_panic(expected = "consumer exploded")]
    fn consume_panic_propagates_instead_of_deadlocking() {
        // Regression: a consume panic must release the blocked producer
        // (cancel-on-unwind) and propagate, not hang the join.
        let _: Result<(), ()> = Prefetch::new(2).run(
            &Pool::new(2),
            100,
            |i| Ok(i),
            |i, _| {
                if i == 3 {
                    panic!("consumer exploded");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn recycling_preserves_order_and_returns_leftovers() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 3, 8] {
            let mut seen = Vec::new();
            let leftovers: Vec<u64> = Prefetch::new(depth)
                .run_recycling::<usize, u64, (), _, _>(
                    &pool,
                    30,
                    |i, _reuse| Ok(i * 2),
                    |i, v| {
                        seen.push((i, v));
                        Ok(Some(i as u64))
                    },
                )
                .unwrap();
            assert_eq!(seen, (0..30).map(|i| (i, i * 2)).collect::<Vec<_>>(), "depth={depth}");
            // Every buffer the producer did not pick up comes back out.
            assert!(!leftovers.is_empty(), "depth={depth}: last buffer is always left over");
        }
    }

    #[test]
    fn serial_recycling_reuses_every_drained_buffer() {
        // Depth 1: stage i+1 must receive exactly the buffer drained by
        // consume i — the strict per-segment reuse the allocation-free
        // test builds on.
        let reused = AtomicUsize::new(0);
        let leftovers = Prefetch::new(1)
            .run_recycling::<usize, u32, (), _, _>(
                &Pool::serial(),
                20,
                |i, reuse| {
                    match reuse {
                        Some(tag) => {
                            assert_eq!(tag as usize, i - 1, "buffer from the previous drain");
                            reused.fetch_add(1, Ordering::Relaxed);
                        }
                        None => assert_eq!(i, 0, "only the first stage starts cold"),
                    }
                    Ok(i)
                },
                |i, _| Ok(Some(i as u32)),
            )
            .unwrap();
        assert_eq!(reused.load(Ordering::Relaxed), 19);
        assert_eq!(leftovers, vec![19]);
    }

    #[test]
    fn pipelined_recycling_misses_at_most_depth_plus_one_stages() {
        // Reuse can lag the drain by the pipeline depth, never more: cold
        // stages (no recycled buffer offered) are bounded by depth + 1.
        for depth in [2usize, 3, 5] {
            let cold = AtomicUsize::new(0);
            let ok = Prefetch::new(depth).run_recycling::<usize, u8, (), _, _>(
                &Pool::new(4),
                100,
                |i, reuse| {
                    if reuse.is_none() {
                        cold.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(i)
                },
                |_, _| Ok(Some(0)),
            );
            assert!(ok.is_ok());
            assert!(
                cold.load(Ordering::Relaxed) <= depth + 1,
                "depth={depth}: {} cold stages",
                cold.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn recycling_consume_error_reports_lowest_index() {
        for depth in [1usize, 2, 4] {
            let r = Prefetch::new(depth).run_recycling::<usize, u8, &str, _, _>(
                &Pool::new(4),
                50,
                |i, _| Ok(i),
                |i, _| if i == 7 { Err("consume 7 failed") } else { Ok(Some(0)) },
            );
            assert_eq!(r.unwrap_err(), "consume 7 failed", "depth={depth}");
        }
    }

    #[test]
    fn recycling_with_no_returns_degrades_to_plain_run() {
        // Consume returning None everywhere is the fresh-allocation
        // oracle: stage must then never see a recycled buffer.
        for depth in [1usize, 2, 4] {
            let leftovers = Prefetch::new(depth)
                .run_recycling::<usize, u8, (), _, _>(
                    &Pool::new(2),
                    25,
                    |i, reuse| {
                        assert!(reuse.is_none(), "depth={depth}: nothing was ever returned");
                        Ok(i)
                    },
                    |_, _| Ok(None),
                )
                .unwrap();
            assert!(leftovers.is_empty(), "depth={depth}");
        }
    }

    #[test]
    fn fanout_gives_every_consumer_the_full_stream_in_order() {
        for threads in [1usize, 4] {
            for depth in [1usize, 2, 4] {
                let pool = Pool::new(threads);
                let mut logs: Vec<Vec<(usize, usize)>> = vec![Vec::new(); 5];
                let mut consumers: Vec<_> = logs
                    .iter_mut()
                    .map(|log| {
                        move |i: usize, v: &usize| {
                            log.push((i, *v));
                            Ok(())
                        }
                    })
                    .collect();
                let mut retired = Vec::new();
                let leftovers = Prefetch::new(depth)
                    .run_fanout::<usize, u8, (), _, _, _>(
                        &pool,
                        12,
                        |i, _| Ok(i * 7),
                        &mut consumers,
                        |i, item| {
                            retired.push((i, item));
                            Ok(None)
                        },
                    )
                    .unwrap();
                assert!(leftovers.is_empty(), "no buffers were handed back");
                drop(consumers);
                let want: Vec<(usize, usize)> = (0..12).map(|i| (i, i * 7)).collect();
                for (t, log) in logs.iter().enumerate() {
                    assert_eq!(
                        log, &want,
                        "threads={threads} depth={depth}: consumer {t} must see \
                         exactly its solo stream"
                    );
                }
                assert_eq!(retired, want, "retire sees every item once, in order");
            }
        }
    }

    #[test]
    fn fanout_retires_only_after_every_consumer_drained() {
        // The scope join is the last drainer: when retire(i, ..) runs, all
        // N consumers must have finished item i — the invariant that makes
        // slab reclamation safe under fan-out.
        const TENANTS: usize = 6;
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            let drained = AtomicUsize::new(0);
            let mut consumers: Vec<_> = (0..TENANTS)
                .map(|_| {
                    |_: usize, _: &usize| {
                        drained.fetch_add(1, Ordering::SeqCst);
                        Ok(())
                    }
                })
                .collect();
            let ok = Prefetch::new(3).run_fanout::<usize, u8, String, _, _, _>(
                &pool,
                10,
                |i, _| Ok(i),
                &mut consumers,
                |i, _| {
                    let seen = drained.load(Ordering::SeqCst);
                    if seen == (i + 1) * TENANTS {
                        Ok(None)
                    } else {
                        Err(format!("item {i} retired after only {seen} drains"))
                    }
                },
            );
            assert!(ok.is_ok(), "threads={threads}: {ok:?}");
        }
    }

    #[test]
    fn fanout_recycles_retired_buffers_into_stage() {
        let cold = AtomicUsize::new(0);
        let mut consumers: Vec<_> = (0..3).map(|_| |_: usize, _: &usize| Ok(())).collect();
        let leftovers = Prefetch::new(2)
            .run_fanout::<usize, u64, (), _, _, _>(
                &Pool::new(4),
                40,
                |i, reuse| {
                    if reuse.is_none() {
                        cold.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(i)
                },
                &mut consumers,
                |i, _| Ok(Some(i as u64)),
            )
            .unwrap();
        assert!(!leftovers.is_empty(), "the last drained buffer always flows back");
        assert!(
            cold.load(Ordering::Relaxed) <= 3,
            "warmed fan-out reuses retired buffers: {} cold stages",
            cold.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn fanout_error_prefers_lowest_item_then_lowest_consumer() {
        for threads in [1usize, 4] {
            let pool = Pool::new(threads);
            // Consumers 1 and 3 both fail on item 4; consumer 2 fails later
            // (item 6). The reported error must be consumer 1's — lowest
            // consumer on the lowest failing item — at every thread count.
            let mut consumers: Vec<_> = (0..5)
                .map(|t| {
                    move |i: usize, _: &usize| {
                        if ((t == 1 || t == 3) && i == 4) || (t == 2 && i == 6) {
                            Err(format!("tenant {t} failed on item {i}"))
                        } else {
                            Ok(())
                        }
                    }
                })
                .collect();
            let err = Prefetch::new(2)
                .run_fanout::<usize, u8, String, _, _, _>(
                    &pool,
                    20,
                    |i, _| Ok(i),
                    &mut consumers,
                    |_, _| Ok(None),
                )
                .unwrap_err();
            assert_eq!(err, "tenant 1 failed on item 4", "threads={threads}");
        }
    }

    #[test]
    fn consumer_panic_payload_surfaces_not_a_poison_error() {
        // Poison-tolerance regression: a consumer panicking mid-stream
        // unwinds across the hand-off channel's mutexes. Every lock on
        // that path recovers the guard from a `PoisonError`, so the caller
        // catches the *original* payload — not a secondary poison panic
        // from the producer touching the channel afterwards.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _: Result<(), ()> = Prefetch::new(3).run(
                &Pool::new(4),
                50,
                |i| Ok(i),
                |i, _| {
                    if i == 7 {
                        panic!("tenant merge exploded");
                    }
                    Ok(())
                },
            );
        }))
        .expect_err("the consumer panic must propagate");
        assert_eq!(
            caught.downcast_ref::<&str>().copied(),
            Some("tenant merge exploded"),
            "original payload must surface"
        );
        // The machinery is reusable after the abort: a fresh run on the
        // same pool completes normally.
        let pool = Pool::new(4);
        let mut seen = Vec::new();
        let ok: Result<(), ()> = Prefetch::new(3).run(&pool, 10, |i| Ok(i), |_, v| {
            seen.push(v);
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn depth_zero_behaves_like_serial() {
        let mut seen = Vec::new();
        let ok: Result<(), ()> =
            Prefetch::new(0).run(&Pool::serial(), 5, |i| Ok(i), |_, v| {
                seen.push(v);
                Ok(())
            });
        assert!(ok.is_ok());
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
