//! Double-buffered asynchronous prefetch pipeline (paper §III-B, Phase II).
//!
//! AIRES's core system claim is that RoBW segment *transfers* overlap
//! segment *compute*: while the kernel consumes segment `i`, the staging
//! path (host-side pack + H2D transfer) prepares segment `i+1`. The
//! scheduler simulation always modelled that overlap; this module makes
//! the execution engine actually perform it.
//!
//! Shape: one **producer task** (spawned on [`Pool::scoped`]) runs the
//! `stage` closure for successive indices and hands results through a
//! bounded [`Handoff`] queue; the **calling thread** consumes them
//! strictly in index order. The queue capacity is `depth - 1` and the
//! producer reserves its slot *before* staging, so at most `depth` items
//! are live at once — the one being consumed, the queued ones, and the
//! one in production — which is exactly the headroom callers budget
//! (e.g. the `GpuMem` ledger in `gcn::oocgcn`). `depth == 2` is classic
//! double buffering; `depth == 1` degrades to the fully serial loop (no
//! producer task, no queue) and is the neutral setting every oracle
//! comparison uses.
//!
//! Determinism rule (same as the rest of `runtime::pool`): consumption
//! order is the index order regardless of staging timing, so merges done
//! in the consumer are ordered by construction and pipeline output is
//! byte-identical to the serial loop at every depth and thread count
//! (enforced by `rust/tests/differential.rs`). Errors keep the same rule:
//! the error reported is always the lowest-index failure, whether it came
//! from `stage` or `consume`.

use super::pool::{Handoff, Pool};

/// Configuration of one prefetch pipeline run.
#[derive(Debug, Clone)]
pub struct Prefetch {
    /// Segment buffers resident at once: 1 = serial staging (neutral),
    /// 2 = double buffering (default), higher values stage further ahead.
    pub depth: usize,
}

impl Default for Prefetch {
    fn default() -> Prefetch {
        Prefetch { depth: 2 }
    }
}

impl Prefetch {
    /// Pipeline with the given depth (floored to 1).
    pub fn new(depth: usize) -> Prefetch {
        Prefetch { depth: depth.max(1) }
    }

    /// Run the pipeline over indices `0..n`.
    ///
    /// `stage(i)` prepares item `i` — on the calling thread at depth 1, on
    /// the producer task otherwise. The producer reserves a queue slot
    /// *before* staging, so across the consumed item, the queue, and the
    /// item in production at most `depth` items are ever live. `consume(i,
    /// item)` always runs on the calling thread, strictly in index order.
    /// The first `Err` (lowest index, whether staged or consumed) aborts
    /// the pipeline and is returned; a cancelled producer stops at its
    /// next reservation or hand-off.
    pub fn run<T, E, P, C>(&self, pool: &Pool, n: usize, stage: P, mut consume: C) -> Result<(), E>
    where
        T: Send,
        E: Send,
        P: Fn(usize) -> Result<T, E> + Sync,
        C: FnMut(usize, T) -> Result<(), E>,
    {
        // One pipeline implementation: `run` is the no-hand-back special
        // case of [`Self::run_recycling`] (the return lane stays empty).
        self.run_recycling::<T, (), E, _, _>(
            pool,
            n,
            |i, _| stage(i),
            |i, item| {
                consume(i, item)?;
                Ok(None)
            },
        )
        .map(|_| ())
    }

    /// [`Self::run`] with a **return channel**: the consumer hands each
    /// drained per-item buffer back to the producer, which reuses it for a
    /// later stage instead of allocating afresh — the steady-state
    /// allocation-free contract of the recycled staging path
    /// (`rust/tests/alloc_free.rs`).
    ///
    /// `stage(i, reuse)` receives a previously drained buffer when one has
    /// come back in time (`None` otherwise — at most the first
    /// `depth + 1` stages, so a warmed pipeline never misses);
    /// `consume(i, item)` returns `Ok(Some(buffer))` to send the drained
    /// buffer back, `Ok(None)` to drop it (the fresh-allocation oracle
    /// does this). On success the buffers still in flight at end-of-stream
    /// are returned so the caller can retire them to a pool; on error they
    /// are dropped with the aborted items.
    ///
    /// Determinism is unchanged from [`Self::run`]: consumption is
    /// strictly index-ordered and the reported error is the lowest-index
    /// failure. Buffer hand-back affects *allocation provenance only* —
    /// every staged item is fully overwritten before the consumer sees it,
    /// so output is byte-identical to the non-recycling pipeline
    /// (`rust/tests/differential.rs`).
    pub fn run_recycling<T, U, E, P, C>(
        &self,
        pool: &Pool,
        n: usize,
        stage: P,
        mut consume: C,
    ) -> Result<Vec<U>, E>
    where
        T: Send,
        U: Send,
        E: Send,
        P: Fn(usize, Option<U>) -> Result<T, E> + Sync,
        C: FnMut(usize, T) -> Result<Option<U>, E>,
    {
        if n == 0 {
            return Ok(Vec::new());
        }
        if self.depth <= 1 || n == 1 {
            // Serial staging: the drained buffer is carried straight into
            // the next stage — perfect recycling, zero channel machinery.
            let mut spare: Option<U> = None;
            for i in 0..n {
                let item = stage(i, spare.take())?;
                spare = consume(i, item)?;
            }
            return Ok(spare.into_iter().collect());
        }
        let chan: Handoff<Result<T, E>> = Handoff::bounded(self.depth - 1);
        // The return lane is sized to the whole stream, so the consumer's
        // push can never block: a blocked return-push while the producer
        // waits in reserve() would deadlock the pipeline. Memory stays
        // bounded by the items actually in flight (at most `depth` exist
        // at once), not by this capacity.
        let returns: Handoff<U> = Handoff::bounded(n);
        let result = pool.scoped(|s| {
            let chan = &chan;
            let returns = &returns;
            let stage = &stage;
            s.spawn(move || {
                struct CloseOnExit<'a, T>(&'a Handoff<T>);
                impl<T> Drop for CloseOnExit<'_, T> {
                    fn drop(&mut self) {
                        self.0.close();
                    }
                }
                let _close = CloseOnExit(chan);
                for i in 0..n {
                    if !chan.reserve() {
                        return;
                    }
                    // Pick up a drained buffer if the consumer has sent
                    // one back; never wait for it (staging ahead matters
                    // more than reuse on a cold pipeline).
                    let item = stage(i, returns.try_pop());
                    let failed = item.is_err();
                    if !chan.push(item) || failed {
                        return;
                    }
                }
            });
            struct CancelOnExit<'a, T>(&'a Handoff<T>);
            impl<T> Drop for CancelOnExit<'_, T> {
                fn drop(&mut self) {
                    self.0.cancel();
                }
            }
            let _cancel = CancelOnExit(chan);
            (0..n).try_for_each(|i| {
                let item = chan.pop().expect("producer stages every index before closing");
                if let Some(buf) = consume(i, item?)? {
                    // Capacity n: never blocks (see above).
                    returns.push(buf);
                }
                Ok(())
            })
        });
        result?;
        // The producer has joined; whatever it did not reuse flows back to
        // the caller for retirement.
        let mut leftovers = Vec::new();
        while let Some(buf) = returns.try_pop() {
            leftovers.push(buf);
        }
        Ok(leftovers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn consumes_in_index_order_at_every_depth() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 3, 8] {
            let mut seen = Vec::new();
            let ok: Result<(), ()> = Prefetch::new(depth).run(
                &pool,
                25,
                |i| Ok(i * 3),
                |i, v| {
                    seen.push((i, v));
                    Ok(())
                },
            );
            assert!(ok.is_ok());
            assert_eq!(seen, (0..25).map(|i| (i, i * 3)).collect::<Vec<_>>(), "depth={depth}");
        }
    }

    #[test]
    fn zero_and_single_item_runs() {
        let pool = Pool::new(2);
        let mut hits = 0;
        let ok: Result<(), ()> = Prefetch::new(4).run(&pool, 0, |_| Ok(()), |_, _| {
            hits += 1;
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(hits, 0);
        let ok: Result<(), ()> = Prefetch::new(4).run(&pool, 1, |i| Ok(i), |i, v| {
            hits += 1;
            assert_eq!((i, v), (0, 0));
            Ok(())
        });
        assert!(ok.is_ok());
        assert_eq!(hits, 1);
    }

    #[test]
    fn stage_error_reports_lowest_index_and_stops() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 4] {
            let staged = AtomicUsize::new(0);
            let mut consumed = Vec::new();
            let r = Prefetch::new(depth).run(
                &pool,
                20,
                |i| {
                    staged.fetch_add(1, Ordering::Relaxed);
                    if i == 5 {
                        Err(format!("stage {i} failed"))
                    } else {
                        Ok(i)
                    }
                },
                |i, v| {
                    consumed.push((i, v));
                    Ok(())
                },
            );
            assert_eq!(r.unwrap_err(), "stage 5 failed", "depth={depth}");
            assert_eq!(consumed, (0..5).map(|i| (i, i)).collect::<Vec<_>>());
            // The producer stops at the failed stage; nothing past it runs.
            assert!(staged.load(Ordering::Relaxed) <= 6, "depth={depth}");
        }
    }

    #[test]
    fn consume_error_cancels_producer() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 4] {
            let staged = AtomicUsize::new(0);
            let r = Prefetch::new(depth).run(
                &pool,
                100,
                |i| {
                    staged.fetch_add(1, Ordering::Relaxed);
                    Ok(i)
                },
                |i, _| if i == 3 { Err("consume 3 failed") } else { Ok(()) },
            );
            assert_eq!(r.unwrap_err(), "consume 3 failed", "depth={depth}");
            // The producer stages at most depth ahead of the failure point
            // plus the hand-off in flight, never the whole stream.
            assert!(
                staged.load(Ordering::Relaxed) <= 4 + depth + 1,
                "depth={depth}: staged {}",
                staged.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn run_ahead_never_exceeds_depth() {
        // Reserve-before-stage: live items (consumed-but-unfinished +
        // queued + in production) never exceed depth. Track via a counter
        // incremented at stage entry and decremented at consume exit.
        for depth in [2usize, 3, 5] {
            let live = AtomicUsize::new(0);
            let peak = AtomicUsize::new(0);
            let ok: Result<(), ()> = Prefetch::new(depth).run(
                &Pool::new(4),
                60,
                |i| {
                    let now = live.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    Ok(i)
                },
                |_, _| {
                    live.fetch_sub(1, Ordering::SeqCst);
                    Ok(())
                },
            );
            assert!(ok.is_ok());
            assert!(
                peak.load(Ordering::SeqCst) <= depth,
                "depth={depth}: peak {} live items",
                peak.load(Ordering::SeqCst)
            );
        }
    }

    #[test]
    #[should_panic(expected = "consumer exploded")]
    fn consume_panic_propagates_instead_of_deadlocking() {
        // Regression: a consume panic must release the blocked producer
        // (cancel-on-unwind) and propagate, not hang the join.
        let _: Result<(), ()> = Prefetch::new(2).run(
            &Pool::new(2),
            100,
            |i| Ok(i),
            |i, _| {
                if i == 3 {
                    panic!("consumer exploded");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn recycling_preserves_order_and_returns_leftovers() {
        let pool = Pool::new(4);
        for depth in [1usize, 2, 3, 8] {
            let mut seen = Vec::new();
            let leftovers: Vec<u64> = Prefetch::new(depth)
                .run_recycling::<usize, u64, (), _, _>(
                    &pool,
                    30,
                    |i, _reuse| Ok(i * 2),
                    |i, v| {
                        seen.push((i, v));
                        Ok(Some(i as u64))
                    },
                )
                .unwrap();
            assert_eq!(seen, (0..30).map(|i| (i, i * 2)).collect::<Vec<_>>(), "depth={depth}");
            // Every buffer the producer did not pick up comes back out.
            assert!(!leftovers.is_empty(), "depth={depth}: last buffer is always left over");
        }
    }

    #[test]
    fn serial_recycling_reuses_every_drained_buffer() {
        // Depth 1: stage i+1 must receive exactly the buffer drained by
        // consume i — the strict per-segment reuse the allocation-free
        // test builds on.
        let reused = AtomicUsize::new(0);
        let leftovers = Prefetch::new(1)
            .run_recycling::<usize, u32, (), _, _>(
                &Pool::serial(),
                20,
                |i, reuse| {
                    match reuse {
                        Some(tag) => {
                            assert_eq!(tag as usize, i - 1, "buffer from the previous drain");
                            reused.fetch_add(1, Ordering::Relaxed);
                        }
                        None => assert_eq!(i, 0, "only the first stage starts cold"),
                    }
                    Ok(i)
                },
                |i, _| Ok(Some(i as u32)),
            )
            .unwrap();
        assert_eq!(reused.load(Ordering::Relaxed), 19);
        assert_eq!(leftovers, vec![19]);
    }

    #[test]
    fn pipelined_recycling_misses_at_most_depth_plus_one_stages() {
        // Reuse can lag the drain by the pipeline depth, never more: cold
        // stages (no recycled buffer offered) are bounded by depth + 1.
        for depth in [2usize, 3, 5] {
            let cold = AtomicUsize::new(0);
            let ok = Prefetch::new(depth).run_recycling::<usize, u8, (), _, _>(
                &Pool::new(4),
                100,
                |i, reuse| {
                    if reuse.is_none() {
                        cold.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(i)
                },
                |_, _| Ok(Some(0)),
            );
            assert!(ok.is_ok());
            assert!(
                cold.load(Ordering::Relaxed) <= depth + 1,
                "depth={depth}: {} cold stages",
                cold.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn recycling_consume_error_reports_lowest_index() {
        for depth in [1usize, 2, 4] {
            let r = Prefetch::new(depth).run_recycling::<usize, u8, &str, _, _>(
                &Pool::new(4),
                50,
                |i, _| Ok(i),
                |i, _| if i == 7 { Err("consume 7 failed") } else { Ok(Some(0)) },
            );
            assert_eq!(r.unwrap_err(), "consume 7 failed", "depth={depth}");
        }
    }

    #[test]
    fn recycling_with_no_returns_degrades_to_plain_run() {
        // Consume returning None everywhere is the fresh-allocation
        // oracle: stage must then never see a recycled buffer.
        for depth in [1usize, 2, 4] {
            let leftovers = Prefetch::new(depth)
                .run_recycling::<usize, u8, (), _, _>(
                    &Pool::new(2),
                    25,
                    |i, reuse| {
                        assert!(reuse.is_none(), "depth={depth}: nothing was ever returned");
                        Ok(i)
                    },
                    |_, _| Ok(None),
                )
                .unwrap();
            assert!(leftovers.is_empty(), "depth={depth}");
        }
    }

    #[test]
    fn depth_zero_behaves_like_serial() {
        let mut seen = Vec::new();
        let ok: Result<(), ()> =
            Prefetch::new(0).run(&Pool::serial(), 5, |i| Ok(i), |_, v| {
                seen.push(v);
                Ok(())
            });
        assert!(ok.is_ok());
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
