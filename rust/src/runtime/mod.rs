//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the rust request path. Python never runs here.
//!
//! Pipeline: `make artifacts` (build time, once) lowers the L2 model to HLO
//! *text* + a `manifest.json`; at startup [`Executor`] parses the manifest
//! ([`artifacts`]), compiles each module on the PJRT CPU client, and serves
//! typed executions. [`tile_exec`] adapts dynamic sparse data to the fixed
//! artifact shapes (padding + batching) — the rust half of the tiling
//! contract with `python/compile/kernels/bsr_spmm.py`.

pub mod artifacts;
pub mod chaos;
pub mod executor;
pub mod heal;
pub mod pool;
pub mod prefetch;
pub mod recycle;
pub mod segstore;
pub mod tile_exec;

pub use artifacts::{Manifest, TensorSpec};
pub use chaos::{FaultKind, FaultPlan, FaultSpec, Tier};
pub use executor::Executor;
pub use heal::{HealPolicy, HealStats};
pub use pool::Pool;
pub use prefetch::Prefetch;
pub use recycle::{BufferPool, RecycleStats};
pub use segstore::{
    CacheStats, MappedPanelChunks, MappedSegment, PanelRead, PanelSrc, PanelStore, SegmentRead,
    SegmentStore,
};
pub use tile_exec::BsrSpmmExec;

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$AIRES_ARTIFACTS`, else ./artifacts,
/// else ../artifacts (when running from a subdirectory).
pub fn find_artifact_dir() -> Option<std::path::PathBuf> {
    if let Ok(p) = std::env::var("AIRES_ARTIFACTS") {
        let p = std::path::PathBuf::from(p);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    for cand in [DEFAULT_ARTIFACT_DIR, "../artifacts", "../../artifacts"] {
        let p = std::path::PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return Some(p);
        }
    }
    None
}
