//! Disk-backed segment store with a bounded host-RAM cache tier.
//!
//! This is the paper's tiered memory system made concrete for the executed
//! pipeline: planned RoBW segments are **spilled** to a directory in the
//! [`sparse::segio`](crate::sparse::segio) format (the NVMe tier), and
//! **served** back through a bounded host-memory cache (the host-RAM tier)
//! that sits between disk and the [`GpuMem`](crate::memsim::GpuMem) ledger
//! (the device tier). A cache hit is a host-memcpy-priced read; a miss is
//! a real file read, checksum-verified before any compute sees the bytes.
//!
//! Eviction is deterministic LRU: the cache's state depends only on the
//! sequence of `read` calls, never on timing. The prefetch producer is a
//! single task reading segments strictly in index order, so hit/miss
//! patterns — and therefore [`CacheStats`] — are identical at every
//! prefetch depth and thread count (asserted in
//! `rust/tests/differential.rs`).
//!
//! Resident segments are `Arc`-shared: a cache hit hands out a reference
//! to the resident matrix instead of deep-copying its three sections (the
//! defensive clone the pre-recycling path paid on every warm read), and a
//! miss that lands in the cache shares the freshly decoded buffers the
//! same way. Reads that bypass the cache return an owned [`Csr`] the
//! consumer can hand back to the staging pipeline's
//! [`BufferPool`](crate::runtime::recycle::BufferPool) — see
//! [`SegmentRead`].

use crate::partition::robw::{calc_mem, materialize, RobwSegment};
use crate::runtime::recycle::BufferPool;
use crate::sparse::segio::{self, Fnv64, SegEncoding, SegioError};
use crate::sparse::spmm::{Dense, RowSrc};
use crate::sparse::{Csr, SegView};
use mmap::Mmap;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: cache and panel state are valid at every
/// instruction boundary, so a panicking reader must not convert every
/// later `stats()`/`read_reusing` call into a `PoisonError` panic that
/// masks the original failure.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Host-cache capacity meaning "no bound": every decoded segment stays
/// resident (the whole matrix ends up in host RAM, like the in-memory
/// path but with a verified disk round trip behind it).
pub const UNBOUNDED_CACHE: u64 = u64::MAX;

/// One spilled segment's metadata (the store's in-memory manifest entry).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SegmentMeta {
    /// First row of the segment (inclusive) in the source matrix.
    pub row_lo: usize,
    /// One past the last row (exclusive).
    pub row_hi: usize,
    /// Stored non-zeros.
    pub nnz: usize,
    /// The planner's `calcMem` footprint (ledger bytes while staged).
    pub plan_bytes: u64,
    /// Encoded file size on disk (header + sections).
    pub file_bytes: u64,
    /// On-disk record kind ([`segio::KIND_CSR`] or
    /// [`segio::KIND_CSR_PACKED`]) — the per-segment encoding the spill
    /// chose, preserved across quarantine rebuilds.
    pub kind: u32,
    /// Segment file path.
    pub path: PathBuf,
}

/// Counters of one store's serving behaviour since creation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Reads served from the host-RAM tier.
    pub hits: usize,
    /// Reads that went to disk.
    pub misses: usize,
    /// Segments evicted to keep the cache within its byte bound.
    pub evictions: usize,
    /// Total bytes read from disk (measured, not planned).
    pub disk_bytes: u64,
    /// Decoded bytes currently resident in the host tier.
    pub resident_bytes: u64,
}

/// What one [`SegmentStore::read`] actually did — the measured I/O the
/// staging layer charges (instead of the planner-estimate sleeps the
/// in-memory path simulates).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReadOrigin {
    /// Bytes read from disk for this call (0 on a cache hit).
    pub disk_bytes: u64,
    /// Whether the host-RAM tier served the read.
    pub cache_hit: bool,
}

/// A served segment: an owned matrix (cache-bypassing read — its buffers
/// can be handed back to the staging pipeline's recycle pool), a shared
/// reference to a cache-resident matrix (no copy was made; the bytes
/// belong to the host tier), or a zero-copy mapping whose O(nnz) sections
/// are served straight from the page cache ([`SegmentStore::read_mapped`]).
///
/// Compute paths should consume reads through [`SegmentRead::view`],
/// which every variant supports without a copy. [`SegmentRead::csr`] (and
/// `Deref<Target = Csr>`) exist for the copy-decode variants only and
/// panic on `Mapped` — a mapped read has no materialized `Csr` to lend.
#[derive(Debug)]
pub enum SegmentRead {
    /// Owned decoded segment; [`SegmentRead::reclaim`] yields its buffers.
    Owned(Csr),
    /// Cache-resident segment, shared without a defensive clone.
    Shared(Arc<Csr>),
    /// mmap-backed segment; colidx/vals stay in the page cache.
    Mapped(MappedSegment),
}

impl SegmentRead {
    /// The decoded matrix, however it is held.
    ///
    /// # Panics
    ///
    /// On [`SegmentRead::Mapped`] — use [`SegmentRead::view`], which all
    /// variants serve without materializing.
    pub fn csr(&self) -> &Csr {
        match self {
            SegmentRead::Owned(m) => m,
            SegmentRead::Shared(m) => m,
            SegmentRead::Mapped(_) => {
                panic!("mapped segment read holds no materialized Csr; use SegmentRead::view()")
            }
        }
    }

    /// Borrowed kernel-ready view of the decoded matrix — the accessor
    /// every variant (owned, cache-shared, mmap-backed) serves without a
    /// copy.
    pub fn view(&self) -> SegView<'_> {
        match self {
            SegmentRead::Owned(m) => m.view(),
            SegmentRead::Shared(m) => m.view(),
            SegmentRead::Mapped(m) => m.view(),
        }
    }

    /// Recover the owned buffers for recycling — `None` when the matrix
    /// is cache-resident (its buffers keep serving future hits). A mapped
    /// read yields the scratch buffers it displaced at read time (plus its
    /// materialized rowptr), so the recycle loop keeps circulating at
    /// steady state.
    pub fn reclaim(self) -> Option<Csr> {
        match self {
            SegmentRead::Owned(m) => Some(m),
            SegmentRead::Shared(_) => None,
            SegmentRead::Mapped(m) => Some(m.reclaim()),
        }
    }

    /// Clone out an owned matrix (test/tool convenience; copies on the
    /// shared and mapped variants).
    pub fn into_csr(self) -> Csr {
        match self {
            SegmentRead::Owned(m) => m,
            SegmentRead::Shared(m) => (*m).clone(),
            SegmentRead::Mapped(m) => m.to_csr(),
        }
    }
}

impl Clone for SegmentRead {
    /// Cloning a mapped read materializes it (`Owned`): a `Clone` must not
    /// duplicate an mmap region, and callers that clone want a matrix, not
    /// a file handle.
    fn clone(&self) -> SegmentRead {
        match self {
            SegmentRead::Owned(m) => SegmentRead::Owned(m.clone()),
            SegmentRead::Shared(m) => SegmentRead::Shared(Arc::clone(m)),
            SegmentRead::Mapped(m) => SegmentRead::Owned(m.to_csr()),
        }
    }
}

impl std::ops::Deref for SegmentRead {
    type Target = Csr;

    fn deref(&self) -> &Csr {
        self.csr()
    }
}

/// A zero-copy mapped segment: the record's file stays mmap'd for the
/// lifetime of the value, its O(nnz) colidx/vals sections are borrowed
/// straight from the page cache, and only the O(nrows) rowptr is decoded
/// once into (recycled) scratch. Produced by
/// [`SegmentStore::read_mapped`]; the bytes were fully validated
/// (checksums + CSR invariants) by [`segio::decode_segment_ref`] before
/// this value existed.
///
/// The section *offsets* are stored rather than borrowed slices — a
/// self-referential borrow of the held mapping is not expressible — and
/// [`MappedSegment::view`] re-derives the slices per call (two bounds
/// checks; alignment was proven at map time).
#[derive(Debug)]
pub struct MappedSegment {
    map: Mmap,
    nrows: usize,
    ncols: usize,
    nnz: usize,
    /// Materialized rowptr (decoded once at map time).
    rowptr: Vec<usize>,
    /// Byte offset of the colidx section within the mapping.
    colidx_off: usize,
    /// Byte offset of the vals section within the mapping.
    vals_off: usize,
    /// Index/value buffers of the recycled scratch `Csr` this read
    /// displaced, held so [`MappedSegment::reclaim`] keeps their capacity
    /// circulating through the staging pool.
    spare_colidx: Vec<u32>,
    spare_vals: Vec<f32>,
}

impl MappedSegment {
    /// Borrowed kernel-ready view: rowptr from the materialized copy,
    /// colidx/vals straight from the mapping.
    pub fn view(&self) -> SegView<'_> {
        let buf = self.map.as_slice();
        let colidx = segio::borrow_le_slice::<u32>(
            &buf[self.colidx_off..self.colidx_off + self.nnz * 4],
            self.nnz,
        )
        .expect("alignment and byte order were proven when the segment was mapped");
        let vals = segio::borrow_le_slice::<f32>(
            &buf[self.vals_off..self.vals_off + self.nnz * 4],
            self.nnz,
        )
        .expect("alignment and byte order were proven when the segment was mapped");
        SegView {
            nrows: self.nrows,
            ncols: self.ncols,
            rowptr: &self.rowptr,
            colidx,
            vals,
        }
    }

    /// Materialize an owned `Csr` (copies all three sections).
    pub fn to_csr(&self) -> Csr {
        let v = self.view();
        Csr {
            nrows: v.nrows,
            ncols: v.ncols,
            rowptr: v.rowptr.to_vec(),
            colidx: v.colidx.to_vec(),
            vals: v.vals.to_vec(),
        }
    }

    /// Unmap and hand back a scratch `Csr` built from the displaced spare
    /// buffers + the materialized rowptr — content is arbitrary, capacity
    /// is what the recycle loop cares about.
    pub fn reclaim(self) -> Csr {
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            rowptr: self.rowptr,
            colidx: self.spare_colidx,
            vals: self.spare_vals,
        }
    }
}

/// The deterministic-LRU host tier, generic over what it holds: decoded
/// CSR segments for [`SegmentStore`], dense feature panels for
/// [`PanelStore`]. Entry costs are supplied by the caller at insertion
/// (decoded logical bytes), so eviction accounting is type-agnostic.
#[derive(Debug)]
struct HostCache<T> {
    /// Byte bound (0 disables the tier entirely).
    capacity: u64,
    used: u64,
    /// Decoded entries keyed by index, shared with in-flight readers,
    /// each with the cost it was charged at insertion.
    entries: HashMap<usize, (Arc<T>, u64)>,
    /// LRU order: front = coldest, back = hottest.
    order: Vec<usize>,
    stats: CacheStats,
}

impl<T> HostCache<T> {
    fn new(capacity: u64) -> HostCache<T> {
        HostCache {
            capacity,
            used: 0,
            entries: HashMap::new(),
            order: Vec::new(),
            stats: CacheStats::default(),
        }
    }

    fn touch(&mut self, idx: usize) {
        if let Some(pos) = self.order.iter().position(|&i| i == idx) {
            self.order.remove(pos);
        }
        self.order.push(idx);
    }

    /// Shared view of a resident entry (no LRU update; see [`Self::touch`]).
    fn get(&self, idx: usize) -> Option<Arc<T>> {
        self.entries.get(&idx).map(|(m, _)| Arc::clone(m))
    }

    /// Insert a decoded entry charged `cost` bytes, evicting LRU entries
    /// to stay within the bound. Returns `false` when the tier is disabled
    /// or the entry alone exceeds it (the caller then keeps sole
    /// ownership).
    fn insert(&mut self, idx: usize, m: Arc<T>, cost: u64) -> bool {
        if self.capacity == 0 || cost > self.capacity {
            return false; // tier disabled, or the entry alone exceeds the bound
        }
        while self.used + cost > self.capacity {
            let coldest = self.order.remove(0);
            let (_, evicted_cost) =
                self.entries.remove(&coldest).expect("order tracks entries");
            self.used -= evicted_cost;
            self.stats.evictions += 1;
        }
        self.used += cost;
        self.entries.insert(idx, (m, cost));
        self.order.push(idx);
        self.stats.resident_bytes = self.used;
        true
    }

    /// Drop a resident entry (a rewritten panel must not serve stale
    /// bytes). Not counted as an eviction — nothing was displaced by
    /// pressure.
    fn remove(&mut self, idx: usize) {
        if let Some((_, cost)) = self.entries.remove(&idx) {
            self.used -= cost;
            self.order.retain(|&i| i != idx);
            self.stats.resident_bytes = self.used;
        }
    }
}

/// A spilled, partitioned matrix served through the host-RAM tier.
///
/// Build one with [`SegmentStore::spill`] (writes every planned segment to
/// a directory) or [`SegmentStore::open_or_spill`] (reuses byte-valid
/// fixture files — the bench/CI path). Reads are `&self` and
/// thread-safe, so the prefetch producer can stage from the store while
/// the consumer computes.
#[derive(Debug)]
pub struct SegmentStore {
    dir: PathBuf,
    segs: Vec<SegmentMeta>,
    /// Largest encoded segment file — the byte-scratch capacity that
    /// covers every read, so a recycled scratch buffer never regrows
    /// mid-stream.
    max_file_bytes: u64,
    /// Largest segment row count (scratch hint, precomputed once).
    max_seg_rows: usize,
    /// Largest segment nnz (scratch hint, precomputed once).
    max_seg_nnz: usize,
    /// Immutable copy of the host tier's byte bound, readable without the
    /// cache lock (cacheability prediction in [`Self::read_reusing`]).
    cache_capacity: u64,
    cache: Mutex<HostCache<Csr>>,
}

/// Fingerprint of (matrix payload, planned layout). The fixture-reuse
/// gate: two different matrices can plan identically-*sized* segments, so
/// file sizes alone cannot prove a directory serves the right bytes —
/// this hash covers every stored value and every planned boundary.
fn fingerprint(a: &Csr, segs: &[RobwSegment]) -> u64 {
    let mut h = Fnv64::new();
    h.update(&(a.nrows as u64).to_le_bytes());
    h.update(&(a.ncols as u64).to_le_bytes());
    for &p in &a.rowptr {
        h.update(&(p as u64).to_le_bytes());
    }
    for &c in &a.colidx {
        h.update(&c.to_le_bytes());
    }
    for &v in &a.vals {
        h.update(&v.to_bits().to_le_bytes());
    }
    for s in segs {
        h.update(&(s.row_lo as u64).to_le_bytes());
        h.update(&(s.row_hi as u64).to_le_bytes());
        h.update(&(s.nnz as u64).to_le_bytes());
    }
    h.finish()
}

/// Marker-file tag of a store-wide [`SegEncoding`] choice. Fixtures are
/// keyed by encoding mode: a directory spilled `raw` is never silently
/// reused for a `packed` (or `auto`) run even when the matrix + plan
/// match, because the recorded per-segment kinds/sizes would describe the
/// wrong files.
fn mode_tag(enc: SegEncoding) -> u32 {
    match enc {
        SegEncoding::Raw => 0,
        SegEncoding::Packed => 1,
        SegEncoding::Auto => 2,
    }
}

/// Serialize the v2 `fingerprint` marker: matrix+plan fingerprint,
/// encoding-mode tag, and the per-segment `(kind, encoded file size)`
/// table the spill committed to, sealed with an FNV-1a 64 of everything
/// before it. The v1 marker was a bare 8-byte fingerprint; it fails
/// [`parse_marker`] and therefore triggers a clean respill.
fn encode_marker(fp: u64, enc: SegEncoding, per_seg: &[(u32, u64)]) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + per_seg.len() * 12 + 8);
    buf.extend_from_slice(&fp.to_le_bytes());
    buf.extend_from_slice(&mode_tag(enc).to_le_bytes());
    buf.extend_from_slice(&(per_seg.len() as u32).to_le_bytes());
    for &(kind, bytes) in per_seg {
        buf.extend_from_slice(&kind.to_le_bytes());
        buf.extend_from_slice(&bytes.to_le_bytes());
    }
    let sum = segio::fnv1a64(&buf);
    buf.extend_from_slice(&sum.to_le_bytes());
    buf
}

/// Parse a v2 marker back into `(fingerprint, mode tag, per-segment
/// (kind, file size))`. `None` for anything else — wrong length, bad
/// seal, v1 markers — which [`SegmentStore::open_or_spill_encoded`]
/// treats as "not reusable".
fn parse_marker(buf: &[u8]) -> Option<(u64, u32, Vec<(u32, u64)>)> {
    if buf.len() < 24 {
        return None;
    }
    let (body, seal) = buf.split_at(buf.len() - 8);
    if segio::fnv1a64(body) != u64::from_le_bytes(seal.try_into().ok()?) {
        return None;
    }
    let fp = u64::from_le_bytes(body.get(0..8)?.try_into().ok()?);
    let tag = u32::from_le_bytes(body.get(8..12)?.try_into().ok()?);
    let count = u32::from_le_bytes(body.get(12..16)?.try_into().ok()?) as usize;
    if body.len() != 16 + count * 12 {
        return None;
    }
    let mut per_seg = Vec::with_capacity(count);
    for i in 0..count {
        let off = 16 + i * 12;
        let kind = u32::from_le_bytes(body.get(off..off + 4)?.try_into().ok()?);
        let bytes = u64::from_le_bytes(body.get(off + 4..off + 12)?.try_into().ok()?);
        per_seg.push((kind, bytes));
    }
    Some((fp, tag, per_seg))
}

impl SegmentStore {
    fn seg_path(dir: &Path, i: usize) -> PathBuf {
        dir.join(format!("seg-{i:05}.bin"))
    }

    fn fingerprint_path(dir: &Path) -> PathBuf {
        dir.join("fingerprint")
    }

    /// Spill every planned segment of `a` to `dir` (created if missing)
    /// in the raw encoding, returning a store that serves them back
    /// through a host cache of at most `host_cache_bytes` decoded bytes
    /// (`0` = no cache, [`UNBOUNDED_CACHE`] = keep everything).
    pub fn spill(
        a: &Csr,
        segs: &[RobwSegment],
        dir: &Path,
        host_cache_bytes: u64,
    ) -> Result<SegmentStore, SegioError> {
        Self::spill_encoded(a, segs, dir, host_cache_bytes, SegEncoding::Raw)
    }

    /// [`Self::spill`] with an explicit segment encoding: `Raw` writes
    /// plain CSR records, `Packed` delta-bitpacks every colidx section,
    /// and `Auto` picks per segment whichever encodes smaller.
    pub fn spill_encoded(
        a: &Csr,
        segs: &[RobwSegment],
        dir: &Path,
        host_cache_bytes: u64,
        enc: SegEncoding,
    ) -> Result<SegmentStore, SegioError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| SegioError::Io(format!("create {}: {e}", dir.display())))?;
        // Marker first, segment files second: a spill interrupted mid-way
        // leaves the marker + partial files, which the next open_or_spill
        // detects (size check fails) and cleanly respills. The other order
        // would leave a marker-less non-empty directory that
        // clear_store_files permanently refuses to touch. The v2 marker
        // records each segment's (kind, encoded size), so the encoding
        // decisions are made up front — from section lengths alone, no
        // bytes written — and the write pass below must land on exactly
        // the committed sizes (both encoders are deterministic).
        let planned: Vec<(u32, u64)> = segs
            .iter()
            .map(|seg| {
                let raw = segio::encoded_len(seg.row_hi - seg.row_lo, seg.nnz);
                match enc {
                    SegEncoding::Raw => (segio::KIND_CSR, raw),
                    SegEncoding::Packed => {
                        let sub = materialize(a, seg);
                        (segio::KIND_CSR_PACKED, segio::encoded_packed_len(&sub))
                    }
                    SegEncoding::Auto => {
                        let sub = materialize(a, seg);
                        let packed = segio::encoded_packed_len(&sub);
                        if packed < raw {
                            (segio::KIND_CSR_PACKED, packed)
                        } else {
                            (segio::KIND_CSR, raw)
                        }
                    }
                }
            })
            .collect();
        let fp = Self::fingerprint_path(dir);
        std::fs::write(&fp, encode_marker(fingerprint(a, segs), enc, &planned))
            .map_err(|e| SegioError::Io(format!("write {}: {e}", fp.display())))?;
        let mut metas = Vec::with_capacity(segs.len());
        for (i, seg) in segs.iter().enumerate() {
            let sub = materialize(a, seg);
            let path = Self::seg_path(dir, i);
            let (file_bytes, kind) = segio::write_segment_encoded(&path, &sub, enc)?;
            debug_assert_eq!(
                (kind, file_bytes),
                planned[i],
                "encoding choice must be deterministic"
            );
            metas.push(SegmentMeta {
                row_lo: seg.row_lo,
                row_hi: seg.row_hi,
                nnz: seg.nnz,
                plan_bytes: seg.bytes,
                file_bytes,
                kind,
                path,
            });
        }
        Ok(Self::with_metas(dir.to_path_buf(), metas, host_cache_bytes))
    }

    /// Reuse `dir`'s files when its recorded fingerprint matches this
    /// (matrix, plan) *and* every expected segment file exists with
    /// exactly the predicted encoded size; otherwise remove the previous
    /// spill's files (`fingerprint` + `seg-*.bin`, nothing else) and
    /// respill. A non-empty directory with no `fingerprint` marker is
    /// refused outright — never deleted. This is the bench/CI fixture
    /// path: a stale or partial fixture — a restored cache from another
    /// plan, or even from a *different matrix* whose segments happen to
    /// have the same sizes — can never serve wrong bytes. Size or
    /// fingerprint mismatches trigger a respill here, and surviving
    /// corruption is caught by the per-read checksum.
    pub fn open_or_spill(
        a: &Csr,
        segs: &[RobwSegment],
        dir: &Path,
        host_cache_bytes: u64,
    ) -> Result<SegmentStore, SegioError> {
        Self::open_or_spill_encoded(a, segs, dir, host_cache_bytes, SegEncoding::Raw)
    }

    /// [`Self::open_or_spill`] with an explicit segment encoding. Reuse
    /// requires the marker's recorded encoding *mode* to match `enc` as
    /// well — fixtures are keyed by encoding, so switching `--seg-encoding`
    /// between runs respills rather than serving records the manifest
    /// would mis-describe.
    pub fn open_or_spill_encoded(
        a: &Csr,
        segs: &[RobwSegment],
        dir: &Path,
        host_cache_bytes: u64,
        enc: SegEncoding,
    ) -> Result<SegmentStore, SegioError> {
        let want_fp = fingerprint(a, segs);
        let marker = std::fs::read(Self::fingerprint_path(dir))
            .ok()
            .and_then(|buf| parse_marker(&buf));
        let reusable = marker.as_ref().is_some_and(|(fp, tag, per_seg)| {
            *fp == want_fp
                && *tag == mode_tag(enc)
                && per_seg.len() == segs.len()
                && per_seg.iter().enumerate().all(|(i, &(_, bytes))| {
                    std::fs::metadata(Self::seg_path(dir, i))
                        .map(|m| m.len() == bytes)
                        .unwrap_or(false)
                })
                && {
                    // No stale extra segment files from a longer previous
                    // plan.
                    std::fs::metadata(Self::seg_path(dir, segs.len())).is_err()
                }
        });
        if reusable {
            let (_, _, per_seg) = marker.expect("reusable implies a parsed marker");
            let metas = segs
                .iter()
                .zip(per_seg)
                .enumerate()
                .map(|(i, (seg, (kind, file_bytes)))| SegmentMeta {
                    row_lo: seg.row_lo,
                    row_hi: seg.row_hi,
                    nnz: seg.nnz,
                    plan_bytes: seg.bytes,
                    file_bytes,
                    kind,
                    path: Self::seg_path(dir, i),
                })
                .collect();
            return Ok(Self::with_metas(dir.to_path_buf(), metas, host_cache_bytes));
        }
        Self::clear_store_files(dir)?;
        Self::spill_encoded(a, segs, dir, host_cache_bytes, enc)
    }

    /// Remove a previous spill's files (`fingerprint` + `seg-*.bin`) from
    /// `dir` — and *only* those. A non-empty directory with no
    /// `fingerprint` marker was never a segment store, and blindly wiping
    /// it could destroy user data (e.g. `--segment-dir ~/data`), so that
    /// case is a refusal, not a cleanup.
    fn clear_store_files(dir: &Path) -> Result<(), SegioError> {
        let entries = match std::fs::read_dir(dir) {
            Err(_) => return Ok(()), // nothing on disk yet
            Ok(entries) => entries,
        };
        let names: Vec<std::ffi::OsString> =
            entries.filter_map(|e| e.ok().map(|e| e.file_name())).collect();
        let is_store_file = |n: &std::ffi::OsString| {
            let n = n.to_string_lossy();
            n == "fingerprint" || (n.starts_with("seg-") && n.ends_with(".bin"))
        };
        let has_marker = names.iter().any(|n| n.to_string_lossy() == "fingerprint");
        if !names.is_empty() && !has_marker {
            return Err(SegioError::Io(format!(
                "refusing to respill into {}: directory is non-empty and has no \
                 `fingerprint` marker, so it is not a segment store",
                dir.display()
            )));
        }
        for n in names.iter().filter(|n| is_store_file(n)) {
            let p = dir.join(n);
            std::fs::remove_file(&p)
                .map_err(|e| SegioError::Io(format!("remove {}: {e}", p.display())))?;
        }
        Ok(())
    }

    fn with_metas(dir: PathBuf, segs: Vec<SegmentMeta>, host_cache_bytes: u64) -> SegmentStore {
        let max_file_bytes = segs.iter().map(|m| m.file_bytes).max().unwrap_or(0);
        let max_seg_rows = segs.iter().map(|m| m.row_hi - m.row_lo).max().unwrap_or(0);
        let max_seg_nnz = segs.iter().map(|m| m.nnz).max().unwrap_or(0);
        SegmentStore {
            dir,
            segs,
            max_file_bytes,
            max_seg_rows,
            max_seg_nnz,
            cache_capacity: host_cache_bytes,
            cache: Mutex::new(HostCache::new(host_cache_bytes)),
        }
    }

    /// Number of segments in the store.
    pub fn len(&self) -> usize {
        self.segs.len()
    }

    /// Whether the store holds no segments.
    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    /// Directory the segments live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Metadata of segment `i`.
    pub fn meta(&self, i: usize) -> &SegmentMeta {
        &self.segs[i]
    }

    /// Serving counters since the store was created.
    pub fn stats(&self) -> CacheStats {
        lock(&self.cache).stats
    }

    /// Verify the store's manifest matches a freshly planned segment list
    /// (same count, same row ranges, same nnz) — the guard that keeps a
    /// disk-backed pass byte-identical to the in-memory plan it claims to
    /// serve.
    pub fn check_plan(&self, segs: &[RobwSegment]) -> Result<(), String> {
        if segs.len() != self.segs.len() {
            return Err(format!(
                "store holds {} segments, plan has {}",
                self.segs.len(),
                segs.len()
            ));
        }
        for (i, (m, s)) in self.segs.iter().zip(segs.iter()).enumerate() {
            if (m.row_lo, m.row_hi, m.nnz) != (s.row_lo, s.row_hi, s.nnz) {
                return Err(format!(
                    "segment {i}: store has rows [{}, {}) nnz {}, plan wants [{}, {}) nnz {}",
                    m.row_lo, m.row_hi, m.nnz, s.row_lo, s.row_hi, s.nnz
                ));
            }
        }
        Ok(())
    }

    /// Read segment `i`: from the host tier when resident, else from disk
    /// (checksum-verified), updating the LRU state either way. The
    /// returned [`ReadOrigin`] reports the *measured* disk bytes — the
    /// number the staging layer charges instead of a simulated sleep.
    ///
    /// A cache hit shares the resident matrix ([`SegmentRead::Shared`])
    /// instead of deep-copying it; a miss that lands in the cache shares
    /// the freshly decoded buffers the same way, and a miss the cache
    /// refuses (tier disabled or segment too big) is handed over owned.
    pub fn read(&self, i: usize) -> Result<(SegmentRead, ReadOrigin), SegioError> {
        self.read_reusing(i, None, None)
    }

    /// [`Self::read`] with recycled buffers: `reuse` is a drained segment
    /// scratch from the pipeline's return channel (decoded into in place),
    /// and `pool` supplies byte/CSR scratch when `reuse` is absent and
    /// retires the producer-side byte buffer after the decode. With both
    /// warm and the host tier disabled, a read performs zero heap
    /// allocations beyond kernel I/O (`rust/tests/alloc_free.rs`).
    /// Byte-for-byte the served matrix is identical to [`Self::read`]'s.
    pub fn read_reusing(
        &self,
        i: usize,
        reuse: Option<Csr>,
        pool: Option<&BufferPool>,
    ) -> Result<(SegmentRead, ReadOrigin), SegioError> {
        let meta = &self.segs[i];
        {
            let mut cache = lock(&self.cache);
            if let Some(m) = cache.get(i) {
                cache.touch(i);
                cache.stats.hits += 1;
                drop(cache);
                // The drained scratch is not needed for a resident read;
                // keep it circulating rather than dropping it.
                if let (Some(m), Some(pool)) = (reuse, pool) {
                    pool.put_csr(m);
                }
                return Ok((SegmentRead::Shared(m), ReadOrigin { disk_bytes: 0, cache_hit: true }));
            }
        }
        // Disk read outside the lock: the producer is the only reader in
        // the pipeline, but `&self` reads must never serialize on I/O.
        // A read that will land in the host tier donates its buffers to
        // the cache (the consumer gets a Shared view and reclaims
        // nothing), so burning pooled plan-maxima scratch on it would
        // drain the pool for good and then pay a shrink copy — predict
        // cacheability from the manifest (exactly the decoded size, by
        // construction) and decode into exact-size fresh sections instead.
        let decoded_bytes = calc_mem(meta.row_hi - meta.row_lo, meta.nnz);
        let likely_cached = self.cache_capacity > 0 && decoded_bytes <= self.cache_capacity;
        // Otherwise: the recycled hand-back first, the pool second, a
        // fresh allocation last. Hints are store-wide maxima (precomputed
        // once) so capacities reach their high-water mark on first use
        // and never regrow mid-stream.
        let mut m = if likely_cached {
            if let (Some(m), Some(pool)) = (reuse, pool) {
                // Keep the drained scratch circulating for later
                // non-cacheable reads instead of dropping it.
                pool.put_csr(m);
            }
            Csr::empty(0, 0)
        } else {
            match (reuse, pool) {
                (Some(m), _) => m,
                (None, Some(pool)) => pool.take_csr(self.max_seg_rows, self.max_seg_nnz),
                (None, None) => Csr::empty(0, 0),
            }
        };
        let mut scratch = match pool {
            Some(pool) => pool.take_bytes(self.max_file_bytes as usize),
            None => Vec::new(),
        };
        let read = segio::read_segment_into(&meta.path, &mut scratch, &mut m);
        if let Some(pool) = pool {
            pool.put_bytes(scratch);
        }
        // On any failure the plan-maxima-sized scratch goes back to the
        // pool (like the byte scratch above) so a retried pass does not
        // re-warm it.
        let bytes = match read {
            Ok(b) => b,
            Err(e) => {
                if let Some(pool) = pool {
                    pool.put_csr(m);
                }
                return Err(e);
            }
        };
        if m.nrows != meta.row_hi - meta.row_lo || m.nnz() != meta.nnz {
            let err = SegioError::InvalidCsr(format!(
                "segment {i} decoded to {} rows / {} nnz, manifest says {} rows / {} nnz",
                m.nrows,
                m.nnz(),
                meta.row_hi - meta.row_lo,
                meta.nnz
            ));
            if let Some(pool) = pool {
                pool.put_csr(m);
            }
            return Err(err);
        }
        let mut cache = lock(&self.cache);
        cache.stats.misses += 1;
        cache.stats.disk_bytes += bytes;
        // A concurrent reader may have inserted `i` while we were on
        // disk (the lock is dropped around the read); inserting again
        // would double-count `used` and duplicate the LRU entry.
        // Decide cacheability *before* Arc-wrapping: the cache-disabled
        // path must stay free of per-segment allocations.
        let cacheable = cache.capacity > 0 && m.size_bytes() <= cache.capacity;
        let result = if cache.entries.contains_key(&i) || !cacheable {
            SegmentRead::Owned(m)
        } else {
            // The cache is charged the *logical* size, so a resident
            // entry must not keep pinning plan-wide scratch capacity —
            // shrink before sharing (this buffer is being donated to the
            // cache, not returned to the pool, so no warm capacity is
            // lost).
            m.rowptr.shrink_to_fit();
            m.colidx.shrink_to_fit();
            m.vals.shrink_to_fit();
            let cost = m.size_bytes();
            let shared = Arc::new(m);
            let inserted = cache.insert(i, Arc::clone(&shared), cost);
            debug_assert!(inserted, "cacheability was checked above");
            SegmentRead::Shared(shared)
        };
        cache.stats.resident_bytes = cache.used;
        Ok((result, ReadOrigin { disk_bytes: bytes, cache_hit: false }))
    }

    /// Zero-copy read of segment `i`: mmap the record, validate it in
    /// place ([`segio::decode_segment_ref`] — checksums + the full CSR
    /// invariant walk, same discipline as the copying decoder), and serve
    /// its colidx/vals sections straight from the page cache
    /// ([`SegmentRead::Mapped`]). Only the O(nrows) rowptr is
    /// materialized, into the recycled scratch when one is supplied.
    ///
    /// The host-RAM tier is bypassed — for mapped reads the page cache
    /// *is* the host tier — so the origin always reports a miss with the
    /// encoded file size as its disk bytes (the kernel may well have
    /// served the pages from memory; the store cannot observe that, and
    /// charging the encoded size keeps the staging ledgers deterministic).
    ///
    /// Packed segments (and targets where in-place section borrowing is
    /// unavailable) fall back to [`Self::read_reusing`] — byte-identical
    /// served matrices, just copy-decoded.
    pub fn read_mapped(
        &self,
        i: usize,
        reuse: Option<Csr>,
        pool: Option<&BufferPool>,
    ) -> Result<(SegmentRead, ReadOrigin), SegioError> {
        let meta = &self.segs[i];
        if meta.kind != segio::KIND_CSR {
            // Packed colidx cannot be borrowed in place.
            return self.read_reusing(i, reuse, pool);
        }
        let map = Mmap::map(&meta.path)
            .map_err(|e| SegioError::Io(format!("map {}: {e}", meta.path.display())))?;
        let sref = match segio::decode_segment_ref(map.as_slice()) {
            Ok(r) => r,
            Err(e) => {
                // The recycled scratch survives a failed read (same
                // discipline as read_reusing), so a healed retry does not
                // re-warm the pool.
                if let (Some(m), Some(pool)) = (reuse, pool) {
                    pool.put_csr(m);
                }
                return Err(e);
            }
        };
        if sref.nrows != meta.row_hi - meta.row_lo || sref.nnz() != meta.nnz {
            let err = SegioError::InvalidCsr(format!(
                "segment {i} decoded to {} rows / {} nnz, manifest says {} rows / {} nnz",
                sref.nrows,
                sref.nnz(),
                meta.row_hi - meta.row_lo,
                meta.nnz
            ));
            if let (Some(m), Some(pool)) = (reuse, pool) {
                pool.put_csr(m);
            }
            return Err(err);
        }
        if sref.colidx_u32().is_none() || sref.vals_f32().is_none() {
            // Big-endian target (mmap'd records are always aligned):
            // zero-copy is off the table, copy-decode instead.
            return self.read_reusing(i, reuse, pool);
        }
        let (mut rowptr, spare_colidx, spare_vals) = match (reuse, pool) {
            (Some(m), _) => (m.rowptr, m.colidx, m.vals),
            (None, Some(pool)) => {
                let m = pool.take_csr(self.max_seg_rows, self.max_seg_nnz);
                (m.rowptr, m.colidx, m.vals)
            }
            (None, None) => (Vec::new(), Vec::new(), Vec::new()),
        };
        sref.fill_rowptr(&mut rowptr);
        let (nrows, ncols, nnz) = (sref.nrows, sref.ncols, sref.nnz());
        let colidx_off = segio::HEADER_BYTES + (nrows + 1) * 8;
        let vals_off = colidx_off + nnz * 4;
        let mapped = MappedSegment {
            map,
            nrows,
            ncols,
            nnz,
            rowptr,
            colidx_off,
            vals_off,
            spare_colidx,
            spare_vals,
        };
        {
            let mut cache = lock(&self.cache);
            cache.stats.misses += 1;
            cache.stats.disk_bytes += meta.file_bytes;
        }
        Ok((
            SegmentRead::Mapped(mapped),
            ReadOrigin { disk_bytes: meta.file_bytes, cache_hit: false },
        ))
    }

    /// Quarantine segment `i`'s on-disk file and rebuild it from the
    /// source matrix + plan entry — the recovery path
    /// [`runtime::heal`](crate::runtime::heal) takes when a read surfaces
    /// persistent corruption (bad magic, truncation, checksum mismatch).
    ///
    /// The corrupt file is renamed to `<name>.quarantined` (preserved for
    /// postmortem, never served again; a file already missing is fine —
    /// deletion is one of the faults this recovers from). Any resident
    /// host-tier copy is dropped, then the segment is re-materialized from
    /// `(a, seg)` and rewritten via temp-file-then-rename so a crash
    /// mid-rebuild never leaves a second torn file. The rewrite must
    /// reproduce exactly the manifest's encoded size — a plan entry that
    /// disagrees with the manifest is refused before anything is touched.
    pub fn quarantine_and_rebuild(
        &self,
        i: usize,
        a: &Csr,
        seg: &RobwSegment,
    ) -> Result<(), SegioError> {
        let meta = &self.segs[i];
        if (meta.row_lo, meta.row_hi, meta.nnz) != (seg.row_lo, seg.row_hi, seg.nnz) {
            return Err(SegioError::Io(format!(
                "rebuild segment {i}: plan entry has rows [{}, {}) nnz {}, \
                 manifest says rows [{}, {}) nnz {}",
                seg.row_lo, seg.row_hi, seg.nnz, meta.row_lo, meta.row_hi, meta.nnz
            )));
        }
        let mut qname = meta.path.file_name().unwrap_or_default().to_os_string();
        qname.push(".quarantined");
        let qpath = meta.path.with_file_name(qname);
        match std::fs::rename(&meta.path, &qpath) {
            Ok(()) => {}
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {}
            Err(e) => {
                return Err(SegioError::Io(format!(
                    "quarantine {}: {e}",
                    meta.path.display()
                )))
            }
        }
        lock(&self.cache).remove(i);
        let sub = materialize(a, seg);
        let tmp = meta.path.with_extension("bin.tmp");
        // Rebuild in the segment's *original* encoding: the manifest's
        // recorded kind, not a store-wide default — a packed store must
        // heal back to packed bytes (and the exact-size check below holds
        // because both encoders are deterministic).
        let enc = SegEncoding::for_kind(meta.kind).ok_or_else(|| {
            SegioError::Io(format!(
                "rebuild segment {i}: manifest kind {} is not a CSR encoding",
                meta.kind
            ))
        })?;
        let (file_bytes, kind) = segio::write_segment_encoded(&tmp, &sub, enc)?;
        debug_assert_eq!(kind, meta.kind, "for_kind round-trips the manifest kind");
        if file_bytes != meta.file_bytes {
            let _ = std::fs::remove_file(&tmp);
            return Err(SegioError::Io(format!(
                "rebuild segment {i}: rewrote {file_bytes} bytes, manifest expects {}",
                meta.file_bytes
            )));
        }
        std::fs::rename(&tmp, &meta.path).map_err(|e| {
            SegioError::Io(format!("rebuild rename {}: {e}", meta.path.display()))
        })?;
        Ok(())
    }
}

// ------------------------------------------------------------ panel tier

/// One spilled feature panel's metadata (manifest entry of a
/// [`PanelStore`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PanelMeta {
    /// Panel row count.
    pub nrows: usize,
    /// Panel column count (the layer's feature width).
    pub ncols: usize,
    /// Encoded size on disk (header + payload; summed over chunks when
    /// the panel was spilled chunked).
    pub file_bytes: u64,
    /// Panel file path (the single-record path; unused when `chunks` is
    /// non-empty).
    pub path: PathBuf,
    /// Row-panel chunk records ([`PanelStore::put_chunked`]). Empty for a
    /// whole-panel spill ([`PanelStore::put`]).
    pub chunks: Vec<PanelChunk>,
}

/// One row-range chunk of a chunked panel spill: rows `[row_lo, row_hi)`
/// of the panel, stored as an independent [`segio::KIND_PANEL`] record.
/// Chunk boundaries follow the *next* layer's RoBW plan, so a staged
/// segment's aggregation touches the fewest chunk records possible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PanelChunk {
    /// First panel row in this chunk (inclusive).
    pub row_lo: usize,
    /// One past the last panel row (exclusive).
    pub row_hi: usize,
    /// Encoded chunk record size on disk.
    pub file_bytes: u64,
    /// Chunk file path.
    pub path: PathBuf,
}

/// A served feature panel: owned (its data vector can retire to the
/// staging [`BufferPool`]), shared with the host tier, or mmap-backed
/// chunk records served from the page cache — the panel-side analog of
/// [`SegmentRead`].
///
/// Compute paths should consume panels through [`PanelRead::src`] (a
/// [`RowSrc`] every variant serves without a copy); [`PanelRead::dense`]
/// and `Deref<Target = Dense>` panic on `Mapped`.
#[derive(Debug)]
pub enum PanelRead {
    /// Owned decoded panel.
    Owned(Dense),
    /// Cache-resident panel, shared without a defensive clone.
    Shared(Arc<Dense>),
    /// mmap-backed chunk records; rows stay in the page cache.
    Mapped(MappedPanelChunks),
}

impl PanelRead {
    /// The decoded panel, however it is held.
    ///
    /// # Panics
    ///
    /// On [`PanelRead::Mapped`] — use [`PanelRead::src`], which all
    /// variants serve without materializing.
    pub fn dense(&self) -> &Dense {
        match self {
            PanelRead::Owned(p) => p,
            PanelRead::Shared(p) => p,
            PanelRead::Mapped(_) => {
                panic!("mapped panel read holds no materialized Dense; use PanelRead::src()")
            }
        }
    }

    /// Borrowed row source over the panel — the accessor every variant
    /// (owned, cache-shared, mmap-backed) serves without a copy.
    pub fn src(&self) -> PanelSrc<'_> {
        match self {
            PanelRead::Owned(p) => PanelSrc::Dense(p),
            PanelRead::Shared(p) => PanelSrc::Dense(p),
            PanelRead::Mapped(m) => PanelSrc::Mapped(m),
        }
    }

    /// Clone out an owned panel (test/tool convenience; copies on the
    /// shared and mapped variants).
    pub fn into_dense(self) -> Dense {
        match self {
            PanelRead::Owned(p) => p,
            PanelRead::Shared(p) => (*p).clone(),
            PanelRead::Mapped(m) => m.to_dense(),
        }
    }
}

impl Clone for PanelRead {
    /// Cloning a mapped read materializes it (`Owned`) — a `Clone` must
    /// not duplicate mmap regions.
    fn clone(&self) -> PanelRead {
        match self {
            PanelRead::Owned(p) => PanelRead::Owned(p.clone()),
            PanelRead::Shared(p) => PanelRead::Shared(Arc::clone(p)),
            PanelRead::Mapped(m) => PanelRead::Owned(m.to_dense()),
        }
    }
}

impl std::ops::Deref for PanelRead {
    type Target = Dense;

    fn deref(&self) -> &Dense {
        self.dense()
    }
}

/// A zero-copy mapped panel: one mmap'd [`segio::KIND_PANEL`] record per
/// row chunk (a whole-panel spill maps as a single chunk spanning every
/// row), validated at map time, rows borrowed from the page cache on
/// demand. Implements [`RowSrc`], so the SpMM kernels aggregate straight
/// out of the mapping.
#[derive(Debug)]
pub struct MappedPanelChunks {
    nrows: usize,
    ncols: usize,
    /// Chunks sorted by `row_lo`, contiguous over `0..nrows`.
    chunks: Vec<MappedPanelChunk>,
}

#[derive(Debug)]
struct MappedPanelChunk {
    map: Mmap,
    row_lo: usize,
    row_hi: usize,
}

impl MappedPanelChunks {
    /// Materialize an owned copy (test/tool convenience).
    pub fn to_dense(&self) -> Dense {
        let mut d = Dense::zeros(self.nrows, self.ncols);
        for r in 0..self.nrows {
            d.data[r * self.ncols..(r + 1) * self.ncols].copy_from_slice(self.row(r));
        }
        d
    }
}

impl RowSrc for MappedPanelChunks {
    fn nrows(&self) -> usize {
        self.nrows
    }

    fn ncols(&self) -> usize {
        self.ncols
    }

    fn row(&self, r: usize) -> &[f32] {
        let k = self.chunks.partition_point(|c| c.row_hi <= r);
        let c = &self.chunks[k];
        debug_assert!(r >= c.row_lo && r < c.row_hi, "chunks cover 0..nrows contiguously");
        let start = segio::HEADER_BYTES + (r - c.row_lo) * self.ncols * 4;
        let bytes = &c.map.as_slice()[start..start + self.ncols * 4];
        segio::borrow_le_slice::<f32>(bytes, self.ncols)
            .expect("alignment and byte order were proven when the panel was mapped")
    }
}

/// What a staged-pass consume callback receives as its feature panel: a
/// materialized dense panel or mapped chunk records. Implements
/// [`RowSrc`] by delegation, so one generic SpMM kernel consumes either —
/// and a call site that wants monomorphized inner loops can match once
/// and pass the borrowed `&Dense` / `&MappedPanelChunks` through instead.
#[derive(Debug, Clone, Copy)]
pub enum PanelSrc<'a> {
    /// A materialized panel (owned or cache-resident).
    Dense(&'a Dense),
    /// Mapped chunk records served from the page cache.
    Mapped(&'a MappedPanelChunks),
}

impl RowSrc for PanelSrc<'_> {
    fn nrows(&self) -> usize {
        match self {
            PanelSrc::Dense(p) => p.nrows,
            PanelSrc::Mapped(m) => m.nrows,
        }
    }

    fn ncols(&self) -> usize {
        match self {
            PanelSrc::Dense(p) => p.ncols,
            PanelSrc::Mapped(m) => m.ncols,
        }
    }

    fn row(&self, r: usize) -> &[f32] {
        match self {
            PanelSrc::Dense(p) => p.row(r),
            PanelSrc::Mapped(m) => m.row(r),
        }
    }
}

/// Disk-backed store for intermediate dense feature panels, served through
/// the same deterministic-LRU host tier as CSR segments.
///
/// The cross-layer pipeline (`gcn::pipeline`) writes layer `l`'s output
/// panel here after its Phase III combine ([`PanelStore::put`] →
/// `panel-%05d.bin` in the [`segio`] panel record format) and reads it
/// back as layer `l+1`'s Phase I input ([`PanelStore::read`]), so the
/// intermediate activations of an N-layer forward need not stay resident
/// in host RAM between layers. Unlike [`SegmentStore`] the manifest grows
/// as the pass runs — panels are produced mid-stream, not pre-spilled —
/// and a rewrite of slot `l` invalidates any cache-resident copy before
/// the new bytes land.
///
/// Determinism matches the segment tier: the pipeline consumer writes and
/// reads panels strictly in layer order, so hit/miss patterns and measured
/// panel I/O are identical at every prefetch depth and thread count.
#[derive(Debug)]
pub struct PanelStore {
    dir: PathBuf,
    cache_capacity: u64,
    state: Mutex<PanelState>,
}

#[derive(Debug)]
struct PanelState {
    metas: HashMap<usize, PanelMeta>,
    cache: HostCache<Dense>,
}

/// Decoded logical bytes of a panel (what the host tier is charged).
fn panel_cost(p: &Dense) -> u64 {
    p.data.len() as u64 * 4
}

impl PanelStore {
    fn panel_path(dir: &Path, idx: usize) -> PathBuf {
        dir.join(format!("panel-{idx:05}.bin"))
    }

    fn chunk_path(dir: &Path, idx: usize, chunk: usize) -> PathBuf {
        dir.join(format!("panel-{idx:05}.c{chunk:03}.bin"))
    }

    /// Open (creating if missing) a panel directory, serving reads through
    /// a host cache of at most `host_cache_bytes` decoded bytes (`0` = no
    /// cache, [`UNBOUNDED_CACHE`] = keep everything). The directory is
    /// scratch space: slots are rewritten in place by each pass.
    pub fn new(dir: &Path, host_cache_bytes: u64) -> Result<PanelStore, SegioError> {
        std::fs::create_dir_all(dir)
            .map_err(|e| SegioError::Io(format!("create {}: {e}", dir.display())))?;
        Ok(PanelStore {
            dir: dir.to_path_buf(),
            cache_capacity: host_cache_bytes,
            state: Mutex::new(PanelState {
                metas: HashMap::new(),
                cache: HostCache::new(host_cache_bytes),
            }),
        })
    }

    /// Directory the panel files live in.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Number of panels the store currently holds.
    pub fn len(&self) -> usize {
        lock(&self.state).metas.len()
    }

    /// Whether no panel has been spilled yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Metadata of panel `idx` (`None` until it has been spilled).
    pub fn meta(&self, idx: usize) -> Option<PanelMeta> {
        lock(&self.state).metas.get(&idx).cloned()
    }

    /// Serving counters since the store was created.
    pub fn stats(&self) -> CacheStats {
        lock(&self.state).cache.stats
    }

    /// Spill panel `idx` to disk, replacing any previous spill of the same
    /// slot (and dropping its stale cache entry *before* the write, so a
    /// concurrent reader can never see old bytes under a new manifest).
    /// Returns the encoded file size — the measured panel-spill I/O the
    /// pipeline report charges.
    ///
    /// The rewrite is atomic: bytes land in `<name>.bin.tmp` and are
    /// renamed over the slot only once fully written, so a process killed
    /// mid-`put` leaves the previously published panel intact (plus a torn
    /// temp file the next `put` overwrites) — never a torn panel that a
    /// later read surfaces as a checksum or `InvalidPanel` error with no
    /// recourse.
    pub fn put(&self, idx: usize, p: &Dense) -> Result<u64, SegioError> {
        let path = Self::panel_path(&self.dir, idx);
        {
            let mut st = lock(&self.state);
            st.cache.remove(idx);
            st.metas.remove(&idx);
        }
        let tmp = path.with_extension("bin.tmp");
        let file_bytes = segio::write_panel(&tmp, p)?;
        std::fs::rename(&tmp, &path).map_err(|e| {
            SegioError::Io(format!("publish panel {}: {e}", path.display()))
        })?;
        let mut st = lock(&self.state);
        st.metas.insert(
            idx,
            PanelMeta { nrows: p.nrows, ncols: p.ncols, file_bytes, path, chunks: Vec::new() },
        );
        Ok(file_bytes)
    }

    /// Spill panel `idx` as row-panel *chunk* records: one
    /// [`segio::KIND_PANEL`] record per `row_starts` interval
    /// (`row_starts[k] .. row_starts[k+1]`, the last running to
    /// `p.nrows`). The callers pass the *next* layer's RoBW plan
    /// boundaries, so a staged segment's aggregation window maps the
    /// fewest chunk records possible ([`Self::read_mapped`]) instead of
    /// one monolithic panel file.
    ///
    /// `row_starts` must begin at 0 and be strictly increasing within
    /// `0..nrows`. Each chunk write is atomic (temp file + rename), same
    /// crash discipline as [`Self::put`]; stale files from a previous
    /// spill of the slot with a different chunking are orphaned, not
    /// served — reads go through the in-memory manifest only. Returns the
    /// total encoded bytes across chunks.
    pub fn put_chunked(
        &self,
        idx: usize,
        p: &Dense,
        row_starts: &[usize],
    ) -> Result<u64, SegioError> {
        let valid = row_starts.first() == Some(&0)
            && row_starts.windows(2).all(|w| w[0] < w[1])
            && *row_starts.last().unwrap_or(&0) < p.nrows.max(1);
        if !valid {
            return Err(SegioError::InvalidPanel(format!(
                "panel {idx}: chunk row starts {row_starts:?} must begin at 0 and be \
                 strictly increasing below nrows={}",
                p.nrows
            )));
        }
        {
            let mut st = lock(&self.state);
            st.cache.remove(idx);
            st.metas.remove(&idx);
        }
        let mut chunks = Vec::with_capacity(row_starts.len());
        let mut total = 0u64;
        for (k, &lo) in row_starts.iter().enumerate() {
            let hi = row_starts.get(k + 1).copied().unwrap_or(p.nrows);
            let sub = Dense::from_vec(
                hi - lo,
                p.ncols,
                p.data[lo * p.ncols..hi * p.ncols].to_vec(),
            );
            let path = Self::chunk_path(&self.dir, idx, k);
            let tmp = path.with_extension("bin.tmp");
            let file_bytes = segio::write_panel(&tmp, &sub)?;
            std::fs::rename(&tmp, &path).map_err(|e| {
                SegioError::Io(format!("publish panel chunk {}: {e}", path.display()))
            })?;
            total += file_bytes;
            chunks.push(PanelChunk { row_lo: lo, row_hi: hi, file_bytes, path });
        }
        let mut st = lock(&self.state);
        st.metas.insert(
            idx,
            PanelMeta {
                nrows: p.nrows,
                ncols: p.ncols,
                file_bytes: total,
                path: Self::panel_path(&self.dir, idx),
                chunks,
            },
        );
        Ok(total)
    }

    /// Read panel `idx`: from the host tier when resident, else from disk
    /// (checksum-verified), updating the LRU state either way.
    pub fn read(&self, idx: usize) -> Result<(PanelRead, ReadOrigin), SegioError> {
        self.read_reusing(idx, None)
    }

    /// [`Self::read`] with recycled buffers: `pool` supplies the byte
    /// scratch and the panel slab a cache-bypassing read decodes into, and
    /// retires the byte scratch after the decode. Byte-for-byte the served
    /// panel is identical to [`Self::read`]'s.
    pub fn read_reusing(
        &self,
        idx: usize,
        pool: Option<&BufferPool>,
    ) -> Result<(PanelRead, ReadOrigin), SegioError> {
        let meta = {
            let mut st = lock(&self.state);
            if let Some(p) = st.cache.get(idx) {
                st.cache.touch(idx);
                st.cache.stats.hits += 1;
                return Ok((PanelRead::Shared(p), ReadOrigin { disk_bytes: 0, cache_hit: true }));
            }
            st.metas
                .get(&idx)
                .cloned()
                .ok_or_else(|| SegioError::Io(format!("panel {idx} was never spilled")))?
        };
        // Disk read outside the lock, like the segment tier. A read that
        // will land in the host tier decodes into exact-size fresh storage
        // (its buffer is donated to the cache); one that will not borrows
        // pooled scratch the caller's pipeline keeps circulating.
        let decoded = (meta.nrows * meta.ncols * 4) as u64;
        let likely_cached = self.cache_capacity > 0 && decoded <= self.cache_capacity;
        let (mut p, bytes) = if meta.chunks.is_empty() {
            Self::read_single(&meta, idx, likely_cached, pool)?
        } else {
            Self::read_chunks(&meta, idx, likely_cached, pool)?
        };
        let mut st = lock(&self.state);
        st.cache.stats.misses += 1;
        st.cache.stats.disk_bytes += bytes;
        let cost = panel_cost(&p);
        let cacheable = st.cache.capacity > 0 && cost <= st.cache.capacity;
        let result = if st.cache.entries.contains_key(&idx) || !cacheable {
            PanelRead::Owned(p)
        } else {
            // Donated to the cache: shrink so a resident panel pins only
            // its logical bytes (same discipline as the segment tier).
            p.data.shrink_to_fit();
            let shared = Arc::new(p);
            let inserted = st.cache.insert(idx, Arc::clone(&shared), cost);
            debug_assert!(inserted, "cacheability was checked above");
            PanelRead::Shared(shared)
        };
        let used = st.cache.used;
        st.cache.stats.resident_bytes = used;
        Ok((result, ReadOrigin { disk_bytes: bytes, cache_hit: false }))
    }

    /// Cache-miss path for a whole-panel record: decode `meta.path` into
    /// scratch (pooled when the panel will not be donated to the cache).
    fn read_single(
        meta: &PanelMeta,
        idx: usize,
        likely_cached: bool,
        pool: Option<&BufferPool>,
    ) -> Result<(Dense, u64), SegioError> {
        let mut p = match (likely_cached, pool) {
            // Empty scratch, not a zero-filled panel: the decode pushes
            // every element itself, so a take_panel memset would be pure
            // waste on the per-layer readback path.
            (false, Some(pool)) => Dense {
                nrows: 0,
                ncols: 0,
                data: pool.take_panel_scratch(meta.nrows * meta.ncols),
            },
            _ => Dense::zeros(0, 0),
        };
        let mut scratch = match pool {
            Some(pool) => pool.take_bytes(meta.file_bytes as usize),
            None => Vec::new(),
        };
        let read = segio::read_panel_into(&meta.path, &mut scratch, &mut p);
        if let Some(pool) = pool {
            pool.put_bytes(scratch);
        }
        let bytes = match read {
            Ok(b) => b,
            Err(e) => {
                if let Some(pool) = pool {
                    pool.put_panel(p.data);
                }
                return Err(e);
            }
        };
        if p.nrows != meta.nrows || p.ncols != meta.ncols {
            let err = SegioError::InvalidPanel(format!(
                "panel {idx} decoded to {}×{}, manifest says {}×{}",
                p.nrows, p.ncols, meta.nrows, meta.ncols
            ));
            if let Some(pool) = pool {
                pool.put_panel(p.data);
            }
            return Err(err);
        }
        Ok((p, bytes))
    }

    /// Cache-miss path for a chunked panel: validate each chunk record
    /// and copy its rows straight into their slot of the assembled panel
    /// ([`segio::PanelRef::fill_into`] — no intermediate `Dense` per
    /// chunk).
    fn read_chunks(
        meta: &PanelMeta,
        idx: usize,
        likely_cached: bool,
        pool: Option<&BufferPool>,
    ) -> Result<(Dense, u64), SegioError> {
        let mut data = match (likely_cached, pool) {
            (false, Some(pool)) => pool.take_panel_scratch(meta.nrows * meta.ncols),
            _ => Vec::new(),
        };
        data.clear();
        data.resize(meta.nrows * meta.ncols, 0.0);
        let max_chunk = meta.chunks.iter().map(|c| c.file_bytes).max().unwrap_or(0);
        let mut scratch = match pool {
            Some(pool) => pool.take_bytes(max_chunk as usize),
            None => Vec::new(),
        };
        let mut bytes = 0u64;
        let mut failure: Option<SegioError> = None;
        for c in &meta.chunks {
            match read_file_into(&c.path, &mut scratch) {
                Err(e) => {
                    failure = Some(e);
                    break;
                }
                Ok(n) => match segio::decode_panel_ref(&scratch) {
                    Err(e) => {
                        failure = Some(e);
                        break;
                    }
                    Ok(r) => {
                        if r.nrows != c.row_hi - c.row_lo || r.ncols != meta.ncols {
                            failure = Some(SegioError::InvalidPanel(format!(
                                "panel {idx} chunk rows [{}, {}) decoded to {}×{}, \
                                 manifest says {}×{}",
                                c.row_lo,
                                c.row_hi,
                                r.nrows,
                                r.ncols,
                                c.row_hi - c.row_lo,
                                meta.ncols
                            )));
                            break;
                        }
                        r.fill_into(
                            &mut data[c.row_lo * meta.ncols..c.row_hi * meta.ncols],
                        );
                        bytes += n;
                    }
                },
            }
        }
        if let Some(pool) = pool {
            pool.put_bytes(scratch);
        }
        if let Some(e) = failure {
            if let Some(pool) = pool {
                pool.put_panel(data);
            }
            return Err(e);
        }
        Ok((Dense { nrows: meta.nrows, ncols: meta.ncols, data }, bytes))
    }

    /// Zero-copy read of panel `idx`: mmap every chunk record (a
    /// whole-panel spill maps as one chunk), validate each in place, and
    /// serve rows straight from the page cache
    /// ([`PanelRead::Mapped`]). Bypasses the host-RAM tier like
    /// [`SegmentStore::read_mapped`], charging the summed encoded chunk
    /// sizes as disk bytes. Targets where in-place borrowing is
    /// unavailable fall back to [`Self::read_reusing`].
    pub fn read_mapped(
        &self,
        idx: usize,
        pool: Option<&BufferPool>,
    ) -> Result<(PanelRead, ReadOrigin), SegioError> {
        let meta = lock(&self.state)
            .metas
            .get(&idx)
            .cloned()
            .ok_or_else(|| SegioError::Io(format!("panel {idx} was never spilled")))?;
        let spans: Vec<(usize, usize, &Path)> = if meta.chunks.is_empty() {
            vec![(0, meta.nrows, meta.path.as_path())]
        } else {
            meta.chunks.iter().map(|c| (c.row_lo, c.row_hi, c.path.as_path())).collect()
        };
        let mut chunks = Vec::with_capacity(spans.len());
        let mut bytes = 0u64;
        for (lo, hi, path) in spans {
            let map = Mmap::map(path)
                .map_err(|e| SegioError::Io(format!("map {}: {e}", path.display())))?;
            let r = segio::decode_panel_ref(map.as_slice())?;
            if r.nrows != hi - lo || r.ncols != meta.ncols {
                return Err(SegioError::InvalidPanel(format!(
                    "panel {idx} rows [{lo}, {hi}) decoded to {}×{}, manifest says {}×{}",
                    r.nrows,
                    r.ncols,
                    hi - lo,
                    meta.ncols
                )));
            }
            if r.data_f32().is_none() {
                // Big-endian target: zero-copy is off the table.
                return self.read_reusing(idx, pool);
            }
            bytes += map.len() as u64;
            chunks.push(MappedPanelChunk { map, row_lo: lo, row_hi: hi });
        }
        {
            let mut st = lock(&self.state);
            st.cache.stats.misses += 1;
            st.cache.stats.disk_bytes += bytes;
        }
        Ok((
            PanelRead::Mapped(MappedPanelChunks {
                nrows: meta.nrows,
                ncols: meta.ncols,
                chunks,
            }),
            ReadOrigin { disk_bytes: bytes, cache_hit: false },
        ))
    }
}

/// Read a whole file into caller-recycled scratch (cleared and refilled),
/// returning its byte length — the chunk assembler's raw ingest.
fn read_file_into(path: &Path, buf: &mut Vec<u8>) -> Result<u64, SegioError> {
    use std::io::Read;
    buf.clear();
    let mut f = std::fs::File::open(path)
        .map_err(|e| SegioError::Io(format!("open {}: {e}", path.display())))?;
    f.read_to_end(buf)
        .map_err(|e| SegioError::Io(format!("read {}: {e}", path.display())))?;
    Ok(buf.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::robw::robw_partition;
    use crate::sparse::Coo;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn spilled_segments_reassemble_exactly() {
        let mut rng = Pcg::seed(200);
        let a = random_csr(&mut rng, 150, 40, 0.12);
        let segs = robw_partition(&a, 700);
        assert!(segs.len() > 2, "budget must force multiple segments");
        let dir = TempDir::new("segstore-rt");
        let store = SegmentStore::spill(&a, &segs, dir.path(), UNBOUNDED_CACHE).unwrap();
        assert_eq!(store.len(), segs.len());
        store.check_plan(&segs).unwrap();
        let parts: Vec<Csr> =
            (0..store.len()).map(|i| store.read(i).unwrap().0.into_csr()).collect();
        assert_eq!(Csr::vstack(&parts).unwrap(), a);
    }

    #[test]
    fn cache_disabled_always_reads_disk() {
        let mut rng = Pcg::seed(201);
        let a = random_csr(&mut rng, 80, 30, 0.15);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-nocache");
        let store = SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        for _ in 0..2 {
            for i in 0..store.len() {
                let (_, origin) = store.read(i).unwrap();
                assert!(!origin.cache_hit);
                assert!(origin.disk_bytes > 0);
            }
        }
        let st = store.stats();
        assert_eq!(st.hits, 0);
        assert_eq!(st.misses, 2 * segs.len());
        assert_eq!(st.resident_bytes, 0);
    }

    #[test]
    fn unbounded_cache_hits_on_second_pass() {
        let mut rng = Pcg::seed(202);
        let a = random_csr(&mut rng, 80, 30, 0.15);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-warm");
        let store = SegmentStore::spill(&a, &segs, dir.path(), UNBOUNDED_CACHE).unwrap();
        let first: Vec<Csr> =
            (0..store.len()).map(|i| store.read(i).unwrap().0.into_csr()).collect();
        let disk_after_first = store.stats().disk_bytes;
        for (i, want) in first.iter().enumerate() {
            let (m, origin) = store.read(i).unwrap();
            assert_eq!(m.csr(), want);
            assert!(origin.cache_hit, "segment {i} must be resident");
            assert_eq!(origin.disk_bytes, 0);
        }
        let st = store.stats();
        assert_eq!(st.misses, segs.len());
        assert_eq!(st.hits, segs.len());
        assert_eq!(st.disk_bytes, disk_after_first, "warm pass reads no disk");
    }

    #[test]
    fn lru_eviction_is_deterministic_and_bounded() {
        let mut rng = Pcg::seed(203);
        let a = random_csr(&mut rng, 120, 30, 0.2);
        let segs = robw_partition(&a, 512);
        assert!(segs.len() >= 4);
        // Budget for roughly two decoded segments.
        let seg_cost: u64 =
            segio::encoded_len(segs[0].row_hi - segs[0].row_lo, segs[0].nnz) - 64;
        let cap = seg_cost * 2 + 16;
        let dir = TempDir::new("segstore-lru");
        let run = |dir: &std::path::Path| {
            let store = SegmentStore::spill(&a, &segs, dir, cap).unwrap();
            let mut origins = Vec::new();
            // Sequential sweep twice, then a re-read of the coldest index.
            for _ in 0..2 {
                for i in 0..store.len() {
                    origins.push(store.read(i).unwrap().1);
                }
            }
            origins.push(store.read(0).unwrap().1);
            (origins, store.stats())
        };
        let d1 = TempDir::new("segstore-lru-b");
        let (o1, s1) = run(dir.path());
        let (o2, s2) = run(d1.path());
        assert_eq!(o1, o2, "cache behaviour must not depend on the directory/run");
        assert_eq!(s1, s2);
        assert!(s1.evictions > 0, "a bounded cache under a sweep must evict");
        assert!(s1.resident_bytes <= cap);
    }

    #[test]
    fn open_or_spill_reuses_valid_fixture_and_respills_stale_one() {
        let mut rng = Pcg::seed(204);
        let a = random_csr(&mut rng, 90, 25, 0.15);
        let segs = robw_partition(&a, 700);
        let dir = TempDir::new("segstore-fixture");
        let s1 = SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        let mtime = std::fs::metadata(&s1.meta(0).path).unwrap().modified().unwrap();
        let s2 = SegmentStore::open_or_spill(&a, &segs, dir.path(), 0).unwrap();
        assert_eq!(
            std::fs::metadata(&s2.meta(0).path).unwrap().modified().unwrap(),
            mtime,
            "byte-valid fixture must be reused, not rewritten"
        );
        let whole: Vec<Csr> = (0..s2.len()).map(|i| s2.read(i).unwrap().0.into_csr()).collect();
        assert_eq!(Csr::vstack(&whole).unwrap(), a);
        // Truncate one file: the size check must force a respill.
        let victim = s2.meta(1).path.clone();
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 3]).unwrap();
        let s3 = SegmentStore::open_or_spill(&a, &segs, dir.path(), 0).unwrap();
        let whole: Vec<Csr> = (0..s3.len()).map(|i| s3.read(i).unwrap().0.into_csr()).collect();
        assert_eq!(Csr::vstack(&whole).unwrap(), a, "respilled store serves good bytes");
        // A plan with a different segment count is never silently reused.
        let coarse = robw_partition(&a, u64::MAX / 8);
        assert_ne!(coarse.len(), segs.len());
        let s4 = SegmentStore::open_or_spill(&a, &coarse, dir.path(), 0).unwrap();
        assert_eq!(s4.len(), coarse.len());
        let coarse_read = s4.read(0).unwrap().0.into_csr();
        assert_eq!(coarse_read, a, "single coarse segment is the whole matrix");
    }

    #[test]
    fn open_or_spill_rejects_same_shaped_fixture_of_a_different_matrix() {
        // Same sparsity pattern, one value changed: every planned segment
        // has identical (rows, nnz) and therefore identical file *sizes*.
        // Only the fingerprint can tell the fixtures apart — without it,
        // reuse would silently serve the old matrix's bytes.
        let mut rng = Pcg::seed(206);
        let a = random_csr(&mut rng, 70, 20, 0.2);
        let mut b = a.clone();
        b.vals[0] += 1.0;
        let segs = robw_partition(&a, 500);
        let dir = TempDir::new("segstore-fp");
        SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        let sb = SegmentStore::open_or_spill(&b, &segs, dir.path(), 0).unwrap();
        let parts: Vec<Csr> = (0..sb.len()).map(|i| sb.read(i).unwrap().0.into_csr()).collect();
        assert_eq!(Csr::vstack(&parts).unwrap(), b, "store must serve b, not the stale a");
    }

    #[test]
    fn interrupted_spill_is_self_healing() {
        let mut rng = Pcg::seed(208);
        let a = random_csr(&mut rng, 80, 20, 0.2);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-interrupted");
        // Simulate a spill killed mid-way: marker on disk, one garbage
        // segment file, nothing else. The next open must respill cleanly.
        std::fs::write(dir.path().join("fingerprint"), 0u64.to_le_bytes()).unwrap();
        std::fs::write(SegmentStore::seg_path(dir.path(), 0), b"partial").unwrap();
        let store = SegmentStore::open_or_spill(&a, &segs, dir.path(), 0).unwrap();
        let parts: Vec<Csr> =
            (0..store.len()).map(|i| store.read(i).unwrap().0.into_csr()).collect();
        assert_eq!(Csr::vstack(&parts).unwrap(), a);
    }

    #[test]
    fn open_or_spill_never_wipes_a_directory_that_is_not_a_store() {
        let mut rng = Pcg::seed(207);
        let a = random_csr(&mut rng, 60, 20, 0.2);
        let segs = robw_partition(&a, 600);
        // Non-empty directory without a fingerprint marker: refuse.
        let dir = TempDir::new("segstore-guard");
        let precious = dir.path().join("user-data.txt");
        std::fs::write(&precious, b"do not delete").unwrap();
        let err = SegmentStore::open_or_spill(&a, &segs, dir.path(), 0).unwrap_err();
        assert!(err.to_string().contains("refusing to respill"), "{err}");
        assert!(precious.exists(), "foreign files must survive the refusal");
        // A real (stale) store dir with a foreign file alongside: respill
        // touches only store files and leaves the foreign one alone.
        let dir2 = TempDir::new("segstore-guard2");
        let other = robw_partition(&a, 300);
        SegmentStore::spill(&a, &other, dir2.path(), 0).unwrap();
        let precious2 = dir2.path().join("notes.md");
        std::fs::write(&precious2, b"keep me").unwrap();
        let store = SegmentStore::open_or_spill(&a, &segs, dir2.path(), 0).unwrap();
        assert_eq!(store.len(), segs.len());
        assert!(precious2.exists(), "respill must only remove seg-*.bin + fingerprint");
        // No leftovers from the longer stale plan.
        assert!(!SegmentStore::seg_path(dir2.path(), segs.len()).exists());
    }

    #[test]
    fn panel_store_roundtrips_and_serves_from_cache() {
        let mut rng = Pcg::seed(210);
        let dir = TempDir::new("panelstore-rt");
        let store = PanelStore::new(dir.path(), UNBOUNDED_CACHE).unwrap();
        assert!(store.is_empty());
        let p0 = Dense::from_vec(6, 4, (0..24).map(|_| rng.normal() as f32).collect());
        let p1 = Dense::from_vec(6, 3, (0..18).map(|_| rng.normal() as f32).collect());
        let b0 = store.put(0, &p0).unwrap();
        store.put(1, &p1).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.meta(0).unwrap().file_bytes, b0);
        assert_eq!(b0, segio::encoded_panel_len(6, 4));

        // First read misses to disk, second is a host-tier hit.
        let (r0, o0) = store.read(0).unwrap();
        assert_eq!(r0.dense(), &p0);
        assert!(!o0.cache_hit);
        assert_eq!(o0.disk_bytes, b0);
        let (r0b, o0b) = store.read(0).unwrap();
        assert_eq!(r0b.dense(), &p0);
        assert!(o0b.cache_hit);
        assert_eq!(o0b.disk_bytes, 0);
        assert_eq!(store.read(1).unwrap().0.into_dense(), p1);
        let st = store.stats();
        assert_eq!((st.hits, st.misses), (1, 2));

        // A never-spilled slot is a typed error.
        assert!(matches!(store.read(7), Err(SegioError::Io(_))));
    }

    #[test]
    fn panel_rewrite_invalidates_the_cached_copy() {
        let dir = TempDir::new("panelstore-rewrite");
        let store = PanelStore::new(dir.path(), UNBOUNDED_CACHE).unwrap();
        let old = Dense::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        store.put(0, &old).unwrap();
        let (r, _) = store.read(0).unwrap();
        assert_eq!(r.dense(), &old);
        // Rewrite the slot: the resident copy must not survive.
        let new = Dense::from_vec(2, 2, vec![9.0, 8.0, 7.0, 6.0]);
        store.put(0, &new).unwrap();
        let (r2, o2) = store.read(0).unwrap();
        assert_eq!(r2.dense(), &new, "rewritten slot must serve the new bytes");
        assert!(!o2.cache_hit, "stale cache entry must have been dropped");
    }

    #[test]
    fn panel_cache_disabled_reads_disk_and_recycles_scratch() {
        let dir = TempDir::new("panelstore-nocache");
        let store = PanelStore::new(dir.path(), 0).unwrap();
        let p = Dense::from_vec(5, 3, (0..15).map(|i| i as f32).collect());
        store.put(0, &p).unwrap();
        let pool = BufferPool::new(1 << 20);
        for _ in 0..3 {
            let (r, o) = store.read_reusing(0, Some(&pool)).unwrap();
            assert!(!o.cache_hit);
            assert!(o.disk_bytes > 0);
            match r {
                PanelRead::Owned(d) => {
                    assert_eq!(d, p);
                    pool.put_panel(d.data);
                }
                PanelRead::Shared(_) => panic!("cacheless reads are owned"),
            }
        }
        let st = pool.stats();
        assert!(st.hits > 0, "byte + panel scratch must cycle through the pool: {st:?}");
        assert_eq!(store.stats().hits, 0);
        assert_eq!(store.stats().resident_bytes, 0);
    }

    #[test]
    fn quarantine_and_rebuild_replaces_a_corrupt_segment() {
        let mut rng = Pcg::seed(209);
        let a = random_csr(&mut rng, 90, 25, 0.15);
        let segs = robw_partition(&a, 600);
        assert!(segs.len() > 2);
        let dir = TempDir::new("segstore-quarantine");
        let store = SegmentStore::spill(&a, &segs, dir.path(), UNBOUNDED_CACHE).unwrap();
        let victim = 1usize;
        // Warm the host tier, then corrupt the file *behind* it: the
        // rebuild must also drop the resident copy, not just fix the disk.
        let _ = store.read(victim).unwrap();
        let path = store.meta(victim).path.clone();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        // A mismatched plan entry is refused before anything is touched.
        assert!(store.quarantine_and_rebuild(victim, &a, &segs[0]).is_err());
        assert!(path.exists(), "refusal must not quarantine the file");
        store.quarantine_and_rebuild(victim, &a, &segs[victim]).unwrap();
        let q = path.with_extension("bin.quarantined");
        assert!(q.exists(), "corrupt bytes preserved at {}", q.display());
        assert_eq!(std::fs::read(&q).unwrap(), bytes, "quarantine keeps the evidence");
        let (r, o) = store.read(victim).unwrap();
        assert!(!o.cache_hit, "rebuild must drop the stale resident copy");
        assert_eq!(r.csr(), &materialize(&a, &segs[victim]));
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            store.meta(victim).file_bytes,
            "rebuilt file matches the manifest size exactly"
        );
    }

    #[test]
    fn panel_put_is_atomic_against_kill_mid_rewrite() {
        let dir = TempDir::new("panelstore-atomic");
        let store = PanelStore::new(dir.path(), 0).unwrap();
        let old = Dense::from_vec(3, 3, (0..9).map(|i| i as f32).collect());
        store.put(0, &old).unwrap();
        // Simulate a process killed mid-rewrite: the half-written bytes
        // live only in the temp file; the published panel is untouched.
        let path = store.meta(0).unwrap().path;
        let tmp = path.with_extension("bin.tmp");
        std::fs::write(&tmp, b"torn half-written panel").unwrap();
        let (r, _) = store.read(0).unwrap();
        assert_eq!(r.dense(), &old, "published panel survives a torn rewrite");
        // A completed rewrite replaces the slot and consumes the temp file.
        let new = Dense::from_vec(3, 3, (9..18).map(|i| i as f32).collect());
        store.put(0, &new).unwrap();
        assert!(!tmp.exists(), "rename consumed the temp file");
        assert_eq!(store.read(0).unwrap().0.dense(), &new);
    }

    #[test]
    fn panel_corruption_surfaces_typed_errors() {
        let dir = TempDir::new("panelstore-fault");
        let store = PanelStore::new(dir.path(), 0).unwrap();
        let p = Dense::from_vec(4, 4, (0..16).map(|i| i as f32 * 0.5).collect());
        store.put(0, &p).unwrap();
        let path = store.meta(0).unwrap().path;
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        assert!(matches!(store.read(0), Err(SegioError::PayloadChecksum { .. })));
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        assert!(matches!(store.read(0), Err(SegioError::Truncated { .. })));
    }

    #[test]
    fn check_plan_rejects_mismatches() {
        let mut rng = Pcg::seed(205);
        let a = random_csr(&mut rng, 60, 20, 0.2);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-plan");
        let store = SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        store.check_plan(&segs).unwrap();
        let other = robw_partition(&a, 300);
        assert!(store.check_plan(&other).is_err());
    }

    #[test]
    fn encoded_spills_roundtrip_and_key_fixtures_by_encoding() {
        let mut rng = Pcg::seed(211);
        let a = random_csr(&mut rng, 150, 40, 0.12);
        let segs = robw_partition(&a, 700);
        for enc in [SegEncoding::Raw, SegEncoding::Packed, SegEncoding::Auto] {
            let dir = TempDir::new("segstore-enc");
            let store =
                SegmentStore::spill_encoded(&a, &segs, dir.path(), 0, enc).unwrap();
            for i in 0..store.len() {
                let m = store.meta(i);
                assert_eq!(
                    std::fs::metadata(&m.path).unwrap().len(),
                    m.file_bytes,
                    "manifest size must be the on-disk size under {enc}"
                );
                match enc {
                    SegEncoding::Raw => assert_eq!(m.kind, segio::KIND_CSR),
                    SegEncoding::Packed => assert_eq!(m.kind, segio::KIND_CSR_PACKED),
                    SegEncoding::Auto => assert!(
                        m.kind == segio::KIND_CSR || m.kind == segio::KIND_CSR_PACKED
                    ),
                }
            }
            let parts: Vec<Csr> =
                (0..store.len()).map(|i| store.read(i).unwrap().0.into_csr()).collect();
            assert_eq!(Csr::vstack(&parts).unwrap(), a, "encoding {enc} must serve a");
            // Reuse requires the same encoding mode...
            let mtime =
                std::fs::metadata(&store.meta(0).path).unwrap().modified().unwrap();
            let again =
                SegmentStore::open_or_spill_encoded(&a, &segs, dir.path(), 0, enc).unwrap();
            assert_eq!(
                std::fs::metadata(&again.meta(0).path).unwrap().modified().unwrap(),
                mtime,
                "same-mode fixture must be reused under {enc}"
            );
            // ...and a different mode respills rather than mis-reading.
            let other = match enc {
                SegEncoding::Raw => SegEncoding::Packed,
                _ => SegEncoding::Raw,
            };
            let cross =
                SegmentStore::open_or_spill_encoded(&a, &segs, dir.path(), 0, other).unwrap();
            let parts: Vec<Csr> =
                (0..cross.len()).map(|i| cross.read(i).unwrap().0.into_csr()).collect();
            assert_eq!(Csr::vstack(&parts).unwrap(), a, "cross-mode open must respill");
        }
        // Packed spills of real planned segments must actually shrink disk.
        let dir_raw = TempDir::new("segstore-enc-raw");
        let dir_packed = TempDir::new("segstore-enc-packed");
        let raw =
            SegmentStore::spill_encoded(&a, &segs, dir_raw.path(), 0, SegEncoding::Raw).unwrap();
        let packed =
            SegmentStore::spill_encoded(&a, &segs, dir_packed.path(), 0, SegEncoding::Packed)
                .unwrap();
        let total = |s: &SegmentStore| (0..s.len()).map(|i| s.meta(i).file_bytes).sum::<u64>();
        assert!(
            total(&packed) < total(&raw),
            "packed {} must beat raw {}",
            total(&packed),
            total(&raw)
        );
    }

    #[test]
    fn v1_marker_triggers_a_clean_respill() {
        let mut rng = Pcg::seed(212);
        let a = random_csr(&mut rng, 80, 20, 0.2);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-v1marker");
        let store = SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        // Overwrite the v2 marker with a v1-style bare fingerprint: the
        // next open must fail the parse and respill, not trust the files.
        std::fs::write(dir.path().join("fingerprint"), fingerprint(&a, &segs).to_le_bytes())
            .unwrap();
        let mtime = std::fs::metadata(&store.meta(0).path).unwrap().modified().unwrap();
        // File mtime granularity can be coarse; force a distinguishable
        // rewrite by corrupting a segment so identity also proves respill.
        let victim = store.meta(0).path.clone();
        let mut bytes = std::fs::read(&victim).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&victim, &bytes).unwrap();
        let reopened = SegmentStore::open_or_spill(&a, &segs, dir.path(), 0).unwrap();
        let parts: Vec<Csr> =
            (0..reopened.len()).map(|i| reopened.read(i).unwrap().0.into_csr()).collect();
        assert_eq!(Csr::vstack(&parts).unwrap(), a, "v1 marker must not be trusted");
        let _ = mtime;
    }

    #[test]
    fn mapped_reads_serve_identical_bytes_and_recycle_scratch() {
        let mut rng = Pcg::seed(213);
        let a = random_csr(&mut rng, 150, 40, 0.12);
        let segs = robw_partition(&a, 700);
        assert!(segs.len() > 2);
        for enc in [SegEncoding::Raw, SegEncoding::Packed, SegEncoding::Auto] {
            let dir = TempDir::new("segstore-mmap");
            let store =
                SegmentStore::spill_encoded(&a, &segs, dir.path(), 0, enc).unwrap();
            let pool = BufferPool::new(1 << 20);
            let mut recycled: Option<Csr> = None;
            let mut parts = Vec::new();
            for i in 0..store.len() {
                let (r, o) = store.read_mapped(i, recycled.take(), Some(&pool)).unwrap();
                assert!(!o.cache_hit);
                assert_eq!(o.disk_bytes, store.meta(i).file_bytes);
                // The view is the kernel-facing contract; materialize it
                // for the vstack identity check.
                let v = r.view();
                assert_eq!(v.nnz(), store.meta(i).nnz);
                if store.meta(i).kind == segio::KIND_CSR {
                    assert!(
                        matches!(r, SegmentRead::Mapped(_)),
                        "raw segments must be served zero-copy"
                    );
                }
                parts.push(r.clone().into_csr());
                recycled = r.reclaim();
            }
            assert_eq!(Csr::vstack(&parts).unwrap(), a, "mapped read identity under {enc}");
        }
    }

    #[test]
    fn mapped_read_surfaces_corruption_as_typed_errors() {
        let mut rng = Pcg::seed(214);
        let a = random_csr(&mut rng, 90, 25, 0.15);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-mmap-fault");
        let store = SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        let path = store.meta(1).path.clone();
        let good = std::fs::read(&path).unwrap();
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        assert!(matches!(
            store.read_mapped(1, None, None),
            Err(SegioError::PayloadChecksum { .. })
        ));
        std::fs::write(&path, &good[..good.len() / 2]).unwrap();
        assert!(matches!(
            store.read_mapped(1, None, None),
            Err(SegioError::Truncated { .. })
        ));
        std::fs::remove_file(&path).unwrap();
        assert!(matches!(store.read_mapped(1, None, None), Err(SegioError::Io(_))));
    }

    #[test]
    fn quarantine_rebuild_preserves_the_packed_encoding() {
        let mut rng = Pcg::seed(215);
        let a = random_csr(&mut rng, 90, 25, 0.15);
        let segs = robw_partition(&a, 600);
        let dir = TempDir::new("segstore-heal-packed");
        let store =
            SegmentStore::spill_encoded(&a, &segs, dir.path(), 0, SegEncoding::Packed).unwrap();
        let victim = 1usize;
        assert_eq!(store.meta(victim).kind, segio::KIND_CSR_PACKED);
        let path = store.meta(victim).path.clone();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        store.quarantine_and_rebuild(victim, &a, &segs[victim]).unwrap();
        let healed = std::fs::read(&path).unwrap();
        assert_eq!(healed.len() as u64, store.meta(victim).file_bytes);
        assert_eq!(
            segio::decode_segment(&healed).unwrap(),
            materialize(&a, &segs[victim]),
            "healed packed segment must decode to the planned rows"
        );
        // The healed record is still packed, not silently re-encoded raw.
        assert_eq!(
            u32::from_le_bytes(healed[12..16].try_into().unwrap()),
            segio::KIND_CSR_PACKED
        );
    }

    #[test]
    fn chunked_panels_assemble_and_serve_mapped_rows() {
        let mut rng = Pcg::seed(216);
        let dir = TempDir::new("panelstore-chunks");
        let store = PanelStore::new(dir.path(), 0).unwrap();
        let p = Dense::from_vec(10, 3, (0..30).map(|_| rng.normal() as f32).collect());
        // Invalid chunkings are typed errors, not torn spills.
        assert!(matches!(
            store.put_chunked(0, &p, &[1, 4]),
            Err(SegioError::InvalidPanel(_))
        ));
        assert!(matches!(
            store.put_chunked(0, &p, &[0, 4, 4]),
            Err(SegioError::InvalidPanel(_))
        ));
        let total = store.put_chunked(0, &p, &[0, 4, 9]).unwrap();
        let meta = store.meta(0).unwrap();
        assert_eq!(meta.chunks.len(), 3);
        assert_eq!(meta.file_bytes, total);
        assert_eq!(
            total,
            segio::encoded_panel_len(4, 3)
                + segio::encoded_panel_len(5, 3)
                + segio::encoded_panel_len(1, 3)
        );
        // Assembled copy-decode read equals the original panel.
        let (r, o) = store.read(0).unwrap();
        assert_eq!(r.dense(), &p);
        assert!(!o.cache_hit);
        assert_eq!(o.disk_bytes, total);
        // Mapped read serves identical rows without materializing.
        let (m, om) = store.read_mapped(0, None).unwrap();
        assert_eq!(om.disk_bytes, total);
        match m.src() {
            PanelSrc::Mapped(chunks) => {
                for r in 0..p.nrows {
                    assert_eq!(chunks.row(r), p.row(r), "mapped row {r}");
                }
            }
            PanelSrc::Dense(_) => panic!("chunked mapped read must borrow the mapping"),
        }
        assert_eq!(m.into_dense(), p);
        // A rewrite with a different chunking replaces the manifest; the
        // orphaned third chunk file is never served.
        let q = Dense::from_vec(10, 3, (0..30).map(|i| i as f32).collect());
        store.put_chunked(0, &q, &[0, 5]).unwrap();
        assert_eq!(store.read(0).unwrap().0.into_dense(), q);
        // Whole-panel spills also serve through the mapped path.
        store.put(1, &p).unwrap();
        let (m1, _) = store.read_mapped(1, None).unwrap();
        match m1.src() {
            PanelSrc::Mapped(chunks) => {
                assert_eq!(RowSrc::nrows(chunks), p.nrows);
                for r in 0..p.nrows {
                    assert_eq!(chunks.row(r), p.row(r));
                }
            }
            PanelSrc::Dense(_) => panic!("whole-panel mapped read must borrow the mapping"),
        }
    }
}
