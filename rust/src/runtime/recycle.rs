//! Deterministic buffer recycling for the staging pipeline.
//!
//! AIRES names sparse-format **memory allocation** (next to data
//! alignment) as the dominant cost of out-of-core SpGEMM, yet the Phase II
//! streaming path used to allocate a fresh byte buffer, a fresh CSR
//! triple, and a fresh dense partial for *every* staged segment. This
//! module is the fix: a std-only [`BufferPool`] of reusable slabs that the
//! whole staging pipeline draws from — the prefetch producer takes
//! decode scratch here, the consumer hands drained segment buffers back
//! through the [`Prefetch::run_recycling`](crate::runtime::prefetch::Prefetch::run_recycling)
//! return channel, and the `gcn::pipeline` streaming engine computes every
//! partial straight into one per-layer output panel (whose slab circulates
//! across layers of a multi-layer pass). In steady state the hot loop
//! performs **zero heap allocations per segment** (enforced by the
//! counting-allocator test in `rust/tests/alloc_free.rs`).
//!
//! Determinism: recycling changes only *where buffer capacity comes from*,
//! never the bytes written through it — every staged segment is fully
//! overwritten before compute sees it, so recycled and fresh passes are
//! byte-identical (swept in `rust/tests/differential.rs`). Retention is
//! bounded: a pool never holds more than its high-water cap of slab
//! capacity; buffers returned beyond the cap are simply dropped.

use crate::sparse::Csr;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: the slab lists are valid at every instruction
/// boundary, so when a streaming worker panics the original payload must
/// surface at the join — not a secondary `PoisonError` panic from the
/// next thread that takes or returns a slab.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Default retention cap for CLI-constructed pools: generous enough to
/// hold a few staged segments plus decode scratch at any paper-scale
/// budget, small enough to never matter next to the feature panel.
pub const DEFAULT_RECYCLE_CAP: u64 = 256 << 20;

/// Counters of one pool's serving behaviour since creation.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecycleStats {
    /// `take_*` calls served from a retained slab (no fresh allocation).
    pub hits: usize,
    /// `take_*` calls that had to allocate a fresh slab.
    pub misses: usize,
    /// Buffers handed back through `put_*`.
    pub returns: usize,
    /// Returned buffers dropped because retaining them would exceed the cap.
    pub drops: usize,
    /// Slab capacity bytes currently retained (idle in the pool).
    pub retained_bytes: u64,
    /// High-water mark of `retained_bytes` over the pool's lifetime.
    pub retained_peak_bytes: u64,
}

#[derive(Debug, Default)]
struct Slabs {
    /// Idle CSR scratch (empty vectors, capacity retained). LIFO so the
    /// most recently drained (cache-warm) slab is reused first.
    csr: Vec<Csr>,
    /// Idle byte buffers (file-read scratch).
    bytes: Vec<Vec<u8>>,
    /// Idle dense panels (f32 slabs).
    panels: Vec<Vec<f32>>,
    stats: RecycleStats,
}

/// Capacity bytes a CSR scratch pins while idle in the pool.
fn csr_slab_bytes(m: &Csr) -> u64 {
    m.rowptr.capacity() as u64 * std::mem::size_of::<usize>() as u64
        + m.colidx.capacity() as u64 * 4
        + m.vals.capacity() as u64 * 4
}

/// Bounded pool of reusable staging buffers.
///
/// All methods take `&self` (internally mutex-guarded), so the prefetch
/// producer and the consuming thread can share one pool. `take_*` pops the
/// most recently returned slab and grows it to the requested capacity
/// (a no-op once capacities have reached the plan's high-water mark);
/// `put_*` retains the buffer unless the pool is already at its cap, in
/// which case the buffer is dropped (CSR scratch and panels come back
/// cleared; byte buffers keep their stale contents — see
/// [`BufferPool::take_bytes`]).
///
/// # Examples
///
/// ```
/// use aires::runtime::recycle::BufferPool;
///
/// let pool = BufferPool::new(1 << 20);
/// let buf = pool.take_bytes(4096);
/// assert!(buf.capacity() >= 4096);
/// pool.put_bytes(buf);
/// // The second take reuses the retained slab: a hit, not an allocation.
/// let again = pool.take_bytes(4096);
/// assert_eq!(pool.stats().hits, 1);
/// drop(again);
/// ```
#[derive(Debug)]
pub struct BufferPool {
    cap_bytes: u64,
    slabs: Mutex<Slabs>,
}

impl BufferPool {
    /// Pool retaining at most `cap_bytes` of idle slab capacity
    /// (`0` retains nothing: every `put_*` drops, every `take_*` allocates
    /// — the degenerate "fresh" behaviour, useful for A/B benches).
    pub fn new(cap_bytes: u64) -> BufferPool {
        BufferPool { cap_bytes, slabs: Mutex::new(Slabs::default()) }
    }

    /// Retention cap this pool was built with.
    pub fn cap_bytes(&self) -> u64 {
        self.cap_bytes
    }

    /// Serving counters since the pool was created.
    pub fn stats(&self) -> RecycleStats {
        lock(&self.slabs).stats
    }

    /// Take a byte buffer with capacity at least `min_cap`. Contents and
    /// length are **unspecified** (whatever the previous user left — every
    /// consumer overwrites before reading, and preserving the length lets
    /// `read_segment_into`'s resize skip the full zero-fill in steady
    /// state).
    pub fn take_bytes(&self, min_cap: usize) -> Vec<u8> {
        let mut s = lock(&self.slabs);
        match s.bytes.pop() {
            Some(mut b) => {
                s.stats.hits += 1;
                s.stats.retained_bytes -= b.capacity() as u64;
                drop(s);
                if b.capacity() < min_cap {
                    b.reserve(min_cap - b.len());
                }
                b
            }
            None => {
                s.stats.misses += 1;
                drop(s);
                Vec::with_capacity(min_cap)
            }
        }
    }

    /// Return a byte buffer to the pool (dropped beyond the cap).
    pub fn put_bytes(&self, b: Vec<u8>) {
        self.retain(b.capacity() as u64, b, |s| &mut s.bytes);
    }

    /// Take empty CSR scratch whose sections can hold `rows` rows and
    /// `nnz` stored entries without reallocating. Callers streaming a
    /// planned segment sequence should pass the *plan-wide maxima* so the
    /// first take already covers every later segment.
    pub fn take_csr(&self, rows: usize, nnz: usize) -> Csr {
        let popped = {
            let mut s = lock(&self.slabs);
            match s.csr.pop() {
                Some(m) => {
                    s.stats.hits += 1;
                    s.stats.retained_bytes -= csr_slab_bytes(&m);
                    Some(m)
                }
                None => {
                    s.stats.misses += 1;
                    None
                }
            }
        };
        let mut m = popped.unwrap_or_else(|| Csr::empty(0, 0));
        reserve_csr(&mut m, rows, nnz);
        m
    }

    /// Return CSR scratch to the pool (cleared; dropped beyond the cap).
    pub fn put_csr(&self, mut m: Csr) {
        m.nrows = 0;
        m.ncols = 0;
        m.rowptr.clear();
        m.colidx.clear();
        m.vals.clear();
        let cost = csr_slab_bytes(&m);
        self.retain(cost, m, |s| &mut s.csr);
    }

    /// Take a dense f32 panel of exactly `len` elements, zero-filled.
    pub fn take_panel(&self, len: usize) -> Vec<f32> {
        let mut p = self.pop_panel(len);
        p.resize(len, 0.0);
        p
    }

    /// Take an **empty** f32 slab with capacity at least `min_cap` — the
    /// panel analog of [`Self::take_bytes`] for callers that push every
    /// element themselves (e.g. a panel decode): no zero-fill is paid for
    /// contents that are about to be overwritten.
    pub fn take_panel_scratch(&self, min_cap: usize) -> Vec<f32> {
        self.pop_panel(min_cap)
    }

    /// Pop (or allocate) a cleared panel slab with capacity ≥ `min_cap`.
    fn pop_panel(&self, min_cap: usize) -> Vec<f32> {
        let popped = {
            let mut s = lock(&self.slabs);
            match s.panels.pop() {
                Some(p) => {
                    s.stats.hits += 1;
                    s.stats.retained_bytes -= p.capacity() as u64 * 4;
                    Some(p)
                }
                None => {
                    s.stats.misses += 1;
                    None
                }
            }
        };
        let mut p = popped.unwrap_or_default();
        p.clear();
        p.reserve(min_cap);
        p
    }

    /// Return a dense panel to the pool (cleared; dropped beyond the cap).
    pub fn put_panel(&self, mut p: Vec<f32>) {
        p.clear();
        let cost = p.capacity() as u64 * 4;
        self.retain(cost, p, |s| &mut s.panels);
    }

    /// Shared retention policy of every `put_*`: count the return, drop
    /// the slab when retaining `cost` more bytes would exceed the cap,
    /// else account it and push onto its free list.
    fn retain<T>(&self, cost: u64, item: T, select: impl FnOnce(&mut Slabs) -> &mut Vec<T>) {
        let mut s = lock(&self.slabs);
        s.stats.returns += 1;
        if s.stats.retained_bytes + cost > self.cap_bytes {
            s.stats.drops += 1;
            return;
        }
        s.stats.retained_bytes += cost;
        s.stats.retained_peak_bytes = s.stats.retained_peak_bytes.max(s.stats.retained_bytes);
        select(&mut *s).push(item);
    }
}

/// Grow `m`'s sections so `rows` rows / `nnz` entries fit without
/// reallocation. The vectors are empty here, so `reserve(n)` is a no-op
/// whenever capacity already covers `n`.
fn reserve_csr(m: &mut Csr, rows: usize, nnz: usize) {
    debug_assert!(m.rowptr.is_empty() && m.colidx.is_empty() && m.vals.is_empty());
    m.rowptr.reserve(rows + 1);
    m.colidx.reserve(nnz);
    m.vals.reserve(nnz);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_put_cycle_reuses_capacity() {
        let pool = BufferPool::new(1 << 20);
        let b = pool.take_bytes(1000);
        assert_eq!(pool.stats().misses, 1);
        let cap = b.capacity();
        pool.put_bytes(b);
        assert_eq!(pool.stats().returns, 1);
        assert_eq!(pool.stats().retained_bytes, cap as u64);
        let b2 = pool.take_bytes(500);
        assert_eq!(pool.stats().hits, 1);
        assert!(b2.capacity() >= cap, "smaller request reuses the big slab");
        assert_eq!(pool.stats().retained_bytes, 0);
    }

    #[test]
    fn csr_scratch_roundtrip_preserves_capacity_and_clears_contents() {
        let pool = BufferPool::new(1 << 20);
        let mut m = pool.take_csr(100, 400);
        assert!(m.rowptr.capacity() >= 101);
        assert!(m.colidx.capacity() >= 400 && m.vals.capacity() >= 400);
        // Simulate a decode filling it.
        m.nrows = 1;
        m.ncols = 2;
        m.rowptr.extend([0, 1]);
        m.colidx.push(1);
        m.vals.push(2.5);
        pool.put_csr(m);
        let m2 = pool.take_csr(10, 10);
        assert_eq!((m2.nrows, m2.ncols, m2.nnz()), (0, 0, 0), "returned scratch is cleared");
        assert!(m2.rowptr.is_empty());
        assert!(m2.colidx.capacity() >= 400, "capacity survives the round trip");
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn panels_come_back_zeroed_at_the_requested_length() {
        let pool = BufferPool::new(1 << 20);
        let mut p = pool.take_panel(8);
        assert_eq!(p, vec![0.0; 8]);
        p.iter_mut().for_each(|v| *v = 7.0);
        pool.put_panel(p);
        let p2 = pool.take_panel(5);
        assert_eq!(p2, vec![0.0; 5], "reused panel is re-zeroed and resized");
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn panel_scratch_skips_the_zero_fill_but_keeps_capacity() {
        let pool = BufferPool::new(1 << 20);
        let mut p = pool.take_panel_scratch(64);
        assert!(p.is_empty(), "scratch comes back empty, not zero-filled");
        assert!(p.capacity() >= 64);
        p.extend(std::iter::repeat(3.0).take(64));
        pool.put_panel(p);
        let p2 = pool.take_panel_scratch(16);
        assert!(p2.is_empty());
        assert!(p2.capacity() >= 64, "capacity survives the round trip");
        assert_eq!(pool.stats().hits, 1);
    }

    #[test]
    fn cap_bounds_retention_and_counts_drops() {
        // Cap below one slab: every return is dropped, takes always miss.
        let pool = BufferPool::new(16);
        let b = pool.take_bytes(1024);
        pool.put_bytes(b);
        let st = pool.stats();
        assert_eq!(st.drops, 1);
        assert_eq!(st.retained_bytes, 0);
        let _ = pool.take_bytes(8);
        assert_eq!(pool.stats().misses, 2, "dropped slab cannot be reused");

        // Cap of zero is the degenerate always-fresh pool.
        let fresh = BufferPool::new(0);
        fresh.put_panel(vec![1.0; 64]);
        assert_eq!(fresh.stats().drops, 1);
        assert_eq!(fresh.stats().retained_bytes, 0);
    }

    #[test]
    fn retained_peak_tracks_high_water() {
        let pool = BufferPool::new(1 << 20);
        let a = pool.take_bytes(1000);
        let b = pool.take_bytes(2000);
        let (ca, cb) = (a.capacity() as u64, b.capacity() as u64);
        pool.put_bytes(a);
        pool.put_bytes(b);
        assert_eq!(pool.stats().retained_peak_bytes, ca + cb);
        let _ = pool.take_bytes(1);
        assert_eq!(pool.stats().retained_peak_bytes, ca + cb, "peak is monotone");
        assert!(pool.stats().retained_bytes < ca + cb);
    }

    #[test]
    fn pool_is_shareable_across_threads() {
        let pool = BufferPool::new(1 << 20);
        std::thread::scope(|s| {
            let p = &pool;
            s.spawn(move || {
                for _ in 0..100 {
                    p.put_bytes(p.take_bytes(256));
                }
            });
            for _ in 0..100 {
                pool.put_csr(pool.take_csr(16, 64));
            }
        });
        let st = pool.stats();
        assert_eq!(st.hits + st.misses, 200);
        assert_eq!(st.returns, 200);
    }
}
