//! PJRT executor: compile HLO-text artifacts once, execute many times.
//!
//! Mirrors /opt/xla-example/load_hlo: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `PjRtClient::compile` → `execute`. The
//! artifacts were lowered with `return_tuple=True`, so every result is a
//! tuple literal which we decompose into per-output literals.

use super::artifacts::{ArtifactSpec, DType, Manifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// A typed input buffer for an execution.
#[derive(Debug, Clone)]
pub enum Buf {
    /// Flat f32 payload.
    F32(Vec<f32>),
    /// Flat i32 payload.
    S32(Vec<i32>),
}

impl Buf {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            Buf::F32(v) => v.len(),
            Buf::S32(v) => v.len(),
        }
    }

    /// True when the buffer holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element type of the payload.
    pub fn dtype(&self) -> DType {
        match self {
            Buf::F32(_) => DType::F32,
            Buf::S32(_) => DType::S32,
        }
    }

    /// Borrow as f32, erroring on an i32 payload.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Buf::F32(v) => Ok(v),
            _ => bail!("expected f32 buffer"),
        }
    }

    /// Borrowed view of the payload (clone-free literal building).
    pub fn view(&self) -> BufView<'_> {
        match self {
            Buf::F32(v) => BufView::F32(v),
            Buf::S32(v) => BufView::S32(v),
        }
    }
}

/// Borrowed view of an input buffer: lets callers build execution
/// literals straight from slices they already own, without wrapping them
/// in an owned [`Buf`] first (the training loop used to deep-copy its
/// constant graph/feature/label buffers on every SGD step for exactly
/// this reason).
#[derive(Debug, Clone, Copy)]
pub enum BufView<'a> {
    /// Flat f32 payload.
    F32(&'a [f32]),
    /// Flat i32 payload.
    S32(&'a [i32]),
}

impl BufView<'_> {
    /// Element count.
    pub fn len(&self) -> usize {
        match self {
            BufView::F32(v) => v.len(),
            BufView::S32(v) => v.len(),
        }
    }

    /// True when the view holds no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Element type of the payload.
    pub fn dtype(&self) -> DType {
        match self {
            BufView::F32(_) => DType::F32,
            BufView::S32(_) => DType::S32,
        }
    }
}

/// Compiled-executable cache over a PJRT CPU client.
pub struct Executor {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Executor {
    /// Create a CPU-PJRT executor over the manifest in `dir`.
    pub fn new(dir: &std::path::Path) -> Result<Executor> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Executor { client, manifest, compiled: HashMap::new() })
    }

    /// Locate artifacts automatically (see [`super::find_artifact_dir`]).
    pub fn from_env() -> Result<Executor> {
        let dir = super::find_artifact_dir()
            .ok_or_else(|| anyhow!("artifacts not found; run `make artifacts`"))?;
        Self::new(&dir)
    }

    /// The manifest this executor serves.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (and cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let spec =
            self.manifest.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?.clone();
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e:?}", spec.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", spec.file))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    fn literal(spec: &super::artifacts::TensorSpec, buf: BufView<'_>) -> Result<xla::Literal> {
        if buf.dtype() != spec.dtype {
            bail!("dtype mismatch: artifact wants {:?}", spec.dtype);
        }
        if buf.len() != spec.elements() && !(spec.shape.is_empty() && buf.len() == 1) {
            bail!("size mismatch: got {} elements, want {:?}", buf.len(), spec.shape);
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match buf {
            BufView::F32(v) => xla::Literal::vec1(v),
            BufView::S32(v) => xla::Literal::vec1(v),
        };
        if spec.shape.is_empty() {
            // Scalar: reshape to rank 0.
            lit.reshape(&[]).map_err(|e| anyhow!("reshape scalar: {e:?}"))
        } else {
            lit.reshape(&dims).map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
        }
    }

    /// Execute `name` with the given inputs; returns one [`Buf`] per output.
    pub fn run(&mut self, name: &str, inputs: &[Buf]) -> Result<Vec<Buf>> {
        self.load(name)?;
        let spec = self.manifest.get(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: got {} inputs, want {}", inputs.len(), spec.inputs.len());
        }
        let literals: Vec<xla::Literal> = spec
            .inputs
            .iter()
            .zip(inputs.iter())
            .enumerate()
            .map(|(i, (s, b))| {
                Self::literal(s, b.view()).with_context(|| format!("{name} input {i}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let exe = self.compiled.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        // return_tuple=True => decompose.
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        if parts.len() != spec.outputs.len() {
            bail!("{name}: got {} outputs, want {}", parts.len(), spec.outputs.len());
        }
        parts
            .into_iter()
            .zip(spec.outputs.iter())
            .map(|(lit, os)| {
                let buf = match os.dtype {
                    DType::F32 => Buf::F32(
                        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
                    ),
                    DType::S32 => Buf::S32(
                        lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec s32: {e:?}"))?,
                    ),
                };
                Ok(buf)
            })
            .collect()
    }

    /// Artifact spec lookup passthrough.
    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.manifest.get(name)
    }

    /// Pre-build the literal for input `idx` of `name` (reuse across many
    /// executions — §Perf: re-uploading an unchanged operand per call costs
    /// a full copy of its buffer).
    pub fn prep_literal(&self, name: &str, idx: usize, buf: &Buf) -> Result<xla::Literal> {
        self.prep_literal_view(name, idx, buf.view())
    }

    /// [`Self::prep_literal`] from a borrowed slice view — no owned [`Buf`]
    /// wrapper (and therefore no payload copy) required. This is how the
    /// training loop hoists its constant inputs (adjacency, features,
    /// labels) out of the per-step path.
    pub fn prep_literal_view(
        &self,
        name: &str,
        idx: usize,
        buf: BufView<'_>,
    ) -> Result<xla::Literal> {
        let spec = self.manifest.get(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let ispec =
            spec.inputs.get(idx).ok_or_else(|| anyhow!("{name}: no input {idx}"))?;
        Self::literal(ispec, buf)
    }

    /// Execute with pre-built literals (shapes validated at prep time).
    pub fn run_literals(&mut self, name: &str, inputs: &[&xla::Literal]) -> Result<Vec<Buf>> {
        self.load(name)?;
        let spec = self.manifest.get(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            bail!("{name}: got {} inputs, want {}", inputs.len(), spec.inputs.len());
        }
        let exe = self.compiled.get(name).unwrap();
        let result = exe
            .execute::<&xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))?;
        parts
            .into_iter()
            .zip(spec.outputs.iter())
            .map(|(lit, os)| {
                Ok(match os.dtype {
                    DType::F32 => Buf::F32(
                        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?,
                    ),
                    DType::S32 => Buf::S32(
                        lit.to_vec::<i32>().map_err(|e| anyhow!("to_vec s32: {e:?}"))?,
                    ),
                })
            })
            .collect()
    }
}
