//! Seeded, schedule-deterministic fault injection for the tiered store.
//!
//! A [`FaultPlan`] sits *in front of* [`SegmentStore`](crate::runtime::segstore::SegmentStore)
//! / [`PanelStore`](crate::runtime::segstore::PanelStore) reads (the
//! [`runtime::heal`](crate::runtime::heal) wrapper consults it before
//! touching the store, so even host-cache hits count as attempts) and
//! injects faults without ever touching the filesystem mid-run: a
//! transient I/O error on the first N reads of a chosen segment, a
//! slow-read latency charge, a corrupt-on-read checksum failure, or a
//! fail-once-then-heal blip. Every downstream recovery path — retry,
//! quarantine, rebuild — is exercised against the injector first and the
//! real filesystem second.
//!
//! **Determinism.** Fault state is keyed per `(tier, index)`, not by
//! global arrival order: the prefetch producer reads each index in a
//! deterministic per-index sequence regardless of depth or thread count,
//! so the k-th read attempt of segment `i` is the same attempt in every
//! schedule. A healed run is therefore byte-identical to the fault-free
//! oracle at every depth × thread × recycle point, with only
//! [`HealStats`](crate::runtime::heal::HealStats) differing
//! (`rust/tests/differential.rs`).

use crate::util::rng::Pcg;
use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: the fault counters are plain integers, so a
/// panicking reader thread must not cascade into `PoisonError` panics.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Which store a fault targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// A RoBW adjacency segment read
    /// ([`SegmentStore::read_reusing`](crate::runtime::segstore::SegmentStore::read_reusing)).
    Segment,
    /// A spilled feature/gradient panel read
    /// ([`PanelStore::read_reusing`](crate::runtime::segstore::PanelStore::read_reusing)).
    Panel,
}

/// What kind of fault a [`FaultSpec`] injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The first `times` read attempts fail with a transient
    /// [`SegioError::Io`](crate::sparse::segio::SegioError::Io); attempt
    /// `times + 1` succeeds. Retryable.
    TransientIo {
        /// Read attempts that fail before the fault clears.
        times: usize,
    },
    /// The first `times` reads succeed but charge `charge_bytes` of
    /// virtual latency into the heal ledger — a degraded-media read that
    /// completes late rather than failing.
    SlowRead {
        /// Reads that arrive slow before the fault clears.
        times: usize,
        /// Virtual bytes charged per slow read (priced by the same cost
        /// model as real staging I/O).
        charge_bytes: u64,
    },
    /// Every read fails with a checksum mismatch until the target is
    /// quarantined and rebuilt ([`FaultPlan::resolve`] clears it) — the
    /// persistent-corruption fault.
    CorruptOnRead,
    /// Exactly the first read fails transiently, then the fault heals
    /// itself — shorthand for `TransientIo { times: 1 }`.
    FailOnceThenHeal,
}

/// One injected fault: a kind aimed at one `(tier, index)` target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    /// The store the fault targets.
    pub tier: Tier,
    /// The segment or panel index within that store.
    pub index: usize,
    /// What happens when the target is read.
    pub kind: FaultKind,
}

/// What the injector did to one read attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Injected {
    /// The attempt fails with a transient I/O error.
    Io,
    /// The attempt fails with a (synthesized) payload-checksum mismatch —
    /// persistent until the target is rebuilt.
    Corrupt,
    /// The attempt succeeds but charges virtual latency.
    Slow {
        /// Virtual bytes to charge for the slow read.
        charge_bytes: u64,
    },
}

/// Per-spec mutable state: read attempts seen, and whether a rebuild
/// resolved the fault.
#[derive(Debug, Default)]
struct FaultState {
    attempts: usize,
    healed: bool,
}

/// Interior-counter state of a plan: per-spec attempt counts plus the
/// total faults injected so far.
#[derive(Debug, Default)]
struct PlanState {
    per_spec: HashMap<usize, FaultState>,
    injected: usize,
}

/// A deterministic fault schedule. Build one with an explicit spec list
/// ([`FaultPlan::new`]) or from a seed ([`FaultPlan::seeded`]), share it
/// via `Arc` through
/// [`StagingConfig::with_chaos`](crate::gcn::oocgcn::StagingConfig::with_chaos),
/// and the heal wrapper consults it on every store read. Counters are
/// interior-mutable (the prefetch producer holds `&FaultPlan`), so a plan
/// is **consumed** by a run — build a fresh plan per run when comparing
/// runs.
#[derive(Debug)]
pub struct FaultPlan {
    specs: Vec<FaultSpec>,
    state: Mutex<PlanState>,
}

impl FaultPlan {
    /// A plan injecting exactly `specs`, in spec order per target.
    pub fn new(specs: Vec<FaultSpec>) -> FaultPlan {
        FaultPlan { specs, state: Mutex::new(PlanState::default()) }
    }

    /// A seeded plan of `faults` retryable faults aimed at distinct
    /// segment indices in `[0, n_segments)`, cycling through transient,
    /// slow-read, and fail-once kinds. Deterministic in `seed`; every
    /// fault it plants is healable with `retry_max >= 2`.
    pub fn seeded(seed: u64, n_segments: usize, faults: usize) -> FaultPlan {
        let mut rng = Pcg::seed(seed);
        let mut indices: Vec<usize> = (0..n_segments).collect();
        rng.shuffle(&mut indices);
        let specs = indices
            .into_iter()
            .take(faults)
            .enumerate()
            .map(|(k, index)| FaultSpec {
                tier: Tier::Segment,
                index,
                kind: match k % 3 {
                    0 => FaultKind::TransientIo { times: 2 },
                    1 => FaultKind::SlowRead { times: 1, charge_bytes: 4096 },
                    _ => FaultKind::FailOnceThenHeal,
                },
            })
            .collect();
        FaultPlan::new(specs)
    }

    /// The plan's fault specs.
    pub fn specs(&self) -> &[FaultSpec] {
        &self.specs
    }

    /// Total faults injected so far (transient failures, corruptions, and
    /// slow reads all count).
    pub fn injected(&self) -> usize {
        lock(&self.state).injected
    }

    /// Consult the plan for one read attempt of `(tier, index)`. Called
    /// *before* the real store read — cache hits count as attempts too.
    /// Returns what to inject, or `None` for a clean read. Increments the
    /// per-target attempt counter either way.
    pub fn intercept(&self, tier: Tier, index: usize) -> Option<Injected> {
        let mut st = lock(&self.state);
        for (k, spec) in self.specs.iter().enumerate() {
            if spec.tier != tier || spec.index != index {
                continue;
            }
            let e = st.per_spec.entry(k).or_default();
            if e.healed {
                continue;
            }
            e.attempts += 1;
            let hit = match spec.kind {
                FaultKind::TransientIo { times } if e.attempts <= times => Some(Injected::Io),
                FaultKind::FailOnceThenHeal if e.attempts <= 1 => Some(Injected::Io),
                FaultKind::SlowRead { times, charge_bytes } if e.attempts <= times => {
                    Some(Injected::Slow { charge_bytes })
                }
                FaultKind::CorruptOnRead => Some(Injected::Corrupt),
                _ => None,
            };
            if let Some(inj) = hit {
                st.injected += 1;
                return Some(inj);
            }
        }
        None
    }

    /// Mark every fault aimed at `(tier, index)` as resolved — called
    /// after a quarantine-and-rebuild replaced the target file, so a
    /// [`FaultKind::CorruptOnRead`] stops firing (the corrupt medium is
    /// gone).
    pub fn resolve(&self, tier: Tier, index: usize) {
        let mut st = lock(&self.state);
        for (k, spec) in self.specs.iter().enumerate() {
            if spec.tier == tier && spec.index == index {
                st.per_spec.entry(k).or_default().healed = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_fault_fires_exactly_n_times_then_clears() {
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: 3,
            kind: FaultKind::TransientIo { times: 2 },
        }]);
        assert_eq!(plan.intercept(Tier::Segment, 3), Some(Injected::Io));
        assert_eq!(plan.intercept(Tier::Segment, 3), Some(Injected::Io));
        assert_eq!(plan.intercept(Tier::Segment, 3), None);
        assert_eq!(plan.intercept(Tier::Segment, 3), None);
        // Other targets and tiers are untouched.
        assert_eq!(plan.intercept(Tier::Segment, 2), None);
        assert_eq!(plan.intercept(Tier::Panel, 3), None);
        assert_eq!(plan.injected(), 2);
    }

    #[test]
    fn fail_once_is_transient_once() {
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Panel,
            index: 0,
            kind: FaultKind::FailOnceThenHeal,
        }]);
        assert_eq!(plan.intercept(Tier::Panel, 0), Some(Injected::Io));
        assert_eq!(plan.intercept(Tier::Panel, 0), None);
        assert_eq!(plan.injected(), 1);
    }

    #[test]
    fn slow_read_charges_then_clears() {
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: 1,
            kind: FaultKind::SlowRead { times: 1, charge_bytes: 512 },
        }]);
        assert_eq!(
            plan.intercept(Tier::Segment, 1),
            Some(Injected::Slow { charge_bytes: 512 })
        );
        assert_eq!(plan.intercept(Tier::Segment, 1), None);
    }

    #[test]
    fn corruption_persists_until_resolved() {
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: 5,
            kind: FaultKind::CorruptOnRead,
        }]);
        for _ in 0..4 {
            assert_eq!(plan.intercept(Tier::Segment, 5), Some(Injected::Corrupt));
        }
        plan.resolve(Tier::Segment, 5);
        assert_eq!(plan.intercept(Tier::Segment, 5), None, "rebuild clears the fault");
    }

    #[test]
    fn seeded_plans_are_deterministic_and_distinct() {
        let a = FaultPlan::seeded(9, 16, 4);
        let b = FaultPlan::seeded(9, 16, 4);
        assert_eq!(a.specs(), b.specs(), "same seed, same plan");
        assert_eq!(a.specs().len(), 4);
        let mut idx: Vec<usize> = a.specs().iter().map(|s| s.index).collect();
        idx.sort_unstable();
        idx.dedup();
        assert_eq!(idx.len(), 4, "targets are distinct segments");
        assert!(idx.iter().all(|&i| i < 16));
        let c = FaultPlan::seeded(10, 16, 4);
        assert_ne!(a.specs(), c.specs(), "different seed, different plan");
    }

    #[test]
    fn plan_capped_by_segment_count() {
        let plan = FaultPlan::seeded(3, 2, 8);
        assert_eq!(plan.specs().len(), 2, "cannot target more segments than exist");
    }
}
