//! Self-healing reads over the tiered store: bounded retry with
//! deterministic virtual-time backoff for transient I/O faults, and
//! quarantine-plus-rebuild for persistent corruption.
//!
//! The staging producers ([`gcn::pipeline`](crate::gcn::pipeline),
//! [`gcn::serve`](crate::gcn::serve),
//! [`gcn::train_stream`](crate::gcn::train_stream)) route every
//! [`SegmentStore`]/[`PanelStore`] read through [`read_segment_healing`] /
//! [`read_panel_healing`] instead of calling the store directly. With the
//! default [`HealPolicy`] the wrapper is a pass-through — every fault
//! stays a fail-fast typed error, exactly the pre-heal behaviour pinned by
//! `diff_injected_io_faults_fail_cleanly_at_every_depth`. With healing
//! enabled:
//!
//! * **Transient faults** ([`SegioError::Io`], including those injected by
//!   a [`FaultPlan`]) are retried up to [`HealPolicy::retry_max`] times.
//!   Backoff is *virtual*: attempt `k` charges
//!   `backoff_ios × file_bytes × 2^(k-1)` bytes into
//!   [`HealStats::backoff_bytes`] — priced by the same cost model as real
//!   staging I/O via [`HealStats::modeled_backoff_secs`] — and never
//!   sleeps, so healed runs stay schedule-deterministic.
//! * **Persistent corruption** (bad magic, truncation, checksum or
//!   validation failures) quarantines the segment file (renamed to
//!   `<name>.quarantined`) and rebuilds it from the source matrix + RoBW
//!   plan ([`SegmentStore::quarantine_and_rebuild`]), then re-reads. One
//!   rebuild per read call; a rebuild that still cannot serve good bytes
//!   surfaces the original typed error.
//!
//! The house determinism rule extends to recovery: a healed run is
//! byte-identical to the fault-free oracle — same output, same measured
//! I/O meters, same ledger balance — with only the [`HealStats`] counters
//! differing (`rust/tests/differential.rs`).

use crate::memsim::{CostModel, Op};
use crate::partition::robw::RobwSegment;
use crate::runtime::chaos::{FaultPlan, Injected, Tier};
use crate::runtime::recycle::BufferPool;
use crate::runtime::segstore::{PanelRead, PanelStore, ReadOrigin, SegmentRead, SegmentStore};
use crate::sparse::segio::SegioError;
use crate::sparse::Csr;

/// Recovery policy for tiered-store reads. The default is all-off: every
/// fault is fail-fast, byte-for-byte the pre-heal behaviour.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealPolicy {
    /// Transient-fault retries per read (0 = fail fast).
    pub retry_max: usize,
    /// Backoff charge factor: retry `k` of a segment with `file_bytes`
    /// encoded bytes charges `retry_backoff_ios × file_bytes × 2^(k-1)`
    /// virtual bytes — "how many I/Os' worth of waiting" each backoff
    /// step costs, doubling per attempt.
    pub backoff_ios: u64,
    /// Quarantine-and-rebuild persistently corrupt segment files from the
    /// source matrix + RoBW plan.
    pub rebuild: bool,
}

impl HealPolicy {
    /// Whether any recovery behaviour is enabled.
    pub fn enabled(&self) -> bool {
        self.retry_max > 0 || self.rebuild
    }
}

/// Recovery counters of one pass. Additive — merge per-read stats into
/// per-layer stats into per-run reports with [`HealStats::merge`]. This is
/// the *only* report field allowed to differ between a healed run and its
/// fault-free oracle.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealStats {
    /// Faults the chaos plan injected into this pass (all kinds).
    pub injected: u64,
    /// Transient-fault retries performed.
    pub retries: u64,
    /// Reads that completed slow (chaos [`Injected::Slow`]).
    pub slow_reads: u64,
    /// Segment files quarantined after persistent corruption.
    pub quarantined: u64,
    /// Segment files rebuilt from the source matrix + plan.
    pub rebuilt: u64,
    /// Virtual backoff + slow-read bytes charged (never slept; price with
    /// [`Self::modeled_backoff_secs`]).
    pub backoff_bytes: u64,
}

impl HealStats {
    /// Fold another stats record into this one (all fields additive).
    pub fn merge(&mut self, other: &HealStats) {
        self.injected += other.injected;
        self.retries += other.retries;
        self.slow_reads += other.slow_reads;
        self.quarantined += other.quarantined;
        self.rebuilt += other.rebuilt;
        self.backoff_bytes += other.backoff_bytes;
    }

    /// Whether any recovery action was taken.
    pub fn any(&self) -> bool {
        *self != HealStats::default()
    }

    /// Seconds the cost model charges for the virtual backoff bytes —
    /// priced like NVMe reads, the same channel the
    /// [`StagingMeter`](crate::memsim::StagingMeter) prices measured disk
    /// I/O on (0 when nothing backed off).
    pub fn modeled_backoff_secs(&self, cm: &CostModel) -> f64 {
        if self.backoff_bytes == 0 {
            0.0
        } else {
            cm.transfer_secs(Op::NvmeToHost, self.backoff_bytes)
        }
    }
}

/// Where a corrupt segment's bytes can be rebuilt from: the source matrix
/// and the segment's RoBW plan entry.
#[derive(Clone, Copy)]
pub struct RebuildSource<'a> {
    /// The full source matrix the store was spilled from.
    pub a: &'a Csr,
    /// Segment `i`'s plan entry.
    pub seg: &'a RobwSegment,
}

/// Transient faults are retryable; everything else (corruption,
/// truncation, format violations) is persistent.
fn is_transient(e: &SegioError) -> bool {
    matches!(e, SegioError::Io(_))
}

/// Read segment `i` through the recovery policy: chaos intercept first
/// (so injected faults hit even warm cache reads — and mmap'd reads:
/// interception happens before the store is consulted, so the zero-copy
/// path is chaos-visible like any other), then the store; transient
/// errors retry with doubling virtual backoff, persistent errors
/// quarantine-and-rebuild once when the policy and a [`RebuildSource`]
/// allow. `mmap` routes the store read through
/// [`SegmentStore::read_mapped`] (zero-copy, packed segments fall back to
/// a copy decode) instead of [`SegmentStore::read_reusing`]. Recovery
/// actions accumulate into `stats` (also on the error path). With the
/// default policy, no chaos, and `mmap` off this is exactly
/// `store.read_reusing(i, reuse, pool)`.
#[allow(clippy::too_many_arguments)]
pub fn read_segment_healing(
    store: &SegmentStore,
    i: usize,
    mut reuse: Option<Csr>,
    pool: Option<&BufferPool>,
    mmap: bool,
    policy: &HealPolicy,
    chaos: Option<&FaultPlan>,
    source: Option<RebuildSource<'_>>,
    stats: &mut HealStats,
) -> Result<(SegmentRead, ReadOrigin), SegioError> {
    let read = |reuse: Option<Csr>| {
        if mmap {
            store.read_mapped(i, reuse, pool)
        } else {
            store.read_reusing(i, reuse, pool)
        }
    };
    let mut attempt = 0usize;
    let mut rebuilt_this_call = false;
    loop {
        // A failed attempt consumes the reuse scratch exactly like a real
        // failed read (read_reusing returns it to the pool internally on
        // error), so retries proceed with reuse = None, pool still offered.
        let attempt_result = match chaos.and_then(|c| c.intercept(Tier::Segment, i)) {
            Some(Injected::Io) => {
                stats.injected += 1;
                if let (Some(m), Some(rp)) = (reuse.take(), pool) {
                    rp.put_csr(m);
                }
                Err(SegioError::Io(format!("injected transient fault on segment {i}")))
            }
            Some(Injected::Corrupt) => {
                stats.injected += 1;
                if let (Some(m), Some(rp)) = (reuse.take(), pool) {
                    rp.put_csr(m);
                }
                Err(SegioError::PayloadChecksum { stored: u64::MAX, computed: 0 })
            }
            Some(Injected::Slow { charge_bytes }) => {
                stats.injected += 1;
                stats.slow_reads += 1;
                stats.backoff_bytes += charge_bytes;
                read(reuse.take())
            }
            None => read(reuse.take()),
        };
        match attempt_result {
            Ok(ok) => return Ok(ok),
            Err(e) if is_transient(&e) && attempt < policy.retry_max => {
                attempt += 1;
                stats.retries += 1;
                stats.backoff_bytes += (policy
                    .backoff_ios
                    .saturating_mul(store.meta(i).file_bytes))
                    << (attempt - 1).min(63);
            }
            Err(e)
                if !is_transient(&e)
                    && policy.rebuild
                    && !rebuilt_this_call
                    && source.is_some() =>
            {
                let src = source.expect("checked above");
                store.quarantine_and_rebuild(i, src.a, src.seg)?;
                if let Some(c) = chaos {
                    // The corrupt medium is gone; a CorruptOnRead fault
                    // aimed at this segment stops firing.
                    c.resolve(Tier::Segment, i);
                }
                rebuilt_this_call = true;
                stats.quarantined += 1;
                stats.rebuilt += 1;
            }
            Err(e) => return Err(e),
        }
    }
}

/// Read panel `idx` through the recovery policy: transient faults retry
/// with doubling virtual backoff (charged on the panel's encoded size);
/// persistent corruption has no rebuild source — a torn panel is data
/// produced mid-run, not derivable from the inputs — so it stays a typed
/// error. `mmap` routes the store read through
/// [`PanelStore::read_mapped`] (chunk records served from the page
/// cache); chaos interception still happens first, so injected faults hit
/// mapped reads too. With the default policy, no chaos, and `mmap` off
/// this is exactly `panels.read_reusing(idx, pool)`.
pub fn read_panel_healing(
    panels: &PanelStore,
    idx: usize,
    pool: Option<&BufferPool>,
    mmap: bool,
    policy: &HealPolicy,
    chaos: Option<&FaultPlan>,
    stats: &mut HealStats,
) -> Result<(PanelRead, ReadOrigin), SegioError> {
    let read = || {
        if mmap {
            panels.read_mapped(idx, pool)
        } else {
            panels.read_reusing(idx, pool)
        }
    };
    let mut attempt = 0usize;
    loop {
        let attempt_result = match chaos.and_then(|c| c.intercept(Tier::Panel, idx)) {
            Some(Injected::Io) => {
                stats.injected += 1;
                Err(SegioError::Io(format!("injected transient fault on panel {idx}")))
            }
            Some(Injected::Corrupt) => {
                stats.injected += 1;
                Err(SegioError::PayloadChecksum { stored: u64::MAX, computed: 0 })
            }
            Some(Injected::Slow { charge_bytes }) => {
                stats.injected += 1;
                stats.slow_reads += 1;
                stats.backoff_bytes += charge_bytes;
                read()
            }
            None => read(),
        };
        match attempt_result {
            Ok(ok) => return Ok(ok),
            Err(e) if is_transient(&e) && attempt < policy.retry_max => {
                attempt += 1;
                stats.retries += 1;
                let file_bytes = panels.meta(idx).map(|m| m.file_bytes).unwrap_or(0);
                stats.backoff_bytes +=
                    (policy.backoff_ios.saturating_mul(file_bytes)) << (attempt - 1).min(63);
            }
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::robw::robw_partition;
    use crate::runtime::chaos::{FaultKind, FaultSpec};
    use crate::sparse::Coo;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg;
    use std::sync::Arc;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    fn store_fixture(seed: u64, name: &str) -> (Csr, Vec<RobwSegment>, TempDir, SegmentStore) {
        let mut rng = Pcg::seed(seed);
        let a = random_csr(&mut rng, 100, 30, 0.15);
        let segs = robw_partition(&a, 600);
        assert!(segs.len() > 2);
        let dir = TempDir::new(name);
        let store = SegmentStore::spill(&a, &segs, dir.path(), 0).unwrap();
        (a, segs, dir, store)
    }

    #[test]
    fn default_policy_is_passthrough() {
        let (_a, _segs, _dir, store) = store_fixture(220, "heal-pass");
        let mut stats = HealStats::default();
        let policy = HealPolicy::default();
        assert!(!policy.enabled());
        let (want, _) = store.read(0).unwrap();
        let (got, origin) =
            read_segment_healing(&store, 0, None, None, false, &policy, None, None, &mut stats)
                .unwrap();
        assert_eq!(got.csr(), want.csr());
        assert!(origin.disk_bytes > 0);
        assert!(!stats.any(), "no recovery happened: {stats:?}");
    }

    #[test]
    fn transient_fault_without_retry_fails_fast() {
        let (_a, _segs, _dir, store) = store_fixture(221, "heal-failfast");
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: 1,
            kind: FaultKind::TransientIo { times: 1 },
        }]);
        let mut stats = HealStats::default();
        let err = read_segment_healing(
            &store,
            1,
            None,
            None,
            false,
            &HealPolicy::default(),
            Some(&plan),
            None,
            &mut stats,
        )
        .unwrap_err();
        assert!(matches!(err, SegioError::Io(_)), "{err}");
        assert_eq!(stats.injected, 1);
        assert_eq!(stats.retries, 0);
    }

    #[test]
    fn retry_heals_transient_faults_and_charges_backoff() {
        let (_a, _segs, _dir, store) = store_fixture(222, "heal-retry");
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: 2,
            kind: FaultKind::TransientIo { times: 2 },
        }]);
        let policy = HealPolicy { retry_max: 3, backoff_ios: 2, rebuild: false };
        let mut stats = HealStats::default();
        let (want, _) = store.read(2).unwrap();
        let (got, _) = read_segment_healing(
            &store,
            2,
            None,
            None,
            false,
            &policy,
            Some(&plan),
            None,
            &mut stats,
        )
        .unwrap();
        assert_eq!(got.csr(), want.csr(), "healed read serves the same bytes");
        assert_eq!(stats.retries, 2);
        assert_eq!(stats.injected, 2);
        let fb = store.meta(2).file_bytes;
        // Retry 1 charges 2·fb·2^0, retry 2 charges 2·fb·2^1.
        assert_eq!(stats.backoff_bytes, 2 * fb + 4 * fb);
        let cm = CostModel::default();
        assert!(stats.modeled_backoff_secs(&cm) > 0.0);
        assert_eq!(HealStats::default().modeled_backoff_secs(&cm), 0.0);
    }

    #[test]
    fn retries_exhausted_surfaces_the_transient_error() {
        let (_a, _segs, _dir, store) = store_fixture(223, "heal-exhaust");
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: 0,
            kind: FaultKind::TransientIo { times: 5 },
        }]);
        let policy = HealPolicy { retry_max: 2, backoff_ios: 1, rebuild: false };
        let mut stats = HealStats::default();
        let err = read_segment_healing(
            &store,
            0,
            None,
            None,
            false,
            &policy,
            Some(&plan),
            None,
            &mut stats,
        )
        .unwrap_err();
        assert!(matches!(err, SegioError::Io(_)), "{err}");
        assert_eq!(stats.retries, 2, "retry budget fully spent");
        assert_eq!(stats.injected, 3, "initial attempt + 2 retries all faulted");
    }

    #[test]
    fn corruption_quarantines_and_rebuilds_real_files() {
        let (a, segs, _dir, store) = store_fixture(224, "heal-rebuild");
        let victim = 1usize;
        // Really corrupt the file on disk (mid-payload bit flip).
        let path = store.meta(victim).path.clone();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let policy = HealPolicy { retry_max: 0, backoff_ios: 0, rebuild: true };
        let src = RebuildSource { a: &a, seg: &segs[victim] };
        let mut stats = HealStats::default();
        let (got, origin) = read_segment_healing(
            &store,
            victim,
            None,
            None,
            false,
            &policy,
            None,
            Some(src),
            &mut stats,
        )
        .unwrap();
        let want = crate::partition::robw::materialize(&a, &segs[victim]);
        assert_eq!(got.csr(), &want, "rebuilt segment serves the true bytes");
        assert!(origin.disk_bytes > 0);
        assert_eq!(stats.quarantined, 1);
        assert_eq!(stats.rebuilt, 1);
        let q = path.with_extension("bin.quarantined");
        assert!(q.exists(), "corrupt file preserved at {}", q.display());
        // The rebuilt file now reads clean without any policy.
        let (clean, _) = store.read(victim).unwrap();
        assert_eq!(clean.csr(), &want);
    }

    #[test]
    fn injected_corruption_rebuilds_once_and_resolves_the_fault() {
        let (a, segs, _dir, store) = store_fixture(225, "heal-chaos-corrupt");
        let victim = 0usize;
        let plan = Arc::new(FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: victim,
            kind: FaultKind::CorruptOnRead,
        }]));
        let policy = HealPolicy { retry_max: 1, backoff_ios: 1, rebuild: true };
        let src = RebuildSource { a: &a, seg: &segs[victim] };
        let mut stats = HealStats::default();
        let (got, _) = read_segment_healing(
            &store,
            victim,
            None,
            None,
            false,
            &policy,
            Some(&plan),
            Some(src),
            &mut stats,
        )
        .unwrap();
        let want = crate::partition::robw::materialize(&a, &segs[victim]);
        assert_eq!(got.csr(), &want);
        assert_eq!(stats.quarantined, 1);
        assert_eq!(stats.rebuilt, 1);
        // Without rebuild permission the same fault is terminal.
        let plan2 = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Segment,
            index: victim,
            kind: FaultKind::CorruptOnRead,
        }]);
        let no_rebuild = HealPolicy { retry_max: 2, backoff_ios: 1, rebuild: false };
        let mut stats2 = HealStats::default();
        let err = read_segment_healing(
            &store,
            victim,
            None,
            None,
            false,
            &no_rebuild,
            Some(&plan2),
            None,
            &mut stats2,
        )
        .unwrap_err();
        assert!(matches!(err, SegioError::PayloadChecksum { .. }), "{err}");
        assert_eq!(stats2.retries, 0, "persistent faults are not retried");
    }

    #[test]
    fn panel_heal_retries_transients_but_not_corruption() {
        let dir = TempDir::new("heal-panel");
        let panels = PanelStore::new(dir.path(), 0).unwrap();
        let p = crate::sparse::spmm::Dense::from_vec(4, 3, (0..12).map(|i| i as f32).collect());
        panels.put(0, &p).unwrap();
        let plan = FaultPlan::new(vec![FaultSpec {
            tier: Tier::Panel,
            index: 0,
            kind: FaultKind::FailOnceThenHeal,
        }]);
        let policy = HealPolicy { retry_max: 1, backoff_ios: 3, rebuild: true };
        let mut stats = HealStats::default();
        let (got, _) =
            read_panel_healing(&panels, 0, None, false, &policy, Some(&plan), &mut stats)
                .unwrap();
        assert_eq!(got.dense(), &p);
        assert_eq!(stats.retries, 1);
        assert_eq!(stats.backoff_bytes, 3 * panels.meta(0).unwrap().file_bytes);
        // Corrupt the panel for real: no rebuild source exists for panels,
        // so even a rebuild-enabled policy surfaces the typed error.
        let path = panels.meta(0).unwrap().path;
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let mut stats2 = HealStats::default();
        let err =
            read_panel_healing(&panels, 0, None, false, &policy, None, &mut stats2).unwrap_err();
        assert!(matches!(err, SegioError::PayloadChecksum { .. }), "{err}");
    }

    #[test]
    fn mmap_reads_heal_chaos_and_real_corruption_in_both_encodings() {
        use crate::runtime::segstore::SegmentRead;
        use crate::sparse::segio::SegEncoding;
        for enc in [SegEncoding::Raw, SegEncoding::Packed] {
            let mut rng = Pcg::seed(226);
            let a = random_csr(&mut rng, 100, 30, 0.15);
            let segs = robw_partition(&a, 600);
            let dir = TempDir::new("heal-mmap");
            let store =
                SegmentStore::spill_encoded(&a, &segs, dir.path(), 0, enc).unwrap();
            let victim = 1usize;
            let want = crate::partition::robw::materialize(&a, &segs[victim]);
            let policy = HealPolicy { retry_max: 1, backoff_ios: 1, rebuild: true };
            // Chaos interception is upstream of the store, so it fires on
            // the mapped path exactly as it does on the copying one.
            let plan = FaultPlan::new(vec![FaultSpec {
                tier: Tier::Segment,
                index: victim,
                kind: FaultKind::CorruptOnRead,
            }]);
            let src = RebuildSource { a: &a, seg: &segs[victim] };
            let mut stats = HealStats::default();
            let (got, _) = read_segment_healing(
                &store,
                victim,
                None,
                None,
                true,
                &policy,
                Some(&plan),
                Some(src),
                &mut stats,
            )
            .unwrap();
            assert_eq!(got.into_csr(), want, "chaos-healed mapped read under {enc}");
            assert_eq!((stats.quarantined, stats.rebuilt), (1, 1));
            // Real on-disk corruption surfaces through the mapped
            // validator and heals back in the original encoding.
            let path = store.meta(victim).path.clone();
            let mut bytes = std::fs::read(&path).unwrap();
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x40;
            std::fs::write(&path, &bytes).unwrap();
            let mut stats2 = HealStats::default();
            let (got2, _) = read_segment_healing(
                &store,
                victim,
                None,
                None,
                true,
                &policy,
                None,
                Some(src),
                &mut stats2,
            )
            .unwrap();
            assert_eq!(got2.into_csr(), want, "disk-healed mapped read under {enc}");
            assert_eq!((stats2.quarantined, stats2.rebuilt), (1, 1));
            let healed = std::fs::read(&path).unwrap();
            assert_eq!(
                u32::from_le_bytes(healed[12..16].try_into().unwrap()),
                store.meta(victim).kind,
                "rebuild must preserve the original encoding"
            );
            // Raw segments come back mapped; packed ones fall back to a
            // copy decode.
            let (served, _) = store.read_mapped(victim, None, None).unwrap();
            match enc {
                SegEncoding::Raw => assert!(matches!(served, SegmentRead::Mapped(_))),
                _ => assert!(matches!(served, SegmentRead::Owned(_))),
            }
        }
    }

    #[test]
    fn stats_merge_is_additive() {
        let mut a = HealStats {
            injected: 1,
            retries: 2,
            slow_reads: 3,
            quarantined: 4,
            rebuilt: 5,
            backoff_bytes: 6,
        };
        let b = HealStats {
            injected: 10,
            retries: 20,
            slow_reads: 30,
            quarantined: 40,
            rebuilt: 50,
            backoff_bytes: 60,
        };
        a.merge(&b);
        assert_eq!(
            a,
            HealStats {
                injected: 11,
                retries: 22,
                slow_reads: 33,
                quarantined: 44,
                rebuilt: 55,
                backoff_bytes: 66,
            }
        );
        assert!(a.any());
    }
}
