//! Std-only chunked thread pool: the concurrency substrate for the
//! parallel row-range kernels (`spgemm_gustavson_par`, `spmm_par`, the
//! parallel tile packer/executor) and for every later scaling feature
//! (batched multi-tenant workloads, async prefetch).
//!
//! Design (the offline crate cache has no rayon, so this is built on
//! `std::thread::scope` alone):
//!
//! * **Chunked self-scheduling.** A parallel region splits its work into
//!   tasks; workers pull task indices from a shared atomic cursor, so a
//!   worker that finishes early immediately steals the next pending chunk —
//!   the load-balancing effect of work stealing without per-deque
//!   machinery. Skewed inputs (RMAT hub rows) are handled by submitting
//!   more chunks than workers.
//! * **Scoped workers.** Threads live for one parallel region
//!   (`std::thread::scope`), which lets tasks borrow the operands directly
//!   — no `'static` bounds, no `unsafe` lifetime laundering. Spawn cost
//!   (~tens of µs) is amortized over kernel-scale regions; the hot kernels
//!   are multi-millisecond.
//! * **Determinism.** Results are keyed by task index and merged in task
//!   order, and in-place variants pre-split the output into fixed,
//!   contiguous row ranges each claimed by exactly one worker. Output
//!   never depends on execution order — the parallel
//!   kernels are byte-identical to their serial oracles at every thread
//!   count (enforced by `rust/tests/differential.rs`).

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Handle carrying the worker-count policy for parallel regions.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with `threads` workers; `0` means one worker per available
    /// hardware thread.
    pub fn new(threads: usize) -> Pool {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Pool { threads }
    }

    /// Single-worker pool: parallel entry points degrade to the serial path.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0..ntasks)` across the pool and return the results in task
    /// order (execution order is dynamic, output order is not).
    pub fn map_tasks<T, F>(&self, ntasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.map_tasks_init(ntasks, || (), |_, i| f(i))
    }

    /// [`Self::map_tasks`] with worker-local state: each worker builds one
    /// `init()` value and reuses it across every task it claims. This is
    /// how kernels with O(problem)-sized scratch (the Gustavson
    /// accumulator/stamp arrays) oversubmit chunks for balance without
    /// paying a scratch allocation per chunk.
    pub fn map_tasks_init<T, S, I, F>(&self, ntasks: usize, init: I, f: F) -> Vec<T>
    where
        T: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> T + Sync,
    {
        if ntasks == 0 {
            return Vec::new();
        }
        if self.threads <= 1 || ntasks == 1 {
            let mut state = init();
            return (0..ntasks).map(|i| f(&mut state, i)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> = (0..ntasks).map(|_| Mutex::new(None)).collect();
        let nworkers = self.threads.min(ntasks);
        std::thread::scope(|s| {
            for _ in 0..nworkers {
                s.spawn(|| {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= ntasks {
                            break;
                        }
                        let out = f(&mut state, i);
                        *slots[i].lock().unwrap() = Some(out);
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker completed every claimed task"))
            .collect()
    }

    /// Row-parallel in-place execution: treat `data` as rows of `width`
    /// elements, split it into `4 * threads` fixed contiguous chunks, and
    /// let workers claim chunks off the shared cursor (oversubscription
    /// absorbs per-row skew, e.g. RMAT hub rows). The row partition
    /// depends only on (nrows, threads) and each output row is written by
    /// exactly one claimant — determinism by construction. For kernels
    /// whose per-chunk cost is a full input scan (not proportional to the
    /// chunk), use [`Self::for_each_row_chunk_static`] instead: there,
    /// extra chunks multiply total work.
    pub fn for_each_row_chunk<F>(&self, data: &mut [f32], width: usize, f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        self.row_chunks_impl(data, width, self.threads.saturating_mul(4).max(1), f)
    }

    /// [`Self::for_each_row_chunk`] with exactly one chunk per worker —
    /// minimal chunk count for scan-all kernels (e.g. the deterministic
    /// transpose SpMM, where every chunk reads all of A).
    pub fn for_each_row_chunk_static<F>(&self, data: &mut [f32], width: usize, f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        self.row_chunks_impl(data, width, self.threads, f)
    }

    fn row_chunks_impl<F>(&self, data: &mut [f32], width: usize, nchunks: usize, f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        if width == 0 || data.is_empty() {
            f(0..0, data);
            return;
        }
        let nrows = data.len() / width;
        debug_assert_eq!(nrows * width, data.len(), "data must be whole rows");
        let ranges = chunk_ranges(nrows, nchunks);
        if self.threads <= 1 || ranges.len() <= 1 {
            f(0..nrows, data);
            return;
        }
        // Pre-split into disjoint chunks; workers claim them in index
        // order off the shared cursor. The Mutex<Option<..>> per chunk is
        // only the ownership hand-off (each is locked exactly once).
        let mut tasks: Vec<Mutex<Option<(Range<usize>, &mut [f32])>>> =
            Vec::with_capacity(ranges.len());
        let mut rest: &mut [f32] = data;
        for r in ranges {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((r.end - r.start) * width);
            rest = tail;
            tasks.push(Mutex::new(Some((r, head))));
        }
        let next = AtomicUsize::new(0);
        let nworkers = self.threads.min(tasks.len());
        std::thread::scope(|s| {
            for _ in 0..nworkers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= tasks.len() {
                        break;
                    }
                    let (range, chunk) =
                        tasks[i].lock().unwrap().take().expect("each chunk claimed once");
                    f(range, chunk);
                });
            }
        });
    }
}

/// Deterministic near-equal partition of `0..n` into at most `k` contiguous
/// ranges (earlier ranges get the remainder). Depends only on `(n, k)`.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.max(1).min(n);
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut lo = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(lo..lo + len);
        lo += len;
    }
    debug_assert_eq!(lo, n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_contiguously() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            for k in [1usize, 2, 3, 8, 2000] {
                let rs = chunk_ranges(n, k);
                if n == 0 {
                    assert!(rs.is_empty());
                    continue;
                }
                assert!(rs.len() <= k.max(1) && rs.len() <= n);
                assert_eq!(rs[0].start, 0);
                assert_eq!(rs.last().unwrap().end, n);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                let max = rs.iter().map(|r| r.len()).max().unwrap();
                let min = rs.iter().map(|r| r.len()).min().unwrap();
                assert!(max - min <= 1, "near-equal split: {min}..{max}");
            }
        }
    }

    #[test]
    fn map_tasks_preserves_task_order() {
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let out = pool.map_tasks(37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn map_tasks_edge_counts() {
        let pool = Pool::new(4);
        assert!(pool.map_tasks(0, |i| i).is_empty());
        assert_eq!(pool.map_tasks(1, |i| i + 10), vec![10]);
        // More workers than tasks.
        assert_eq!(Pool::new(16).map_tasks(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn for_each_row_chunk_matches_serial() {
        let width = 5;
        let nrows = 23;
        let fill = |pool: &Pool| {
            let mut data = vec![0f32; nrows * width];
            pool.for_each_row_chunk(&mut data, width, |range, chunk| {
                for (local, row) in range.clone().enumerate() {
                    for c in 0..width {
                        chunk[local * width + c] = (row * width + c) as f32;
                    }
                }
            });
            data
        };
        let want = fill(&Pool::serial());
        assert_eq!(want, (0..nrows * width).map(|i| i as f32).collect::<Vec<_>>());
        for threads in [2usize, 4, 8, 64] {
            assert_eq!(fill(&Pool::new(threads)), want, "threads={threads}");
        }
    }

    #[test]
    fn for_each_row_chunk_degenerate_inputs() {
        let pool = Pool::new(4);
        let mut empty: Vec<f32> = Vec::new();
        pool.for_each_row_chunk(&mut empty, 3, |range, chunk| {
            assert!(range.is_empty() && chunk.is_empty());
        });
        let mut one = vec![1f32, 2.0];
        pool.for_each_row_chunk(&mut one, 2, |range, chunk| {
            assert_eq!(range, 0..1);
            chunk[0] += 1.0;
        });
        assert_eq!(one, vec![2.0, 2.0]);
    }

    #[test]
    fn map_tasks_init_reuses_worker_state_correctly() {
        // Worker-local scratch must not leak between tasks in a way that
        // changes results: fill scratch with task-dependent garbage, and
        // require each task's output to depend only on its own index.
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let out = pool.map_tasks_init(
                50,
                || vec![0u64; 16],
                |scratch, i| {
                    for (j, s) in scratch.iter_mut().enumerate() {
                        *s = (i * 31 + j) as u64; // overwrite, never read stale
                    }
                    scratch.iter().sum::<u64>()
                },
            );
            let want: Vec<u64> =
                (0..50).map(|i| (0..16).map(|j| (i * 31 + j) as u64).sum()).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn static_row_chunks_match_oversubscribed() {
        let width = 3;
        let nrows = 17;
        let run = |oversub: bool, threads: usize| {
            let mut data = vec![0f32; nrows * width];
            let pool = Pool::new(threads);
            let fill = |range: Range<usize>, chunk: &mut [f32]| {
                for (local, row) in range.clone().enumerate() {
                    for c in 0..width {
                        chunk[local * width + c] = (row * 10 + c) as f32;
                    }
                }
            };
            if oversub {
                pool.for_each_row_chunk(&mut data, width, fill);
            } else {
                pool.for_each_row_chunk_static(&mut data, width, fill);
            }
            data
        };
        let want = run(true, 1);
        for threads in [1usize, 2, 4, 8] {
            assert_eq!(run(true, threads), want);
            assert_eq!(run(false, threads), want);
        }
    }

    #[test]
    fn auto_thread_count_is_positive() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::serial().threads(), 1);
    }

    #[test]
    fn map_tasks_is_deterministic_across_runs() {
        let pool = Pool::new(8);
        let a = pool.map_tasks(100, |i| (i as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let b = pool.map_tasks(100, |i| (i as u64).wrapping_mul(0x9e3779b97f4a7c15));
        assert_eq!(a, b);
    }
}
