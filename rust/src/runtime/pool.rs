//! Std-only chunked thread pool: the concurrency substrate for the
//! parallel row-range kernels (`spgemm_gustavson_par`, `spmm_par`, the
//! parallel tile packer/executor) and for every later scaling feature
//! (batched multi-tenant workloads, async prefetch).
//!
//! Design (the offline crate cache has no rayon, so this is built on
//! `std::thread::scope` alone):
//!
//! * **Chunked self-scheduling.** A parallel region splits its work into
//!   tasks; workers pull task indices from a shared atomic cursor, so a
//!   worker that finishes early immediately steals the next pending chunk —
//!   the load-balancing effect of work stealing without per-deque
//!   machinery. Skewed inputs (RMAT hub rows) are handled by submitting
//!   more chunks than workers.
//! * **Scoped workers.** Threads live for one parallel region
//!   (`std::thread::scope`), which lets tasks borrow the operands directly
//!   — no `'static` bounds, no `unsafe` lifetime laundering. Spawn cost
//!   (~tens of µs) is amortized over kernel-scale regions; the hot kernels
//!   are multi-millisecond.
//! * **Determinism.** Results are keyed by task index and merged in task
//!   order, and in-place variants pre-split the output into fixed,
//!   contiguous row ranges each claimed by exactly one worker. Output
//!   never depends on execution order — the parallel
//!   kernels are byte-identical to their serial oracles at every thread
//!   count (enforced by `rust/tests/differential.rs`).

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Poison-tolerant lock: every mutex in this module guards state that is
/// valid at each instruction boundary (slot options, hand-off queues), so
/// when a worker panics mid-region the *original* panic payload must
/// surface at the scope join — not a secondary `PoisonError` panic from
/// the next thread that touches the state.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Handle carrying the worker-count policy for parallel regions.
#[derive(Debug, Clone)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with `threads` workers; `0` means one worker per available
    /// hardware thread.
    pub fn new(threads: usize) -> Pool {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            threads
        };
        Pool { threads }
    }

    /// Single-worker pool: parallel entry points degrade to the serial path.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    /// Worker count this pool runs parallel regions with.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Open a structured-concurrency region: a thin wrapper over
    /// [`std::thread::scope`] that pipeline code (`runtime::prefetch`)
    /// uses to run a staging task alongside the caller. Tasks spawned on
    /// the scope may borrow from the enclosing stack frame and are all
    /// joined before `scoped` returns, so no work outlives its operands.
    ///
    /// # Examples
    ///
    /// ```
    /// use aires::runtime::pool::Pool;
    ///
    /// let pool = Pool::new(2);
    /// let data = vec![1u64, 2, 3];
    /// let total = pool.scoped(|s| {
    ///     // A background task borrowing `data` — no 'static bound needed.
    ///     let sum = s.spawn(|| data.iter().sum::<u64>());
    ///     let max = data.iter().copied().max().unwrap();
    ///     sum.join().unwrap() + max
    /// });
    /// assert_eq!(total, 9);
    /// ```
    pub fn scoped<'env, F, R>(&self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope std::thread::Scope<'scope, 'env>) -> R,
    {
        std::thread::scope(f)
    }

    /// Run `f(0..ntasks)` across the pool and return the results in task
    /// order (execution order is dynamic, output order is not).
    pub fn map_tasks<T, F>(&self, ntasks: usize, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(usize) -> T + Sync,
    {
        self.map_tasks_init(ntasks, || (), |_, i| f(i))
    }

    /// [`Self::map_tasks`] with worker-local state: each worker builds one
    /// `init()` value and reuses it across every task it claims. This is
    /// how kernels with O(problem)-sized scratch (the Gustavson
    /// accumulator/stamp arrays) oversubmit chunks for balance without
    /// paying a scratch allocation per chunk.
    pub fn map_tasks_init<T, S, I, F>(&self, ntasks: usize, init: I, f: F) -> Vec<T>
    where
        T: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize) -> T + Sync,
    {
        if ntasks == 0 {
            return Vec::new();
        }
        if self.threads <= 1 || ntasks == 1 {
            let mut state = init();
            return (0..ntasks).map(|i| f(&mut state, i)).collect();
        }
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<T>>> = (0..ntasks).map(|_| Mutex::new(None)).collect();
        let nworkers = self.threads.min(ntasks);
        std::thread::scope(|s| {
            for _ in 0..nworkers {
                s.spawn(|| {
                    let mut state = init();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= ntasks {
                            break;
                        }
                        let out = f(&mut state, i);
                        *lock(&slots[i]) = Some(out);
                    }
                });
            }
        });
        slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap_or_else(PoisonError::into_inner)
                    .expect("worker completed every claimed task")
            })
            .collect()
    }

    /// Row-parallel in-place execution: treat `data` as rows of `width`
    /// elements, split it into `4 * threads` fixed contiguous chunks, and
    /// let workers claim chunks off the shared cursor (oversubscription
    /// absorbs per-row skew, e.g. RMAT hub rows). The row partition
    /// depends only on (nrows, threads) and each output row is written by
    /// exactly one claimant — determinism by construction. For kernels
    /// whose per-chunk cost is a full input scan (not proportional to the
    /// chunk), use [`Self::for_each_row_chunk_static`] instead: there,
    /// extra chunks multiply total work.
    pub fn for_each_row_chunk<F>(&self, data: &mut [f32], width: usize, f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        self.row_chunks_impl(data, width, self.threads.saturating_mul(4).max(1), f)
    }

    /// [`Self::for_each_row_chunk`] with exactly one chunk per worker —
    /// minimal chunk count for scan-all kernels (e.g. the deterministic
    /// transpose SpMM, where every chunk reads all of A).
    pub fn for_each_row_chunk_static<F>(&self, data: &mut [f32], width: usize, f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        self.row_chunks_impl(data, width, self.threads, f)
    }

    fn row_chunks_impl<F>(&self, data: &mut [f32], width: usize, nchunks: usize, f: F)
    where
        F: Fn(Range<usize>, &mut [f32]) + Sync,
    {
        if width == 0 || data.is_empty() {
            f(0..0, data);
            return;
        }
        let nrows = data.len() / width;
        debug_assert_eq!(nrows * width, data.len(), "data must be whole rows");
        // Serial fast path before any chunk planning: the streaming hot
        // loop calls this once per segment, and a depth-1 serial pass must
        // stay allocation-free (rust/tests/alloc_free.rs).
        if self.threads <= 1 || nrows <= 1 || nchunks <= 1 {
            f(0..nrows, data);
            return;
        }
        let ranges = chunk_ranges(nrows, nchunks);
        if ranges.len() <= 1 {
            f(0..nrows, data);
            return;
        }
        // Pre-split into disjoint chunks; workers claim them in index
        // order off the shared cursor. The Mutex<Option<..>> per chunk is
        // only the ownership hand-off (each is locked exactly once).
        let mut tasks: Vec<Mutex<Option<(Range<usize>, &mut [f32])>>> =
            Vec::with_capacity(ranges.len());
        let mut rest: &mut [f32] = data;
        for r in ranges {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((r.end - r.start) * width);
            rest = tail;
            tasks.push(Mutex::new(Some((r, head))));
        }
        let next = AtomicUsize::new(0);
        let nworkers = self.threads.min(tasks.len());
        std::thread::scope(|s| {
            for _ in 0..nworkers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= tasks.len() {
                        break;
                    }
                    let (range, chunk) =
                        lock(&tasks[i]).take().expect("each chunk claimed once");
                    f(range, chunk);
                });
            }
        });
    }
}

/// Deterministic near-equal partition of `0..n` into at most `k` contiguous
/// ranges (earlier ranges get the remainder). Depends only on `(n, k)`.
pub fn chunk_ranges(n: usize, k: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let k = k.max(1).min(n);
    let base = n / k;
    let rem = n % k;
    let mut out = Vec::with_capacity(k);
    let mut lo = 0usize;
    for i in 0..k {
        let len = base + usize::from(i < rem);
        out.push(lo..lo + len);
        lo += len;
    }
    debug_assert_eq!(lo, n);
    out
}

/// Bounded single-producer/single-consumer hand-off queue: the task
/// hand-off primitive between a staging task and the consuming thread of a
/// [`crate::runtime::prefetch`] pipeline. Capacity bounds how far the
/// producer may run ahead (the double-buffering depth); `close` signals
/// end-of-stream, `cancel` lets the consumer stop a blocked producer.
///
/// Hand-rolled rather than `std::sync::mpsc::sync_channel` for one
/// semantic the pipeline's memory bound needs: [`Self::reserve`] blocks
/// *before* the expensive production step, so a staged-but-unqueued item
/// can never exist without a free slot waiting for it (`sync_channel`
/// only blocks at send time, after production already happened).
pub struct Handoff<T> {
    capacity: usize,
    state: Mutex<HandoffState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct HandoffState<T> {
    buf: VecDeque<T>,
    closed: bool,
    cancelled: bool,
}

impl<T> Handoff<T> {
    /// Queue holding at most `capacity.max(1)` in-flight items.
    pub fn bounded(capacity: usize) -> Handoff<T> {
        Handoff {
            capacity: capacity.max(1),
            state: Mutex::new(HandoffState {
                buf: VecDeque::new(),
                closed: false,
                cancelled: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Block until the queue has room for one more item (or the consumer
    /// cancelled — then `false`). Producers call this *before* staging the
    /// next item so production itself never runs ahead of the queue bound.
    pub fn reserve(&self) -> bool {
        let mut st = lock(&self.state);
        loop {
            if st.cancelled {
                return false;
            }
            if st.buf.len() < self.capacity {
                return true;
            }
            st = self.not_full.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Enqueue `item`, blocking while the queue is full. Once the consumer
    /// has cancelled, the item is handed **back** as `Err(item)` instead of
    /// being dropped — a recycling pipeline's slab must survive the abort
    /// and retire to its pool, not leak to the allocator (the producer
    /// should stop staging either way).
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut st = lock(&self.state);
        loop {
            if st.cancelled {
                drop(st);
                return Err(item);
            }
            if st.buf.len() < self.capacity {
                break;
            }
            st = self.not_full.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st.buf.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking dequeue: the next buffered item if one is ready, else
    /// `None` immediately (whether or not the channel is still open). The
    /// recycling pipeline's producer uses this to pick up a drained buffer
    /// when one has come back without ever stalling the staging stream.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = lock(&self.state);
        let v = st.buf.pop_front();
        if v.is_some() {
            drop(st);
            self.not_full.notify_one();
        }
        v
    }

    /// Dequeue the next item in FIFO order, blocking while the queue is
    /// empty. Returns `None` once the channel is closed (or cancelled) and
    /// drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = lock(&self.state);
        loop {
            if let Some(v) = st.buf.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(v);
            }
            if st.closed || st.cancelled {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Producer side: no further items will be pushed. Buffered items stay
    /// consumable; a consumer blocked in [`Self::pop`] wakes up.
    pub fn close(&self) {
        lock(&self.state).closed = true;
        self.not_empty.notify_all();
    }

    /// Consumer side: stop the stream. A producer blocked in
    /// [`Self::push`] wakes up and gets its item back, and the buffered
    /// items are drained and **returned** to the caller rather than
    /// dropped. Two reasons, both found auditing the multi-consumer
    /// fan-out: a return lane's buffered slabs must outlive the abort so
    /// they can retire to their pool (the old drop-under-lock lost them),
    /// and dropping arbitrary `T`s while holding the state mutex let a
    /// panicking `Drop` poison the channel for every other thread.
    #[must_use = "the drained items carry recyclable buffers; drop them deliberately"]
    pub fn cancel(&self) -> Vec<T> {
        let mut st = lock(&self.state);
        st.cancelled = true;
        let drained: Vec<T> = st.buf.drain(..).collect();
        drop(st);
        self.not_full.notify_all();
        self.not_empty.notify_all();
        drained
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_contiguously() {
        for n in [0usize, 1, 2, 7, 64, 1000] {
            for k in [1usize, 2, 3, 8, 2000] {
                let rs = chunk_ranges(n, k);
                if n == 0 {
                    assert!(rs.is_empty());
                    continue;
                }
                assert!(rs.len() <= k.max(1) && rs.len() <= n);
                assert_eq!(rs[0].start, 0);
                assert_eq!(rs.last().unwrap().end, n);
                for w in rs.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                }
                let max = rs.iter().map(|r| r.len()).max().unwrap();
                let min = rs.iter().map(|r| r.len()).min().unwrap();
                assert!(max - min <= 1, "near-equal split: {min}..{max}");
            }
        }
    }

    #[test]
    fn map_tasks_preserves_task_order() {
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let out = pool.map_tasks(37, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn map_tasks_edge_counts() {
        let pool = Pool::new(4);
        assert!(pool.map_tasks(0, |i| i).is_empty());
        assert_eq!(pool.map_tasks(1, |i| i + 10), vec![10]);
        // More workers than tasks.
        assert_eq!(Pool::new(16).map_tasks(3, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn for_each_row_chunk_matches_serial() {
        let width = 5;
        let nrows = 23;
        let fill = |pool: &Pool| {
            let mut data = vec![0f32; nrows * width];
            pool.for_each_row_chunk(&mut data, width, |range, chunk| {
                for (local, row) in range.clone().enumerate() {
                    for c in 0..width {
                        chunk[local * width + c] = (row * width + c) as f32;
                    }
                }
            });
            data
        };
        let want = fill(&Pool::serial());
        assert_eq!(want, (0..nrows * width).map(|i| i as f32).collect::<Vec<_>>());
        for threads in [2usize, 4, 8, 64] {
            assert_eq!(fill(&Pool::new(threads)), want, "threads={threads}");
        }
    }

    #[test]
    fn for_each_row_chunk_degenerate_inputs() {
        let pool = Pool::new(4);
        let mut empty: Vec<f32> = Vec::new();
        pool.for_each_row_chunk(&mut empty, 3, |range, chunk| {
            assert!(range.is_empty() && chunk.is_empty());
        });
        let mut one = vec![1f32, 2.0];
        pool.for_each_row_chunk(&mut one, 2, |range, chunk| {
            assert_eq!(range, 0..1);
            chunk[0] += 1.0;
        });
        assert_eq!(one, vec![2.0, 2.0]);
    }

    #[test]
    fn map_tasks_init_reuses_worker_state_correctly() {
        // Worker-local scratch must not leak between tasks in a way that
        // changes results: fill scratch with task-dependent garbage, and
        // require each task's output to depend only on its own index.
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let out = pool.map_tasks_init(
                50,
                || vec![0u64; 16],
                |scratch, i| {
                    for (j, s) in scratch.iter_mut().enumerate() {
                        *s = (i * 31 + j) as u64; // overwrite, never read stale
                    }
                    scratch.iter().sum::<u64>()
                },
            );
            let want: Vec<u64> =
                (0..50).map(|i| (0..16).map(|j| (i * 31 + j) as u64).sum()).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn static_row_chunks_match_oversubscribed() {
        let width = 3;
        let nrows = 17;
        let run = |oversub: bool, threads: usize| {
            let mut data = vec![0f32; nrows * width];
            let pool = Pool::new(threads);
            let fill = |range: Range<usize>, chunk: &mut [f32]| {
                for (local, row) in range.clone().enumerate() {
                    for c in 0..width {
                        chunk[local * width + c] = (row * 10 + c) as f32;
                    }
                }
            };
            if oversub {
                pool.for_each_row_chunk(&mut data, width, fill);
            } else {
                pool.for_each_row_chunk_static(&mut data, width, fill);
            }
            data
        };
        let want = run(true, 1);
        for threads in [1usize, 2, 4, 8] {
            assert_eq!(run(true, threads), want);
            assert_eq!(run(false, threads), want);
        }
    }

    #[test]
    fn auto_thread_count_is_positive() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::serial().threads(), 1);
    }

    #[test]
    fn map_tasks_is_deterministic_across_runs() {
        let pool = Pool::new(8);
        let a = pool.map_tasks(100, |i| (i as u64).wrapping_mul(0x9e3779b97f4a7c15));
        let b = pool.map_tasks(100, |i| (i as u64).wrapping_mul(0x9e3779b97f4a7c15));
        assert_eq!(a, b);
    }

    #[test]
    fn handoff_is_fifo_across_threads() {
        let chan: Handoff<usize> = Handoff::bounded(2);
        let got = Pool::new(2).scoped(|s| {
            s.spawn(|| {
                for i in 0..100 {
                    assert!(chan.push(i).is_ok(), "consumer never cancels in this test");
                }
                chan.close();
            });
            let mut got = Vec::new();
            while let Some(v) = chan.pop() {
                got.push(v);
            }
            got
        });
        assert_eq!(got, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn handoff_close_drains_then_ends() {
        let chan: Handoff<u32> = Handoff::bounded(4);
        assert!(chan.push(1).is_ok());
        assert!(chan.push(2).is_ok());
        chan.close();
        assert_eq!(chan.pop(), Some(1));
        assert_eq!(chan.pop(), Some(2));
        assert_eq!(chan.pop(), None);
        assert_eq!(chan.pop(), None, "closed channel stays ended");
    }

    #[test]
    fn handoff_cancel_unblocks_full_producer() {
        let chan: Handoff<u32> = Handoff::bounded(1);
        Pool::new(2).scoped(|s| {
            let producer = s.spawn(|| {
                let first = chan.push(7);
                let second = chan.push(8);
                // With capacity 1 and nothing consumed after the pop below,
                // this one can only end via cancellation.
                let third = chan.push(9);
                (first, second, third)
            });
            // Popping the first item proves push(7) completed before cancel.
            assert_eq!(chan.pop(), Some(7));
            let drained = chan.cancel();
            let (first, _, third) = producer.join().unwrap();
            assert!(first.is_ok(), "push before cancel succeeds");
            assert_eq!(third, Err(9), "blocked push hands the item back on cancel");
            assert_eq!(drained, vec![8], "cancel returns the buffered items");
        });
        assert_eq!(chan.pop(), None, "cancelled channel yields nothing");
    }

    #[test]
    fn handoff_try_pop_never_blocks() {
        let chan: Handoff<u32> = Handoff::bounded(2);
        assert_eq!(chan.try_pop(), None, "empty open channel yields None immediately");
        assert!(chan.push(5).is_ok());
        assert!(chan.push(6).is_ok());
        assert_eq!(chan.try_pop(), Some(5));
        // try_pop freed a slot: a producer blocked on push would wake. Here
        // we just verify the slot is reusable without blocking.
        assert!(chan.push(7).is_ok());
        chan.close();
        assert_eq!(chan.try_pop(), Some(6));
        assert_eq!(chan.try_pop(), Some(7), "close drains buffered items");
        assert_eq!(chan.try_pop(), None);
    }

    #[test]
    fn handoff_capacity_floor_is_one() {
        let chan: Handoff<u8> = Handoff::bounded(0);
        assert!(chan.push(9).is_ok());
        assert_eq!(chan.pop(), Some(9));
    }

    #[test]
    fn handoff_push_after_cancel_hands_the_item_back() {
        // The lost-slab window of the multi-consumer audit: a drainer
        // returning a slab through a lane whose consumer already aborted
        // must get the slab back (to retire it to the pool), never have it
        // silently destroyed.
        let chan: Handoff<Vec<u8>> = Handoff::bounded(4);
        assert!(chan.push(vec![1, 2, 3]).is_ok());
        let drained = chan.cancel();
        assert_eq!(drained, vec![vec![1, 2, 3]], "buffered slab survives the cancel");
        assert_eq!(
            chan.push(vec![4, 5]),
            Err(vec![4, 5]),
            "post-cancel push returns the slab to its caller"
        );
        assert_eq!(chan.try_pop(), None);
    }

    #[test]
    fn handoff_survives_panicking_drop_during_cancel() {
        // cancel() used to clear the buffer while *holding* the state
        // mutex, so an item whose Drop panics poisoned the channel: every
        // later push/pop then died with a PoisonError that masked the
        // original panic. Now cancel hands the items out and the drop runs
        // outside the lock; the channel stays usable and the original
        // payload is what the catcher sees.
        struct Grenade(bool);
        impl Drop for Grenade {
            fn drop(&mut self) {
                if self.0 && !std::thread::panicking() {
                    panic!("slab drop exploded");
                }
            }
        }
        let chan: Handoff<Grenade> = Handoff::bounded(2);
        assert!(chan.push(Grenade(true)).is_ok());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            drop(chan.cancel());
        }))
        .expect_err("the armed drop must panic");
        assert_eq!(
            caught.downcast_ref::<&str>().copied(),
            Some("slab drop exploded"),
            "the original payload surfaces"
        );
        // The channel mutex was never poisoned: both sides still answer
        // (as the cancelled channel they are) instead of panicking.
        assert!(chan.push(Grenade(false)).is_err(), "cancelled channel rejects pushes");
        assert!(chan.pop().is_none(), "cancelled channel drains clean");
        assert!(!chan.reserve(), "reserve sees the cancel, not a poison panic");
    }

    #[test]
    fn handoff_multi_drainer_return_lane_never_wedges() {
        // Fan-out return-lane sizing contract: with capacity >= the number
        // of slabs simultaneously in flight (segments x drainers here),
        // every drainer's give-back push completes without blocking even
        // when the producer never pops — the stuck-producer window the
        // fan-out audit closed by sizing the lane for *all* consumers.
        const DRAINERS: usize = 4;
        const SLABS: usize = 8;
        let lane: Handoff<(usize, usize)> = Handoff::bounded(DRAINERS * SLABS);
        Pool::new(DRAINERS).scoped(|s| {
            for d in 0..DRAINERS {
                let lane = &lane;
                s.spawn(move || {
                    for i in 0..SLABS {
                        assert!(lane.push((d, i)).is_ok(), "lane sized for every drainer");
                    }
                });
            }
        });
        lane.close();
        let mut got = Vec::new();
        while let Some(v) = lane.try_pop() {
            got.push(v);
        }
        got.sort_unstable();
        let want: Vec<(usize, usize)> =
            (0..DRAINERS).flat_map(|d| (0..SLABS).map(move |i| (d, i))).collect();
        assert_eq!(got, want, "every slab crossed the lane exactly once");
    }
}
