//! Tile executor: runs sparse aggregation through the fixed-shape
//! `bsr_spmm` artifact (dynamic matrix -> padded BSR batches -> accumulate).
//!
//! This is the rust half of the RoBW->MXU tiling contract (DESIGN.md
//! §Hardware-Adaptation): [`crate::sparse::block`] regrids a RoBW segment
//! into `bm x bk` tiles and pads them to the artifact's static `(r, nb)`
//! grid; this module feeds batches through PJRT and scatters the results
//! back into the output rows, accumulating across overflow slots.

use super::artifacts::ArtifactSpec;
use super::executor::{Buf, Executor};
use super::pool::Pool;
use crate::sparse::block::{pack_csr_batches_par, SpmmBatch};
use crate::sparse::spmm::Dense;
use crate::sparse::Csr;
use anyhow::{anyhow, bail, Result};

/// Static shape of one `bsr_spmm` artifact (from manifest meta).
#[derive(Debug, Clone, Copy)]
pub struct SpmmShape {
    /// Row-block slots per call.
    pub r: usize,
    /// Padded tile slots per row block.
    pub nb: usize,
    /// Tile height.
    pub bm: usize,
    /// Tile width.
    pub bk: usize,
    /// Feature-panel rows (inner dimension) the artifact was lowered with.
    pub k: usize,
    /// Feature width.
    pub f: usize,
}

impl SpmmShape {
    /// Read the shape from an artifact's manifest metadata.
    pub fn from_spec(spec: &ArtifactSpec) -> Result<SpmmShape> {
        let get = |key: &str| {
            spec.meta
                .get(key)
                .map(|&v| v as usize)
                .ok_or_else(|| anyhow!("{}: missing meta {key}", spec.name))
        };
        Ok(SpmmShape { r: get("r")?, nb: get("nb")?, bm: get("bm")?, bk: get("bk")?, k: get("k")?, f: get("f")? })
    }
}

/// Executes CSR x dense SpMM through a `bsr_spmm` artifact.
pub struct BsrSpmmExec {
    /// Name of the bound `bsr_spmm` artifact.
    pub artifact: String,
    /// Its static tile grid.
    pub shape: SpmmShape,
}

impl BsrSpmmExec {
    /// Pick an artifact variant matching feature width `f` from the
    /// executor's manifest.
    pub fn for_feature_width(exec: &Executor, f: usize) -> Result<BsrSpmmExec> {
        for spec in exec.manifest().spmm_variants() {
            let shape = SpmmShape::from_spec(spec)?;
            if shape.f == f {
                return Ok(BsrSpmmExec { artifact: spec.name.clone(), shape });
            }
        }
        bail!("no bsr_spmm artifact for feature width {f}")
    }

    /// Compute `a · h` through the accelerator artifact (serial packing).
    pub fn spmm(&self, exec: &mut Executor, a: &Csr, h: &Dense) -> Result<Dense> {
        self.spmm_with_pool(exec, a, h, &Pool::serial())
    }

    /// Compute `a · h` through the accelerator artifact.
    ///
    /// Constraints (checked): `h.ncols == f`, `a.ncols <= k`,
    /// `h.nrows == a.ncols`. Rows of `a` are processed `r*bm` at a time;
    /// the padded feature panel is reused across batches. The CPU-side
    /// tile extraction/packing (the bridge cost, §Perf) runs on `pool`;
    /// the PJRT dispatch itself stays serial — one client, one stream —
    /// and the per-slot output accumulation is index-ordered, so results
    /// are identical at every thread count.
    pub fn spmm_with_pool(
        &self,
        exec: &mut Executor,
        a: &Csr,
        h: &Dense,
        pool: &Pool,
    ) -> Result<Dense> {
        let s = self.shape;
        if h.ncols != s.f {
            bail!("feature width {} != artifact f {}", h.ncols, s.f);
        }
        if a.ncols != h.nrows {
            bail!("inner dim mismatch: {} vs {}", a.ncols, h.nrows);
        }
        if a.ncols > s.k {
            bail!("a.ncols {} exceeds artifact K {} (panel the input)", a.ncols, s.k);
        }

        // Pad the feature panel once and build its literal once — it is
        // identical across every batch of this pass (§Perf).
        let mut h_pad = vec![0f32; s.k * s.f];
        for r in 0..h.nrows {
            h_pad[r * s.f..(r + 1) * s.f].copy_from_slice(h.row(r));
        }
        exec.load(&self.artifact)?;
        let h_lit = exec.prep_literal(&self.artifact, 3, &Buf::F32(h_pad))?;

        // Fused extraction+packing (§Perf: one write per padded payload),
        // parallel across row blocks / batches on the pool.
        let batches = pack_csr_batches_par(a, s.bm, s.bk, s.r, s.nb, pool);
        let mut out = Dense::zeros(a.nrows, s.f);
        for batch in &batches {
            let nblk = exec.prep_literal(&self.artifact, 0, &Buf::S32(batch.nblk.clone()))?;
            let colidx = exec.prep_literal(&self.artifact, 1, &Buf::S32(batch.colidx.clone()))?;
            let blocks = exec.prep_literal(&self.artifact, 2, &Buf::F32(batch.blocks.clone()))?;
            let outputs =
                exec.run_literals(&self.artifact, &[&nblk, &colidx, &blocks, &h_lit])?;
            let y = outputs[0].as_f32()?; // [r*bm, f]
            for (slot, &brow) in batch.slot_block_row.iter().enumerate() {
                let row0 = brow * s.bm;
                for lr in 0..s.bm {
                    let dst_row = row0 + lr;
                    if dst_row >= a.nrows {
                        break;
                    }
                    let src = &y[(slot * s.bm + lr) * s.f..(slot * s.bm + lr + 1) * s.f];
                    let dst = &mut out.data[dst_row * s.f..(dst_row + 1) * s.f];
                    for (d, &v) in dst.iter_mut().zip(src.iter()) {
                        *d += v; // accumulate overflow slots of the same row block
                    }
                }
            }
        }
        Ok(out)
    }
}

/// CPU tile executor: runs the same padded-batch program `bsr_spmm`
/// consumes, entirely on host threads. This is the parallel per-tile
/// execution path that works without compiled artifacts (and the
/// differential-testing oracle target for the packing/accumulation
/// semantics — see `rust/tests/differential.rs`).
#[derive(Debug, Clone, Copy)]
pub struct CpuTileSpmm {
    /// Tile height.
    pub bm: usize,
    /// Tile width.
    pub bk: usize,
    /// Row-block slots per batch (the artifact grid's `r`).
    pub r: usize,
    /// Tile slots per row-block slot (the artifact grid's `nb`).
    pub nb: usize,
}

impl CpuTileSpmm {
    /// `a · h` via pack → tile-execute, both phases on the pool.
    pub fn spmm(&self, a: &Csr, h: &Dense, pool: &Pool) -> Dense {
        assert_eq!(a.ncols, h.nrows, "inner dimension mismatch");
        let batches = pack_csr_batches_par(a, self.bm, self.bk, self.r, self.nb, pool);
        execute_batches_cpu(&batches, h, a.nrows, self.bm, self.bk, self.nb, pool)
    }
}

/// Execute packed [`SpmmBatch`]es on the CPU, output-row-parallel.
///
/// Each pool worker owns a contiguous output row range and accumulates, in
/// fixed (batch, slot, tile, column) order, every tile whose row block
/// intersects its range — so a given output row always sees the same
/// addition sequence regardless of thread count (deterministic), and that
/// sequence is ascending-k, matching `spmm`'s per-row order. Zero-valued
/// tile entries are skipped as padding positions a CSR traversal never
/// visits. Caveat: an *explicitly stored* 0.0 in the CSR (possible via
/// duplicate-cancelling COO input) is indistinguishable from padding after
/// packing and is skipped too — with finite features the ±0.0-sign
/// difference is invisible to `==`, but a non-finite feature row (Inf/NaN)
/// multiplied by a stored zero would diverge from `spmm` (NaN vs skip).
pub fn execute_batches_cpu(
    batches: &[SpmmBatch],
    h: &Dense,
    nrows: usize,
    bm: usize,
    bk: usize,
    nb: usize,
    pool: &Pool,
) -> Dense {
    let f = h.ncols;
    let mut out = Dense::zeros(nrows, f);
    // Static split: every chunk scans the full batch/slot metadata to find
    // its intersecting row blocks, so oversubscribed chunks would multiply
    // that scan (pool.rs guidance for scan-all kernels).
    pool.for_each_row_chunk_static(&mut out.data, f, |range, chunk| {
        for batch in batches {
            for (slot, &brow) in batch.slot_block_row.iter().enumerate() {
                let row0 = brow * bm;
                if row0 >= range.end || row0 + bm <= range.start {
                    continue;
                }
                for j in 0..batch.nblk[slot] as usize {
                    let bc = batch.colidx[slot * nb + j] as usize;
                    let tile = &batch.blocks[(slot * nb + j) * bm * bk..(slot * nb + j + 1) * bm * bk];
                    for lr in 0..bm {
                        let row = row0 + lr;
                        if row >= range.end {
                            break;
                        }
                        if row < range.start {
                            continue;
                        }
                        let local = row - range.start;
                        let orow = &mut chunk[local * f..(local + 1) * f];
                        for lc in 0..bk {
                            let k = bc * bk + lc;
                            if k >= h.nrows {
                                break;
                            }
                            let av = tile[lr * bk + lc];
                            if av == 0.0 {
                                continue;
                            }
                            let hrow = h.row(k);
                            for (o, &hv) in orow.iter_mut().zip(hrow.iter()) {
                                *o += av * hv;
                            }
                        }
                    }
                }
            }
        }
    });
    out
}

/// Executes the fused combine tile (`gcn_combine_*`): relu(x·w + b).
pub struct CombineExec {
    /// Name of the bound `gcn_combine` artifact.
    pub artifact: String,
    /// (p, f, h) static shape.
    pub p: usize,
    /// Input feature width.
    pub f: usize,
    /// Output (hidden) width.
    pub h: usize,
}

impl CombineExec {
    /// Pick a combine artifact with matching in/out widths.
    pub fn for_widths(exec: &Executor, f: usize, h: usize, relu: bool) -> Result<CombineExec> {
        for spec in exec.manifest().artifacts.iter().filter(|a| a.name.starts_with("gcn_combine_")) {
            let mf = spec.meta.get("f").copied().unwrap_or(0.0) as usize;
            let mh = spec.meta.get("h").copied().unwrap_or(0.0) as usize;
            let mrelu = spec.meta.get("relu").copied().unwrap_or(1.0) != 0.0;
            if mf == f && mh == h && mrelu == relu {
                let p = spec.meta.get("p").copied().unwrap_or(0.0) as usize;
                return Ok(CombineExec { artifact: spec.name.clone(), p, f, h });
            }
        }
        bail!("no gcn_combine artifact for f={f} h={h} relu={relu}")
    }

    /// Compute relu(x·w + b), row-batching x through the static p rows.
    pub fn combine(&self, exec: &mut Executor, x: &Dense, w: &Dense, b: &[f32]) -> Result<Dense> {
        if x.ncols != self.f || w.nrows != self.f || w.ncols != self.h || b.len() != self.h {
            bail!("combine shape mismatch");
        }
        let mut out = Dense::zeros(x.nrows, self.h);
        let w_buf = Buf::F32(w.data.clone());
        let b_buf = Buf::F32(b.to_vec());
        let mut row = 0;
        while row < x.nrows {
            let take = (x.nrows - row).min(self.p);
            let mut xp = vec![0f32; self.p * self.f];
            xp[..take * self.f]
                .copy_from_slice(&x.data[row * self.f..(row + take) * self.f]);
            let outputs =
                exec.run(&self.artifact, &[Buf::F32(xp), w_buf.clone(), b_buf.clone()])?;
            let y = outputs[0].as_f32()?;
            out.data[row * self.h..(row + take) * self.h]
                .copy_from_slice(&y[..take * self.h]);
            row += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::spmm::spmm;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn cpu_tile_exec_matches_spmm_at_every_thread_count() {
        let mut rng = Pcg::seed(41);
        let a = random_csr(&mut rng, 37, 50, 0.15);
        let h = Dense::from_vec(50, 6, (0..300).map(|_| rng.normal() as f32).collect());
        let want = spmm(&a, &h);
        let exec = CpuTileSpmm { bm: 4, bk: 8, r: 3, nb: 2 };
        for threads in [1usize, 2, 4, 8] {
            let got = exec.spmm(&a, &h, &Pool::new(threads));
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn cpu_tile_exec_handles_empty_matrix() {
        let a = Csr::empty(9, 12);
        let h = Dense::zeros(12, 4);
        let exec = CpuTileSpmm { bm: 4, bk: 4, r: 2, nb: 2 };
        let out = exec.spmm(&a, &h, &Pool::new(4));
        assert_eq!(out, Dense::zeros(9, 4));
    }
}
