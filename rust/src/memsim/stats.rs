//! I/O statistics derived from the simulator op log — the data behind the
//! paper's Figure 7 (GPU-CPU breakdown by memcpy kind) and Figure 8
//! (GPU/CPU-SSD achieved bandwidth).

use super::channel::{CostModel, Op};
use super::sim::Sim;
use std::collections::BTreeMap;

/// Measured staging I/O of one executed disk-backed pipeline pass.
///
/// The simulated schedulers charge planner-*estimated* byte counts; the
/// in-memory execution path mirrors that by sleeping on estimates
/// (`StagingConfig::io_cost`). The disk-backed path instead performs real
/// reads and records what actually moved per tier here — cache hits in the
/// host-RAM tier add nothing — and converts the measured counts into
/// modeled seconds through the same [`CostModel`] calibration, so figures
/// derived from executed and simulated passes stay comparable.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StagingMeter {
    /// Bytes actually read from the NVMe tier.
    pub disk_bytes: u64,
    /// Segment reads served by the host-RAM cache tier.
    pub cache_hits: usize,
    /// Segment reads that went to disk.
    pub cache_misses: usize,
}

impl StagingMeter {
    /// Record one segment read: a hit costs no disk bytes, a miss charges
    /// the measured file size.
    pub fn record(&mut self, disk_bytes: u64, cache_hit: bool) {
        if cache_hit {
            self.cache_hits += 1;
        } else {
            self.cache_misses += 1;
            self.disk_bytes += disk_bytes;
        }
    }

    /// Seconds the cost model charges for the measured NVMe reads
    /// ([`Op::NvmeToHost`] over `disk_bytes`; 0 when nothing hit disk).
    pub fn modeled_read_secs(&self, cm: &CostModel) -> f64 {
        if self.disk_bytes == 0 {
            0.0
        } else {
            cm.transfer_secs(Op::NvmeToHost, self.disk_bytes)
        }
    }
}

/// Aggregated per-op-kind I/O: bytes moved, busy seconds, op count.
#[derive(Debug, Clone, Default)]
pub struct IoStats {
    /// Aggregates keyed by op-kind name ("HtoD", "GdsRead", ...).
    pub per_op: BTreeMap<&'static str, OpAgg>,
}

/// Totals for one op kind.
#[derive(Debug, Clone, Copy, Default)]
pub struct OpAgg {
    /// Bytes moved.
    pub bytes: u64,
    /// Seconds the op kind held its resources.
    pub secs: f64,
    /// Number of ops.
    pub count: u64,
}

fn op_name(op: Op) -> &'static str {
    match op {
        Op::NvmeToHost => "NvmeToHost",
        Op::HostToNvme => "HostToNvme",
        Op::GdsRead => "GdsRead",
        Op::GdsWrite => "GdsWrite",
        Op::HtoD => "HtoD",
        Op::DtoH => "DtoH",
        Op::UmFault => "UM",
        Op::HostMemcpy => "HostMemcpy",
        Op::CpuPartition => "CpuPartition",
        Op::CpuCompute => "CpuCompute",
        Op::GpuKernel => "GpuKernel",
        Op::GpuMalloc => "GpuMalloc",
    }
}

impl IoStats {
    /// Summarize a finished simulation.
    pub fn from_sim(sim: &Sim) -> IoStats {
        let mut per_op: BTreeMap<&'static str, OpAgg> = BTreeMap::new();
        for rec in &sim.log {
            let agg = per_op.entry(op_name(rec.op)).or_default();
            agg.bytes += rec.bytes;
            agg.secs += rec.end - rec.start;
            agg.count += 1;
        }
        IoStats { per_op }
    }

    /// Aggregate for one op kind (zeroes if the kind never ran).
    pub fn get(&self, name: &str) -> OpAgg {
        self.per_op.get(name).copied().unwrap_or_default()
    }

    /// Total GPU<->CPU traffic (Fig. 7 left panel: HtoD + DtoH + UM).
    pub fn gpu_cpu_bytes(&self) -> u64 {
        self.get("HtoD").bytes + self.get("DtoH").bytes + self.get("UM").bytes
    }

    /// Total GPU<->CPU transfer latency (Fig. 7 right panel).
    pub fn gpu_cpu_secs(&self) -> f64 {
        self.get("HtoD").secs + self.get("DtoH").secs + self.get("UM").secs
    }

    /// GPU<->SSD bytes via the GDS direct path (Fig. 8 "GPU-SSD").
    pub fn gpu_ssd_bytes(&self) -> u64 {
        self.get("GdsRead").bytes + self.get("GdsWrite").bytes
    }

    /// CPU<->SSD bytes via classic NVMe reads/writes (Fig. 8 "CPU-SSD").
    pub fn cpu_ssd_bytes(&self) -> u64 {
        self.get("NvmeToHost").bytes + self.get("HostToNvme").bytes
    }

    /// Achieved bandwidth of a path in GB/s (bytes / busy time).
    pub fn bandwidth_gbps(&self, names: &[&str]) -> f64 {
        let (mut bytes, mut secs) = (0u64, 0f64);
        for n in names {
            let a = self.get(n);
            bytes += a.bytes;
            secs += a.secs;
        }
        if secs == 0.0 {
            0.0
        } else {
            bytes as f64 / secs / 1e9
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::channel::CostModel;

    #[test]
    fn aggregates_by_kind() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        sim.transfer(&cm, Op::HtoD, 1000, 0.0, "a");
        sim.transfer(&cm, Op::HtoD, 500, 0.0, "b");
        sim.transfer(&cm, Op::DtoH, 200, 0.0, "c");
        let st = IoStats::from_sim(&sim);
        assert_eq!(st.get("HtoD").bytes, 1500);
        assert_eq!(st.get("HtoD").count, 2);
        assert_eq!(st.gpu_cpu_bytes(), 1700);
        assert_eq!(st.get("UM").count, 0);
    }

    #[test]
    fn staging_meter_accumulates_measured_bytes() {
        let mut m = StagingMeter::default();
        m.record(1000, false);
        m.record(0, true);
        m.record(500, false);
        assert_eq!(m.disk_bytes, 1500);
        assert_eq!((m.cache_hits, m.cache_misses), (1, 2));
        let cm = CostModel::default();
        assert!(m.modeled_read_secs(&cm) > 0.0);
        assert_eq!(StagingMeter::default().modeled_read_secs(&cm), 0.0);
    }

    #[test]
    fn bandwidth_is_bytes_over_busy() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        sim.transfer(&cm, Op::GdsRead, 5_800_000_000, 0.0, "b");
        let st = IoStats::from_sim(&sim);
        let bw = st.bandwidth_gbps(&["GdsRead"]);
        assert!((bw - cm.gds_read_gbps).abs() / cm.gds_read_gbps < 0.01, "bw {bw}");
    }
}
