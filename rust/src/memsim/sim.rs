//! Deterministic list-scheduling simulator.
//!
//! Ops are submitted in program order with explicit dependency times; the
//! simulator assigns `start = max(dep_ready, resource_free...)` and
//! serializes each resource. This captures exactly the overlap semantics
//! the paper's three-phase scheduling exploits (dual-way transfers on
//! disjoint resources proceed in parallel; same-resource ops queue).

use super::channel::{CostModel, Op, Res, ALL_RES};

/// One completed op in the log (drives the Fig. 7/8 breakdowns).
#[derive(Debug, Clone)]
pub struct OpRecord {
    /// Op kind.
    pub op: Op,
    /// Bytes moved (0 for compute ops).
    pub bytes: u64,
    /// Simulated start time (seconds from epoch start).
    pub start: f64,
    /// Simulated completion time.
    pub end: f64,
    /// Free-form tag for reports ("CSC B load", "RoBW seg 3", ...).
    pub tag: &'static str,
}

/// The simulator: per-resource busy-until clocks + an op log.
#[derive(Debug, Default)]
pub struct Sim {
    busy: std::collections::HashMap<Res, f64>,
    /// Every submitted op, in submission order.
    pub log: Vec<OpRecord>,
}

impl Sim {
    /// Fresh simulator: all resources free at t = 0.
    pub fn new() -> Self {
        let mut busy = std::collections::HashMap::new();
        for r in ALL_RES {
            busy.insert(r, 0.0);
        }
        Sim { busy, log: Vec::new() }
    }

    fn schedule(&mut self, op: Op, dur: f64, ready: f64, bytes: u64, tag: &'static str) -> f64 {
        let (r1, r2) = CostModel::resources(op);
        let mut start = ready.max(self.busy[&r1]);
        if let Some(r2) = r2 {
            start = start.max(self.busy[&r2]);
        }
        let end = start + dur;
        self.busy.insert(r1, end);
        if let Some(r2) = r2 {
            self.busy.insert(r2, end);
        }
        self.log.push(OpRecord { op, bytes, start, end, tag });
        end
    }

    /// Submit a transfer of `bytes` that may start once `ready` (dependency
    /// completion time) has passed. Returns its completion time.
    pub fn transfer(
        &mut self,
        cm: &CostModel,
        op: Op,
        bytes: u64,
        ready: f64,
        tag: &'static str,
    ) -> f64 {
        if bytes == 0 {
            return ready;
        }
        let dur = cm.transfer_secs(op, bytes);
        self.schedule(op, dur, ready, bytes, tag)
    }

    /// Submit a sparse GPU kernel of `flops` over `bytes` of operand data.
    pub fn gpu_kernel(
        &mut self,
        cm: &CostModel,
        flops: u64,
        bytes: u64,
        ready: f64,
        tag: &'static str,
    ) -> f64 {
        if flops == 0 && bytes == 0 {
            return ready;
        }
        self.schedule(Op::GpuKernel, cm.gpu_secs(flops, bytes), ready, 0, tag)
    }

    /// Submit a dense-rate GPU kernel (combination matmul tiles).
    pub fn gpu_dense(&mut self, cm: &CostModel, flops: u64, ready: f64, tag: &'static str) -> f64 {
        if flops == 0 {
            return ready;
        }
        self.schedule(Op::GpuKernel, cm.gpu_dense_secs(flops), ready, 0, tag)
    }

    /// Submit a CPU compute span of `flops` (UCG's CPU share).
    pub fn cpu_compute(&mut self, cm: &CostModel, flops: u64, ready: f64, tag: &'static str) -> f64 {
        if flops == 0 {
            return ready;
        }
        self.schedule(Op::CpuCompute, cm.cpu_secs(flops), ready, 0, tag)
    }

    /// Submit a cudaMalloc.
    pub fn gpu_malloc(&mut self, cm: &CostModel, ready: f64, tag: &'static str) -> f64 {
        self.schedule(Op::GpuMalloc, cm.gpu_malloc_s, ready, 0, tag)
    }

    /// Occupy an op's resources for an explicit duration (used to account
    /// aggregate fixed costs, e.g. N real segments' submission overheads
    /// coalesced into one simulator op).
    pub fn occupy(&mut self, op: Op, dur_s: f64, ready: f64, tag: &'static str) -> f64 {
        if dur_s <= 0.0 {
            return ready;
        }
        self.schedule(op, dur_s, ready, 0, tag)
    }

    /// Latest completion time across all resources — the epoch makespan.
    pub fn makespan(&self) -> f64 {
        self.log.iter().map(|r| r.end).fold(0.0, f64::max)
    }

    /// Time a specific resource is busy (utilization numerator).
    pub fn busy_time(&self, res: Res) -> f64 {
        self.log
            .iter()
            .filter(|r| {
                let (a, b) = CostModel::resources(r.op);
                a == res || b == Some(res)
            })
            .map(|r| r.end - r.start)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_resource_serializes() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        let t1 = sim.transfer(&cm, Op::HtoD, 1 << 30, 0.0, "a");
        let t2 = sim.transfer(&cm, Op::HtoD, 1 << 30, 0.0, "b");
        assert!(t2 > t1, "second HtoD must queue behind first");
        assert!((t2 - 2.0 * t1).abs() < 1e-6);
    }

    #[test]
    fn disjoint_resources_overlap() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        // The paper's dual-way path: GDS read (NVMe+GpuDma) overlaps a
        // host-side NVMe read? No — both hold NVMe, so they serialize.
        // But H2D and D2H do overlap:
        let t1 = sim.transfer(&cm, Op::HtoD, 1 << 30, 0.0, "h2d");
        let t2 = sim.transfer(&cm, Op::DtoH, 1 << 30, 0.0, "d2h");
        assert!((t1 - t2).abs() / t1 < 0.2, "independent engines run concurrently");
        let makespan = sim.makespan();
        assert!(makespan < t1 + t2, "makespan reflects overlap");
    }

    #[test]
    fn gds_serializes_with_nvme_host_reads() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        let t1 = sim.transfer(&cm, Op::NvmeToHost, 1 << 30, 0.0, "a");
        let t2 = sim.transfer(&cm, Op::GdsRead, 1 << 30, 0.0, "b");
        assert!(t2 > t1, "GDS shares the NVMe controller");
    }

    #[test]
    fn dependencies_respected() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        let load = sim.transfer(&cm, Op::HtoD, 1 << 20, 0.0, "load");
        let k = sim.gpu_kernel(&cm, 1 << 20, 1 << 20, load, "kernel");
        assert!(k > load);
        let rec = sim.log.last().unwrap();
        assert!(rec.start >= load);
    }

    #[test]
    fn zero_byte_transfer_is_free() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        let t = sim.transfer(&cm, Op::HtoD, 0, 1.5, "noop");
        assert_eq!(t, 1.5);
        assert!(sim.log.is_empty());
    }

    #[test]
    fn busy_time_accounts_shared_resources() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        sim.transfer(&cm, Op::GdsRead, 1 << 30, 0.0, "gds");
        assert!(sim.busy_time(Res::Nvme) > 0.0);
        assert!(sim.busy_time(Res::GpuDma) > 0.0);
        assert_eq!(sim.busy_time(Res::PcieH2d), 0.0);
    }
}
