//! Tiered-memory / transfer simulator.
//!
//! The paper evaluates on an RTX 4090 + PCIe 4.0 + M.2 NVMe testbed and
//! states its I/O and kernel latencies are *modeled with simulations
//! profiled via Nsight* (§V-A). This module is our equivalent substrate: a
//! deterministic list-scheduling simulator over the machine's resources
//! (NVMe, GDS path, PCIe H2D/D2H engines, host CPU, GPU, UM fault engine)
//! with a single calibration point ([`CostModel`]).
//!
//! Every scheduler (AIRES + the three baselines) expresses an epoch as a
//! DAG of [`Sim`] operations; the simulator assigns start times respecting
//! both dependency edges and per-resource serialization, and keeps a full
//! op log from which the Figure 7/8 I/O breakdowns are derived.

pub mod alloc;
pub mod channel;
pub mod sim;
pub mod stats;
pub mod trace;

pub use alloc::OutputModel;
pub use channel::{CostModel, Op, Res};
pub use sim::Sim;
pub use stats::{IoStats, StagingMeter};

/// GPU memory ledger: capacity-checked alloc/free with peak tracking.
/// Schedulers use it to decide segment sizes and detect OOM, mirroring the
/// paper's `cudaMalloc`-guided dynamic allocation (§IV).
#[derive(Debug, Clone)]
pub struct GpuMem {
    /// Total device bytes (the evaluated constraint).
    pub capacity: u64,
    /// Currently allocated bytes.
    pub used: u64,
    /// High-water mark of `used` over the ledger's lifetime.
    pub peak: u64,
}

/// Error returned when an allocation exceeds the memory constraint —
/// the condition reported as '-' (OOM) in the paper's Table III.
/// (Display/Error are hand-implemented: thiserror's derive is not in the
/// offline crate set.)
#[derive(Debug, Clone)]
pub struct OomError {
    /// Bytes the failing allocation asked for.
    pub wanted: u64,
    /// Bytes already allocated at the time.
    pub used: u64,
    /// The ledger's capacity.
    pub capacity: u64,
    /// What was being allocated (for the failure message).
    pub context: String,
}

impl std::fmt::Display for OomError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "GPU OOM: wanted {} B, used {} B of {} B ({})",
            self.wanted, self.used, self.capacity, self.context
        )
    }
}

impl std::error::Error for OomError {}

impl GpuMem {
    /// Empty ledger with `capacity` bytes.
    pub fn new(capacity: u64) -> Self {
        GpuMem { capacity, used: 0, peak: 0 }
    }

    /// Whether `bytes` more would still fit under the capacity.
    /// Overflow-safe: a request near `u64::MAX` (e.g. a corrupted or
    /// adversarial panel size reaching admission control) reports "does
    /// not fit" instead of wrapping past the capacity check.
    pub fn can_fit(&self, bytes: u64) -> bool {
        self.used.checked_add(bytes).is_some_and(|total| total <= self.capacity)
    }

    /// Allocate `bytes`, failing with [`OomError`] if over capacity.
    pub fn alloc(&mut self, bytes: u64, context: &str) -> Result<(), OomError> {
        if !self.can_fit(bytes) {
            return Err(OomError {
                wanted: bytes,
                used: self.used,
                capacity: self.capacity,
                context: context.to_string(),
            });
        }
        self.used += bytes;
        self.peak = self.peak.max(self.used);
        Ok(())
    }

    /// Free `bytes` (saturating; schedulers free what they allocated).
    pub fn free(&mut self, bytes: u64) {
        self.used = self.used.saturating_sub(bytes);
    }

    /// Unallocated bytes remaining.
    pub fn available(&self) -> u64 {
        self.capacity - self.used
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpu_mem_tracks_peak_and_oom() {
        let mut m = GpuMem::new(100);
        m.alloc(60, "a").unwrap();
        m.alloc(30, "b").unwrap();
        assert_eq!(m.peak, 90);
        assert!(m.alloc(20, "c").is_err());
        m.free(50);
        assert_eq!(m.used, 40);
        m.alloc(20, "c").unwrap();
        assert_eq!(m.peak, 90); // peak unchanged
        assert_eq!(m.available(), 40);
    }

    #[test]
    fn oom_error_carries_context() {
        let mut m = GpuMem::new(10);
        let err = m.alloc(11, "CSR C output").unwrap_err();
        assert!(err.to_string().contains("CSR C output"));
    }

    #[test]
    fn huge_requests_reject_without_overflowing() {
        let mut m = GpuMem::new(u64::MAX);
        m.alloc(16, "resident").unwrap();
        assert!(!m.can_fit(u64::MAX), "used + wanted would wrap past the capacity check");
        let err = m.alloc(u64::MAX, "absurd panel").unwrap_err();
        assert_eq!(err.wanted, u64::MAX);
        assert_eq!(m.used, 16, "the failed allocation charges nothing");
        assert!(m.can_fit(u64::MAX - 16));
    }
}
