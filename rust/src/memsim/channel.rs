//! Machine resources and the calibrated cost model.

/// Hardware resources the simulator serializes on. One op may hold up to
/// two resources (e.g. a GDS transfer occupies the NVMe controller *and*
/// the GPU DMA engine for its duration).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Res {
    /// NVMe controller (shared by host reads and GDS reads/writes).
    Nvme,
    /// PCIe host-to-device DMA engine.
    PcieH2d,
    /// PCIe device-to-host DMA engine.
    PcieD2h,
    /// Host CPU (preprocessing: RoBW partitioning, merging partial rows).
    HostCpu,
    /// GPU compute (SpGEMM kernels).
    Gpu,
    /// GPU DMA engine used by the GDS direct path.
    GpuDma,
}

/// Every resource the simulator tracks, in serialization order.
pub const ALL_RES: [Res; 6] =
    [Res::Nvme, Res::PcieH2d, Res::PcieD2h, Res::HostCpu, Res::Gpu, Res::GpuDma];

/// Transfer / compute op kinds, tagged for the Figure 7/8 breakdowns.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// NVMe -> host memory (classic read into page cache / pinned buffer).
    NvmeToHost,
    /// Host -> NVMe write-back.
    HostToNvme,
    /// NVMe -> GPU direct via GPU Direct Storage (dual-way path, AIRES).
    GdsRead,
    /// GPU -> NVMe direct via GDS.
    GdsWrite,
    /// cudaMemcpy HtoD over PCIe.
    HtoD,
    /// cudaMemcpy DtoH over PCIe.
    DtoH,
    /// CUDA unified-memory fault-driven migration (UCG's read path).
    UmFault,
    /// Host-side memcpy (staging/merging partial segments).
    HostMemcpy,
    /// CPU preprocessing pass (RoBW partitioning scan).
    CpuPartition,
    /// CPU share of the computation (UCG's CPU-GPU split).
    CpuCompute,
    /// GPU SpGEMM kernel.
    GpuKernel,
    /// Device-side allocation (cudaMalloc) — serialized on the GPU.
    GpuMalloc,
}

/// Calibrated bandwidth/latency model of the paper's testbed class
/// (RTX 4090, PCIe 4.0 x16, M.2 NVMe; §V-A). All bandwidths in GB/s
/// (1e9 bytes), latencies in seconds. One struct == one calibration source
/// for every figure (DESIGN.md §Simulator cost model).
#[derive(Debug, Clone)]
pub struct CostModel {
    /// PCIe host-to-device bandwidth.
    pub pcie_h2d_gbps: f64,
    /// PCIe device-to-host bandwidth.
    pub pcie_d2h_gbps: f64,
    /// NVMe sequential read bandwidth (host path).
    pub nvme_read_gbps: f64,
    /// NVMe sequential write bandwidth (host path).
    pub nvme_write_gbps: f64,
    /// Effective GDS NVMe->GPU throughput (bounded by NVMe, minus protocol).
    pub gds_read_gbps: f64,
    /// Effective GDS GPU->NVMe throughput.
    pub gds_write_gbps: f64,
    /// Effective fault-driven UM migration throughput.
    pub um_gbps: f64,
    /// Host-side memcpy bandwidth (staging/merging partial segments).
    pub host_memcpy_gbps: f64,
    /// CPU streaming throughput of the RoBW partitioning pass (calibrated
    /// against the real `partition::robw` implementation — see §Perf).
    pub cpu_partition_gbps: f64,
    /// Effective GPU throughput on sparse-format SpGEMM (far below dense
    /// peak; Nsight-class number for CSR kernels on Ada).
    pub gpu_spgemm_gflops: f64,
    /// Effective memory bandwidth of the sparse kernel's irregular access
    /// pattern (gathers + hash probes): SpGEMM is bandwidth-bound, so the
    /// kernel-time model is max(flop term, bytes/this).
    pub gpu_sparse_bw_gbps: f64,
    /// Effective GPU throughput on dense tiles (the combination matmul).
    pub gpu_dense_gflops: f64,
    /// Effective CPU throughput on the same kernels (UCG's CPU share).
    pub cpu_spgemm_gflops: f64,
    /// Fixed per-op submission latency (driver + DMA setup).
    pub op_latency_s: f64,
    /// Extra per-op latency of a UM fault burst.
    pub um_fault_latency_s: f64,
    /// cudaMalloc cost (the reason static allocators avoid reallocating,
    /// and the price AIRES pays -- once -- for dynamic allocation).
    pub gpu_malloc_s: f64,
    /// Kernel launch overhead.
    pub kernel_launch_s: f64,
    /// Host compute threads driving the parallel row-range kernels
    /// (`runtime::pool`); scales CPU compute via
    /// [`CostModel::host_parallelism`]. Default 1.0 = serial (the
    /// calibration baseline; every figure is unchanged at the default).
    /// The RoBW partition scan has its own gate — see `partition_threads`.
    pub cpu_threads: f64,
    /// Parallel efficiency per extra host thread (memory-bandwidth and
    /// merge overheads keep row-range kernels below linear scaling).
    pub cpu_parallel_eff: f64,
    /// Host threads driving the *parallel RoBW partitioner*
    /// (`partition::robw::robw_partition_par`). Deliberately separate from
    /// `cpu_threads`: the `Op::CpuPartition` scan only speeds up when the
    /// parallel planner is actually selected, which the CLI mirrors here
    /// (serial planner keeps the default 1.0 and the scan stays at
    /// calibration speed even with a sized pool).
    pub partition_threads: f64,
    /// Segment staging depth of the executed Phase II prefetch pipeline
    /// (`runtime::prefetch`), mirrored from `--prefetch-depth`. Drives
    /// [`CostModel::staging_exposure`]; 1.0 = serial staging (neutral
    /// calibration baseline — every figure unchanged at the default).
    pub prefetch_depth: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel {
            pcie_h2d_gbps: 22.0,
            pcie_d2h_gbps: 20.0,
            nvme_read_gbps: 6.6,
            nvme_write_gbps: 5.2,
            gds_read_gbps: 5.8,
            gds_write_gbps: 5.0,
            um_gbps: 7.5,
            host_memcpy_gbps: 18.0,
            cpu_partition_gbps: 8.0,
            gpu_spgemm_gflops: 480.0,
            gpu_sparse_bw_gbps: 16.0,
            gpu_dense_gflops: 35_000.0,
            cpu_spgemm_gflops: 28.0,
            op_latency_s: 18e-6,
            um_fault_latency_s: 35e-6,
            gpu_malloc_s: 110e-6,
            kernel_launch_s: 8e-6,
            cpu_threads: 1.0,
            cpu_parallel_eff: 0.85,
            partition_threads: 1.0,
            prefetch_depth: 1.0,
        }
    }
}

impl CostModel {
    /// Effective host-compute speedup at `cpu_threads` workers: 1 at one
    /// thread; each extra thread contributes `cpu_parallel_eff`. This is
    /// the hook the schedulers' CPU compute costs (`cpu_secs`, the RoBW
    /// partition scan) share with the real `runtime::pool` kernels.
    pub fn host_parallelism(&self) -> f64 {
        1.0 + (self.cpu_threads - 1.0).max(0.0) * self.cpu_parallel_eff
    }

    /// Effective speedup of the RoBW partitioning scan. Scales with
    /// `partition_threads` — set only when the parallel planner
    /// (`robw_partition_par`) is the selected code path — not with the
    /// general `cpu_threads` hook, so a sized pool alone never discounts a
    /// serial planning pass.
    pub fn partition_parallelism(&self) -> f64 {
        1.0 + (self.partition_threads - 1.0).max(0.0) * self.cpu_parallel_eff
    }

    /// Fraction of per-segment staging overhead (cudaMalloc + DMA setup)
    /// left *exposed* on the critical path by the Phase II prefetch
    /// pipeline: staging segment `i+1` through `i+depth-1` proceeds under
    /// segment `i`'s kernel, so only `1/depth` of the submission overhead
    /// serializes with compute. 1.0 at the neutral depth of 1.
    pub fn staging_exposure(&self) -> f64 {
        1.0 / self.prefetch_depth.max(1.0)
    }

    /// Duration of moving `bytes` over the op's channel.
    pub fn transfer_secs(&self, op: Op, bytes: u64) -> f64 {
        let gbps = match op {
            Op::NvmeToHost => self.nvme_read_gbps,
            Op::HostToNvme => self.nvme_write_gbps,
            Op::GdsRead => self.gds_read_gbps,
            Op::GdsWrite => self.gds_write_gbps,
            Op::HtoD => self.pcie_h2d_gbps,
            Op::DtoH => self.pcie_d2h_gbps,
            Op::UmFault => self.um_gbps,
            Op::HostMemcpy => self.host_memcpy_gbps,
            // The RoBW scan only scales when the parallel planner is the
            // selected code path (see `partition_parallelism`).
            Op::CpuPartition => self.cpu_partition_gbps * self.partition_parallelism(),
            _ => panic!("not a transfer op: {op:?}"),
        };
        let lat = match op {
            Op::UmFault => self.um_fault_latency_s,
            _ => self.op_latency_s,
        };
        lat + bytes as f64 / (gbps * 1e9)
    }

    /// Duration of a GPU kernel doing `flops` floating ops over `bytes` of
    /// irregularly accessed operand data (roofline: max of the two terms).
    pub fn gpu_secs(&self, flops: u64, bytes: u64) -> f64 {
        let flop_t = flops as f64 / (self.gpu_spgemm_gflops * 1e9);
        let mem_t = bytes as f64 / (self.gpu_sparse_bw_gbps * 1e9);
        self.kernel_launch_s + flop_t.max(mem_t)
    }

    /// Duration of a dense GPU matmul tile (combination phase).
    pub fn gpu_dense_secs(&self, flops: u64) -> f64 {
        self.kernel_launch_s + flops as f64 / (self.gpu_dense_gflops * 1e9)
    }

    /// Duration of the CPU computing `flops` (scaled by the host-thread
    /// hook — UCG's CPU share and any host-side kernel go through here).
    pub fn cpu_secs(&self, flops: u64) -> f64 {
        flops as f64 / (self.cpu_spgemm_gflops * 1e9 * self.host_parallelism())
    }

    /// Resources an op holds while executing.
    pub fn resources(op: Op) -> (Res, Option<Res>) {
        match op {
            Op::NvmeToHost | Op::HostToNvme => (Res::Nvme, None),
            Op::GdsRead | Op::GdsWrite => (Res::Nvme, Some(Res::GpuDma)),
            Op::HtoD => (Res::PcieH2d, None),
            Op::DtoH => (Res::PcieD2h, None),
            // UM migrations ride PCIe H2D and stall the GPU's fault engine.
            Op::UmFault => (Res::PcieH2d, Some(Res::GpuDma)),
            Op::HostMemcpy | Op::CpuPartition | Op::CpuCompute => (Res::HostCpu, None),
            Op::GpuKernel | Op::GpuMalloc => (Res::Gpu, None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_times_scale_with_bytes() {
        let cm = CostModel::default();
        let t1 = cm.transfer_secs(Op::HtoD, 1 << 30);
        let t2 = cm.transfer_secs(Op::HtoD, 2 << 30);
        assert!(t2 > 1.9 * t1 && t2 < 2.1 * t1);
    }

    #[test]
    fn gds_is_slower_than_pcie_but_skips_host() {
        let cm = CostModel::default();
        // Direct GDS read vs the two-hop NVMe->host->GPU path for 1 GiB.
        let direct = cm.transfer_secs(Op::GdsRead, 1 << 30);
        let two_hop = cm.transfer_secs(Op::NvmeToHost, 1 << 30)
            + cm.transfer_secs(Op::HtoD, 1 << 30);
        // GDS wins when the path is serialized (it is for cold data).
        assert!(direct < two_hop);
    }

    #[test]
    fn host_parallelism_hook_is_neutral_at_default() {
        let cm = CostModel::default();
        assert_eq!(cm.host_parallelism(), 1.0);
        let mut par = CostModel::default();
        par.cpu_threads = 4.0;
        assert!(par.host_parallelism() > 3.0 && par.host_parallelism() < 4.0);
        assert!(par.cpu_secs(1 << 30) < cm.cpu_secs(1 << 30));
        // Non-CPU channels are untouched by the hook.
        assert_eq!(par.transfer_secs(Op::HtoD, 1 << 30), cm.transfer_secs(Op::HtoD, 1 << 30));
        // Degenerate sub-1.0 settings never speed anything up.
        let mut half = CostModel::default();
        half.cpu_threads = 0.5;
        assert_eq!(half.host_parallelism(), 1.0);
    }

    #[test]
    fn partition_scan_scales_only_with_the_parallel_planner() {
        let cm = CostModel::default();
        // A sized pool alone (cpu_threads) must NOT discount the RoBW scan:
        // the serial planner is still the code path.
        let mut pool_only = CostModel::default();
        pool_only.cpu_threads = 8.0;
        assert_eq!(
            pool_only.transfer_secs(Op::CpuPartition, 1 << 30),
            cm.transfer_secs(Op::CpuPartition, 1 << 30),
            "serial planner keeps calibration speed"
        );
        // Selecting the parallel planner (partition_threads) does.
        let mut par = CostModel::default();
        par.partition_threads = 8.0;
        assert!(par.partition_parallelism() > 6.0);
        assert!(
            par.transfer_secs(Op::CpuPartition, 1 << 30)
                < cm.transfer_secs(Op::CpuPartition, 1 << 30)
        );
        // Other channels stay untouched.
        assert_eq!(
            par.transfer_secs(Op::NvmeToHost, 1 << 30),
            cm.transfer_secs(Op::NvmeToHost, 1 << 30)
        );
        // Degenerate settings never speed anything up.
        let mut half = CostModel::default();
        half.partition_threads = 0.25;
        assert_eq!(half.partition_parallelism(), 1.0);
    }

    #[test]
    fn staging_exposure_is_neutral_at_depth_one() {
        let cm = CostModel::default();
        assert_eq!(cm.staging_exposure(), 1.0);
        let mut d2 = CostModel::default();
        d2.prefetch_depth = 2.0;
        assert_eq!(d2.staging_exposure(), 0.5);
        // Degenerate sub-1.0 depths never expose more than the serial path.
        let mut d0 = CostModel::default();
        d0.prefetch_depth = 0.5;
        assert_eq!(d0.staging_exposure(), 1.0);
    }

    #[test]
    fn latency_floor_applies() {
        let cm = CostModel::default();
        assert!(cm.transfer_secs(Op::HtoD, 0) >= cm.op_latency_s);
        assert!(cm.gpu_secs(0, 0) >= cm.kernel_launch_s);
    }
}
