//! Dynamic output-memory model (paper Eqs. 5-7).
//!
//! In sparse-format SpGEMM the output size depends on the row/column
//! matching process and cannot be known exactly beforehand (§III-B). The
//! paper's analytical model estimates it from operand sparsities:
//!
//!   Eq. 5:  M_C = 3 · α_A · (100 − s_A)/100 · (1 + α_B/α_A + (100 − s_B)/100)
//!   Eq. 6:  M_B = α_B + β_B + θ_B
//!   Eq. 7:  p   = (M − M_C − M_B) / 3
//!
//! with α the value-array byte sizes, β/θ the CSC index arrays, s the
//! sparsity percentages, M the total GPU memory. `p` is the byte budget per
//! CSR A array (values / colidx / rowptr) for one RoBW block — maximizing
//! GPU utilization without risking OOM on the dynamically sized output.
//!
//! We also carry a probabilistic estimator (`expected_c_nnz`) used to
//! *validate* Eq. 5 against exact SpGEMM on small instances (tests +
//! EXPERIMENTS.md) — the paper's model is deliberately a cheap upper bound.

/// Operand descriptors for the allocation model.
#[derive(Debug, Clone, Copy)]
pub struct OperandSizes {
    /// α_A: CSR A value-array bytes.
    pub alpha_a: u64,
    /// s_A: CSR A sparsity percent (0..=100).
    pub s_a: f64,
    /// α_B: CSC B value-array bytes.
    pub alpha_b: u64,
    /// β_B: CSC B column-offset array bytes.
    pub beta_b: u64,
    /// θ_B: CSC B row-id array bytes.
    pub theta_b: u64,
    /// s_B: CSC B sparsity percent.
    pub s_b: f64,
}

/// The Eq. 5-7 model.
#[derive(Debug, Clone, Copy)]
pub struct OutputModel {
    /// Operand descriptors the equations read.
    pub sizes: OperandSizes,
}

impl OutputModel {
    /// Model over explicit operand sizes.
    pub fn new(sizes: OperandSizes) -> Self {
        OutputModel { sizes }
    }

    /// Build the model from concrete operands. α is interpreted as the
    /// *dense-equivalent* value-array size (so α·(100−s)/100 is the stored
    /// non-zero payload), which is the reading of Eq. 5 that reproduces
    /// the paper's reservation behaviour; β/θ are the compressed CSC index
    /// arrays as stored.
    pub fn from_matrices(a: &crate::sparse::Csr, b: &crate::sparse::Csc) -> Self {
        OutputModel::new(OperandSizes {
            alpha_a: a.nrows as u64 * a.ncols as u64 * 4,
            s_a: a.sparsity_pct(),
            alpha_b: b.nrows as u64 * b.ncols as u64 * 4,
            beta_b: (b.ncols as u64 + 1) * 8,
            theta_b: b.nnz() as u64 * 4,
            s_b: b.sparsity_pct(),
        })
    }

    /// Eq. 5: estimated GPU bytes for the output CSR C.
    pub fn m_c(&self) -> u64 {
        let s = &self.sizes;
        let da = (100.0 - s.s_a) / 100.0;
        let db = (100.0 - s.s_b) / 100.0;
        let ratio = if s.alpha_a == 0 { 0.0 } else { s.alpha_b as f64 / s.alpha_a as f64 };
        (3.0 * s.alpha_a as f64 * da * (1.0 + ratio + db)).ceil() as u64
    }

    /// Eq. 6: GPU bytes for CSC B (resident for the whole cycle).
    pub fn m_b(&self) -> u64 {
        self.sizes.alpha_b + self.sizes.beta_b + self.sizes.theta_b
    }

    /// Eq. 7: per-array byte budget `p` for one RoBW block of CSR A given
    /// total GPU memory `m`. `None` when B + C alone exceed memory (the
    /// scheduler must then fall back to B panelling).
    pub fn block_budget(&self, m: u64) -> Option<u64> {
        let reserved = self.m_c() + self.m_b();
        if reserved >= m {
            return None;
        }
        Some((m - reserved) / 3)
    }

    /// Minimum feasible GPU memory under this model: B + C + one minimal
    /// block (3 arrays of `min_block` bytes). Drives the Table III OOM rows.
    pub fn min_feasible(&self, min_block: u64) -> u64 {
        self.m_b() + self.m_c() + 3 * min_block
    }
}

/// Probabilistic expected nnz of C = A·B for uniformly sparse operands:
/// P[c_ij != 0] = 1 − (1 − d_A·d_B)^k with k the inner dimension. Exact for
/// independent uniform placement; used to sanity-check Eq. 5's slack.
pub fn expected_c_nnz(m: u64, k: u64, n: u64, d_a: f64, d_b: f64) -> f64 {
    let p_hit = 1.0 - (1.0 - d_a * d_b).powf(k as f64);
    m as f64 * n as f64 * p_hit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::spgemm::spgemm_csr_csc;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn sizes(alpha_a: u64, s_a: f64, alpha_b: u64, s_b: f64) -> OperandSizes {
        OperandSizes { alpha_a, s_a, alpha_b, beta_b: alpha_b / 4, theta_b: alpha_b, s_b }
    }

    #[test]
    fn eq5_shrinks_with_sparsity() {
        let dense = OutputModel::new(sizes(1 << 20, 50.0, 1 << 20, 50.0));
        let sparse = OutputModel::new(sizes(1 << 20, 99.0, 1 << 20, 99.0));
        assert!(sparse.m_c() < dense.m_c());
    }

    #[test]
    fn eq7_budget_decreases_with_memory() {
        let m = OutputModel::new(sizes(1 << 24, 99.0, 1 << 24, 99.0));
        let hi = m.block_budget(8 << 30).unwrap();
        let lo = m.block_budget(1 << 30).unwrap();
        assert!(hi > lo);
    }

    #[test]
    fn eq7_none_when_b_and_c_dont_fit() {
        let m = OutputModel::new(sizes(1 << 30, 0.0, 1 << 30, 0.0));
        assert!(m.block_budget(1 << 20).is_none());
    }

    #[test]
    fn eq5_tracks_real_output_within_factor() {
        // Eq. 5 is the paper's *approximation* of the dynamically sized
        // output; it need not be a strict bound (AIRES grows the
        // allocation when the estimate falls short — that's the "dynamic"
        // in dynamic scheduling). Assert it stays within a small constant
        // factor of exact SpGEMM output bytes on uniform operands.
        let mut rng = Pcg::seed(90);
        for &(n, d) in &[(64usize, 0.05f64), (96, 0.02), (48, 0.10)] {
            let mut coo_a = Coo::new(n, n);
            let mut coo_b = Coo::new(n, n);
            for r in 0..n {
                for c in 0..n {
                    if rng.chance(d) {
                        coo_a.push(r as u32, c as u32, 1.0);
                    }
                    if rng.chance(d) {
                        coo_b.push(r as u32, c as u32, 1.0);
                    }
                }
            }
            let a = coo_a.to_csr();
            let b = coo_b.to_csr();
            let model = OutputModel::from_matrices(&a, &b.to_csc());
            let prod = spgemm_csr_csc(&a, &b.to_csc());
            let real_c_bytes = prod.c.nnz() as u64 * 8 + (n as u64 + 1) * 8;
            let ratio = model.m_c() as f64 / real_c_bytes as f64;
            assert!(
                (0.25..8.0).contains(&ratio),
                "n={n} d={d}: model {} vs real {real_c_bytes} (ratio {ratio})",
                model.m_c()
            );
        }
    }

    #[test]
    fn expected_nnz_tracks_reality() {
        let mut rng = Pcg::seed(91);
        let (n, d) = (128usize, 0.04f64);
        let mut coo_a = Coo::new(n, n);
        let mut coo_b = Coo::new(n, n);
        for r in 0..n {
            for c in 0..n {
                if rng.chance(d) {
                    coo_a.push(r as u32, c as u32, 1.0);
                }
                if rng.chance(d) {
                    coo_b.push(r as u32, c as u32, 1.0);
                }
            }
        }
        let a = coo_a.to_csr();
        let b = coo_b.to_csr();
        let d_a = a.nnz() as f64 / (n * n) as f64;
        let d_b = b.nnz() as f64 / (n * n) as f64;
        let expect = expected_c_nnz(n as u64, n as u64, n as u64, d_a, d_b);
        let real = spgemm_csr_csc(&a, &b.to_csc()).matches as f64;
        let rel = (expect - real).abs() / real;
        assert!(rel < 0.25, "expected {expect}, real {real}");
    }

    #[test]
    fn min_feasible_monotone_in_block() {
        let m = OutputModel::new(sizes(1 << 20, 99.0, 1 << 20, 99.0));
        assert!(m.min_feasible(1 << 20) > m.min_feasible(1 << 10));
    }
}
