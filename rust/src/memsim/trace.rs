//! Chrome-trace export of a simulated schedule: every op in the `Sim` log
//! becomes a duration event on its resource's track, so a run opens in
//! `chrome://tracing` / Perfetto for visual inspection of the overlap
//! structure (Phase II pipelining, dual-way concurrency, merge stalls).

use super::channel::{CostModel, Res};
use super::sim::{OpRecord, Sim};
use crate::util::json::Json;
use std::collections::BTreeMap;

fn res_name(r: Res) -> &'static str {
    match r {
        Res::Nvme => "NVMe",
        Res::PcieH2d => "PCIe H2D",
        Res::PcieD2h => "PCIe D2H",
        Res::HostCpu => "Host CPU",
        Res::Gpu => "GPU",
        Res::GpuDma => "GPU DMA",
    }
}

/// Render the op log as a Chrome Trace Event JSON document.
/// Times are exported in microseconds (the trace format's unit).
pub fn chrome_trace(sim: &Sim) -> String {
    chrome_trace_log(&sim.log)
}

/// Trace from a raw op log (e.g. `EpochResult::log`).
pub fn chrome_trace_log(log: &[OpRecord]) -> String {
    let mut events = Vec::new();
    for rec in log {
        let (r1, r2) = CostModel::resources(rec.op);
        for (idx, res) in [Some(r1), r2].into_iter().flatten().enumerate() {
            let mut obj = BTreeMap::new();
            obj.insert("name".into(), Json::Str(rec.tag.to_string()));
            obj.insert("cat".into(), Json::Str(format!("{:?}", rec.op)));
            obj.insert("ph".into(), Json::Str("X".into()));
            obj.insert("ts".into(), Json::Num(rec.start * 1e6));
            obj.insert("dur".into(), Json::Num((rec.end - rec.start) * 1e6));
            obj.insert("pid".into(), Json::Num(1.0));
            obj.insert("tid".into(), Json::Str(res_name(res).into()));
            let mut args = BTreeMap::new();
            args.insert("bytes".into(), Json::Num(rec.bytes as f64));
            if idx > 0 {
                args.insert("shared_resource".into(), Json::Bool(true));
            }
            obj.insert("args".into(), Json::Obj(args));
            events.push(Json::Obj(obj));
        }
    }
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    Json::Obj(root).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::Op;
    use crate::util::json::parse;

    #[test]
    fn trace_is_valid_json_with_all_ops() {
        let cm = CostModel::default();
        let mut sim = Sim::new();
        sim.transfer(&cm, Op::GdsRead, 1 << 20, 0.0, "B load");
        sim.transfer(&cm, Op::HtoD, 1 << 20, 0.0, "seg");
        sim.gpu_kernel(&cm, 1000, 1 << 20, 0.0, "spgemm");
        let trace = chrome_trace(&sim);
        let parsed = parse(&trace).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // GdsRead holds two resources -> two events; others one each.
        assert_eq!(events.len(), 4);
        for e in events {
            assert!(e.get("ts").is_some() && e.get("dur").is_some());
        }
    }

    #[test]
    fn aires_schedule_exports() {
        use crate::sched::{Scheduler, Workload};
        let cm = CostModel::default();
        let d = crate::graphgen::catalog::by_name("kU1a").unwrap();
        let w = Workload::from_catalog(d, 256, 1);
        // Re-run the scheduler with a captured sim by reusing run_epoch's
        // public output: just verify trace generation over a fresh sim.
        let _ = crate::sched::Aires.run_epoch(&w, &cm);
        let mut sim = Sim::new();
        sim.transfer(&cm, Op::GdsRead, w.b_bytes(), 0.0, "B load (GDS)");
        let trace = chrome_trace(&sim);
        assert!(trace.contains("B load (GDS)"));
        assert!(trace.contains("NVMe"));
    }
}
