//! Minimal benchmarking harness (criterion is unavailable in the offline
//! crate cache). Used by every `rust/benches/*` target: warmup, N timed
//! iterations, mean / stddev / min reporting, and a `BENCH` prefixed line
//! per result so `cargo bench | grep BENCH` yields a machine-readable log.
//!
//! Also hosts [`CountingAlloc`], a global-allocator shim that counts heap
//! allocations: the `micro_hotpath` bench and `rust/tests/alloc_free.rs`
//! install it to measure (and assert) the allocation traffic of the
//! recycled vs fresh staging paths.

use crate::util::Stopwatch;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Heap allocations observed by [`CountingAlloc`] since process start
/// (alloc + realloc calls; deallocations are not counted).
static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

/// Counting wrapper over the system allocator. Install it in a test or
/// bench binary with
/// `#[global_allocator] static A: aires::benchlib::CountingAlloc = aires::benchlib::CountingAlloc;`
/// and read the running total via [`allocation_count`]. The counter is a
/// single relaxed atomic — cheap enough to leave on for a whole bench run.
pub struct CountingAlloc;

// SAFETY: defers entirely to `System`; the only addition is a relaxed
// counter increment, which allocates nothing and cannot unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total heap allocations counted so far. Returns 0 forever unless the
/// binary installed [`CountingAlloc`] as its `#[global_allocator]`.
pub fn allocation_count() -> u64 {
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case label as printed in the `BENCH` log line.
    pub name: String,
    /// Timed iterations (excluding warmup).
    pub iters: usize,
    /// Mean wall-clock seconds per iteration.
    pub mean_s: f64,
    /// Sample standard deviation of the iteration times.
    pub stddev_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
    };
    println!(
        "BENCH {name}: mean {} ± {} (min {}, n={iters})",
        crate::util::human_secs(result.mean_s),
        crate::util::human_secs(result.stddev_s),
        crate::util::human_secs(result.min_s),
    );
    result
}

/// Machine-readable form of one bench case: `mean_s` and `min_s` plus
/// any derived metrics (`ns_per_segment`, ...), as a JSON object whose
/// sorted-key emission feeds the perf-trajectory artifact
/// (`BENCH_streaming.json` → `bench ingest`).
pub fn result_json(r: &BenchResult, extra: &[(&str, f64)]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("mean_s".to_string(), Json::Num(r.mean_s));
    obj.insert("min_s".to_string(), Json::Num(r.min_s));
    for (k, v) in extra {
        obj.insert((*k).to_string(), Json::Num(*v));
    }
    Json::Obj(obj)
}

/// Throughput helper: report bytes/s over the measured mean.
pub fn report_throughput(r: &BenchResult, bytes: u64) {
    let gbps = bytes as f64 / r.mean_s / 1e9;
    println!("BENCH {}: throughput {:.2} GB/s", r.name, gbps);
}

/// Speedup helper: report `base` mean over `new` mean (serial vs parallel).
pub fn report_speedup(base: &BenchResult, new: &BenchResult) {
    println!(
        "BENCH {}: {:.2}x speedup over {} (mean {} vs {})",
        new.name,
        base.mean_s / new.mean_s,
        base.name,
        crate::util::human_secs(new.mean_s),
        crate::util::human_secs(base.mean_s),
    );
}

/// Pool for benches, sized by `AIRES_THREADS` (0 = one per hardware
/// thread; unset = auto). Lets every bench run serial vs parallel without
/// recompiling: `AIRES_THREADS=1 cargo bench ...`.
pub fn pool_from_env() -> crate::runtime::pool::Pool {
    let threads = std::env::var("AIRES_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(0);
    crate::runtime::pool::Pool::new(threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn result_json_carries_extras() {
        let r = BenchResult {
            name: "case".into(),
            iters: 3,
            mean_s: 0.25,
            stddev_s: 0.0,
            min_s: 0.125,
        };
        let j = result_json(&r, &[("ns_per_segment", 1234.5)]).to_string();
        assert_eq!(j, r#"{"mean_s":0.25,"min_s":0.125,"ns_per_segment":1234.5}"#);
    }

    #[test]
    fn env_pool_is_usable() {
        let pool = pool_from_env();
        assert!(pool.threads() >= 1);
        assert_eq!(pool.map_tasks(4, |i| i), vec![0, 1, 2, 3]);
    }
}
