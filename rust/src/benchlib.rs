//! Minimal benchmarking harness (criterion is unavailable in the offline
//! crate cache). Used by every `rust/benches/*` target: warmup, N timed
//! iterations, mean / stddev / min reporting, and a `BENCH` prefixed line
//! per result so `cargo bench | grep BENCH` yields a machine-readable log.

use crate::util::Stopwatch;

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Case label as printed in the `BENCH` log line.
    pub name: String,
    /// Timed iterations (excluding warmup).
    pub iters: usize,
    /// Mean wall-clock seconds per iteration.
    pub mean_s: f64,
    /// Sample standard deviation of the iteration times.
    pub stddev_s: f64,
    /// Fastest iteration.
    pub min_s: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
        / samples.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        stddev_s: var.sqrt(),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
    };
    println!(
        "BENCH {name}: mean {} ± {} (min {}, n={iters})",
        crate::util::human_secs(result.mean_s),
        crate::util::human_secs(result.stddev_s),
        crate::util::human_secs(result.min_s),
    );
    result
}

/// Throughput helper: report bytes/s over the measured mean.
pub fn report_throughput(r: &BenchResult, bytes: u64) {
    let gbps = bytes as f64 / r.mean_s / 1e9;
    println!("BENCH {}: throughput {:.2} GB/s", r.name, gbps);
}

/// Speedup helper: report `base` mean over `new` mean (serial vs parallel).
pub fn report_speedup(base: &BenchResult, new: &BenchResult) {
    println!(
        "BENCH {}: {:.2}x speedup over {} (mean {} vs {})",
        new.name,
        base.mean_s / new.mean_s,
        base.name,
        crate::util::human_secs(new.mean_s),
        crate::util::human_secs(base.mean_s),
    );
}

/// Pool for benches, sized by `AIRES_THREADS` (0 = one per hardware
/// thread; unset = auto). Lets every bench run serial vs parallel without
/// recompiling: `AIRES_THREADS=1 cargo bench ...`.
pub fn pool_from_env() -> crate::runtime::pool::Pool {
    let threads = std::env::var("AIRES_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(0);
    crate::runtime::pool::Pool::new(threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn env_pool_is_usable() {
        let pool = pool_from_env();
        assert!(pool.threads() >= 1);
        assert_eq!(pool.map_tasks(4, |i| i), vec![0, 1, 2, 3]);
    }
}
