//! Config system: JSON overrides for the cost model and experiment
//! parameters (serde/toml unavailable offline — uses `util::json`).
//!
//! One file configures a whole evaluation run:
//!
//! ```json
//! {
//!   "cost_model": { "nvme_read_gbps": 12.0, "gds_read_gbps": 10.5 },
//!   "feat_dim": 128,
//!   "layers": 2,
//!   "datasets": ["kP1a", "kV1r"]
//! }
//! ```
//!
//! Every CLI subcommand accepts `--config <file>`; unknown cost-model keys
//! are rejected (typos should fail loudly, not silently keep defaults).

use crate::memsim::CostModel;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, bail, Result};

/// A full experiment configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Calibrated simulator cost model with any overrides applied.
    pub cost_model: CostModel,
    /// GCN feature width (paper default 256).
    pub feat_dim: u64,
    /// GCN layers per epoch.
    pub layers: u32,
    /// Catalog dataset names to evaluate (empty = all).
    pub datasets: Vec<String>,
    /// Worker threads for the `runtime::pool` parallel kernels
    /// (1 = serial, 0 = one per hardware thread). The CLI's `--threads`
    /// flag overrides this.
    pub threads: usize,
    /// Segment staging depth for the executed `runtime::prefetch` pipeline
    /// (1 = serial staging, 2 = double buffering). Output is byte-identical
    /// at every depth; only overlap changes. `None` = unset: execution uses
    /// the double-buffering default of 2 and the simulator hook stays at
    /// its depth-1 calibration baseline. When set (here or via the CLI's
    /// `--prefetch-depth`, which wins), the CLI mirrors the depth into
    /// `cost_model.prefetch_depth` so modelled Phase II overhead moves
    /// with the executed pipeline.
    pub prefetch_depth: Option<usize>,
    /// Directory disk-backed staging spills RoBW segments to and serves
    /// them back from (`runtime::segstore`). `None` = in-memory staging
    /// (the default). The CLI's `--segment-dir` overrides this.
    pub segment_dir: Option<String>,
    /// Byte bound of the host-RAM cache tier between the segment files
    /// and the `GpuMem` ledger: `0` disables the tier (every staged read
    /// hits disk); `None` = unbounded. Only meaningful with disk-backed
    /// staging. The CLI's `--host-cache-bytes` overrides this.
    pub host_cache_bytes: Option<u64>,
    /// Retention cap of the staging buffer-recycle pool
    /// (`runtime::recycle`): `0` disables recycling (every staged segment
    /// allocates fresh scratch — the pre-recycling behaviour); `None` =
    /// recycle with the default cap. Output is byte-identical either way;
    /// only allocator traffic changes. The CLI's `--recycle-cap-bytes`
    /// overrides this.
    pub recycle_cap_bytes: Option<u64>,
    /// Directory the multi-layer pipeline spills intermediate feature
    /// panels to (`runtime::segstore::PanelStore`, the `gcnstream`
    /// subcommand). `None` = intermediate panels stay resident in host
    /// RAM (the default). Output is byte-identical either way. The CLI's
    /// `--panel-dir` overrides this.
    pub panel_dir: Option<String>,
    /// Concurrent tenant queries the `serve` subcommand batches onto one
    /// staged pass of the adjacency (`gcn::serve`). `None` = unset: the
    /// CLI uses its own default of 4. The CLI's `--tenants` flag
    /// overrides this.
    pub tenants: Option<usize>,
    /// Path of the perf-trajectory JSONL store the `bench` subcommand
    /// family reads and appends (`benchdb`). `None` = unset: `bench`
    /// then requires the `--db` flag. The CLI's `--db` overrides this.
    pub bench_db: Option<String>,
    /// Route the `train` subcommand through the streamed out-of-core
    /// trainer (`gcn::train_stream`) instead of the dense PJRT artifact.
    /// `None` = unset (artifact path). The CLI's `--train-stream` flag
    /// also enables it.
    pub train_stream: Option<bool>,
    /// Recompute-vs-reload policy for the streamed trainer's aggregated
    /// inputs: `"reload"`, `"recompute"`, or `"auto"`. `None` = unset
    /// (the CLI defaults to `auto`). The CLI's `--recompute-policy`
    /// overrides this.
    pub recompute_policy: Option<String>,
    /// Transient-fault retries per tiered-store read (`runtime::heal`):
    /// `0` = fail fast (the pre-healing behaviour). Enabling retries also
    /// enables quarantine-and-rebuild of persistently corrupt segments.
    /// `None` = unset (fail fast). The CLI's `--retry-max` overrides this.
    pub retry_max: Option<usize>,
    /// Virtual-time backoff charge per retry, in multiples of the failed
    /// file's size (doubling per attempt, charged to the heal ledger —
    /// never a wall-clock sleep). `None` = unset (no backoff charge). The
    /// CLI's `--retry-backoff-ios` overrides this.
    pub retry_backoff_ios: Option<u64>,
    /// Directory the streamed trainer persists per-step checkpoints to
    /// and resumes from (`gcn::checkpoint`). `None` = no checkpointing.
    /// The CLI's `--checkpoint-dir` overrides this.
    pub checkpoint_dir: Option<String>,
    /// Zero-copy mapped staging reads (`runtime::segstore` through the
    /// vendored mmap shim): `true` maps spilled segment and panel files
    /// into the address space instead of copying them through read
    /// buffers. Served bytes are identical either way; only copy traffic
    /// changes. `None` = unset (copying reads). The CLI's `--mmap` flag
    /// also enables it.
    pub mmap_segments: Option<bool>,
    /// On-disk encoding for spilled RoBW segments (`sparse::segio`):
    /// `"raw"`, `"packed"` (delta + bit-packed column indices), or
    /// `"auto"` (per segment, smaller file wins). Staged output is
    /// byte-identical at every encoding. `None` = unset (the CLI
    /// defaults to `raw`). The CLI's `--seg-encoding` overrides this.
    pub seg_encoding: Option<String>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cost_model: CostModel::default(),
            feat_dim: crate::coordinator::FEAT_DIM,
            layers: crate::coordinator::LAYERS,
            datasets: Vec::new(),
            threads: 1,
            prefetch_depth: None,
            segment_dir: None,
            host_cache_bytes: None,
            recycle_cap_bytes: None,
            panel_dir: None,
            tenants: None,
            bench_db: None,
            train_stream: None,
            recompute_policy: None,
            retry_max: None,
            retry_backoff_ios: None,
            checkpoint_dir: None,
            mmap_segments: None,
            seg_encoding: None,
        }
    }
}

/// Apply one cost-model override by field name.
fn set_cm_field(cm: &mut CostModel, key: &str, v: f64) -> Result<()> {
    match key {
        "pcie_h2d_gbps" => cm.pcie_h2d_gbps = v,
        "pcie_d2h_gbps" => cm.pcie_d2h_gbps = v,
        "nvme_read_gbps" => cm.nvme_read_gbps = v,
        "nvme_write_gbps" => cm.nvme_write_gbps = v,
        "gds_read_gbps" => cm.gds_read_gbps = v,
        "gds_write_gbps" => cm.gds_write_gbps = v,
        "um_gbps" => cm.um_gbps = v,
        "host_memcpy_gbps" => cm.host_memcpy_gbps = v,
        "cpu_partition_gbps" => cm.cpu_partition_gbps = v,
        "gpu_spgemm_gflops" => cm.gpu_spgemm_gflops = v,
        "gpu_sparse_bw_gbps" => cm.gpu_sparse_bw_gbps = v,
        "gpu_dense_gflops" => cm.gpu_dense_gflops = v,
        "cpu_spgemm_gflops" => cm.cpu_spgemm_gflops = v,
        "op_latency_s" => cm.op_latency_s = v,
        "um_fault_latency_s" => cm.um_fault_latency_s = v,
        "gpu_malloc_s" => cm.gpu_malloc_s = v,
        "kernel_launch_s" => cm.kernel_launch_s = v,
        "cpu_threads" => cm.cpu_threads = v,
        "cpu_parallel_eff" => cm.cpu_parallel_eff = v,
        "partition_threads" => cm.partition_threads = v,
        "prefetch_depth" => cm.prefetch_depth = v,
        other => bail!("unknown cost_model field {other:?}"),
    }
    Ok(())
}

impl Config {
    /// Parse a config document (strict: unknown keys are errors).
    pub fn from_json_str(text: &str) -> Result<Config> {
        let root = parse(text).map_err(|e| anyhow!("config parse: {e}"))?;
        let obj = root.as_obj().ok_or_else(|| anyhow!("config root must be an object"))?;
        let mut cfg = Config::default();
        for (key, val) in obj {
            match key.as_str() {
                "cost_model" => {
                    let cm_obj =
                        val.as_obj().ok_or_else(|| anyhow!("cost_model must be an object"))?;
                    for (k, v) in cm_obj {
                        let n = v
                            .as_f64()
                            .ok_or_else(|| anyhow!("cost_model.{k} must be a number"))?;
                        if n <= 0.0 {
                            bail!("cost_model.{k} must be positive");
                        }
                        set_cm_field(&mut cfg.cost_model, k, n)?;
                    }
                }
                "feat_dim" => {
                    cfg.feat_dim =
                        val.as_f64().ok_or_else(|| anyhow!("feat_dim must be a number"))? as u64;
                    if cfg.feat_dim == 0 {
                        bail!("feat_dim must be positive");
                    }
                }
                "layers" => {
                    cfg.layers =
                        val.as_f64().ok_or_else(|| anyhow!("layers must be a number"))? as u32;
                    if cfg.layers == 0 {
                        bail!("layers must be positive");
                    }
                }
                "threads" => {
                    let n =
                        val.as_f64().ok_or_else(|| anyhow!("threads must be a number"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        bail!("threads must be a non-negative integer (0 = auto)");
                    }
                    cfg.threads = n as usize;
                }
                "prefetch_depth" => {
                    let n = val
                        .as_f64()
                        .ok_or_else(|| anyhow!("prefetch_depth must be a number"))?;
                    if n < 1.0 || n.fract() != 0.0 {
                        bail!("prefetch_depth must be a positive integer (1 = serial)");
                    }
                    cfg.prefetch_depth = Some(n as usize);
                }
                "segment_dir" => {
                    let dir = val
                        .as_str()
                        .ok_or_else(|| anyhow!("segment_dir must be a string"))?;
                    if dir.is_empty() {
                        bail!("segment_dir must not be empty (omit the key for in-memory staging)");
                    }
                    cfg.segment_dir = Some(dir.to_string());
                }
                "panel_dir" => {
                    let dir =
                        val.as_str().ok_or_else(|| anyhow!("panel_dir must be a string"))?;
                    if dir.is_empty() {
                        bail!("panel_dir must not be empty (omit the key to keep panels in RAM)");
                    }
                    cfg.panel_dir = Some(dir.to_string());
                }
                "host_cache_bytes" => {
                    let n = val
                        .as_f64()
                        .ok_or_else(|| anyhow!("host_cache_bytes must be a number"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        bail!("host_cache_bytes must be a non-negative integer (0 = no cache)");
                    }
                    cfg.host_cache_bytes = Some(n as u64);
                }
                "recycle_cap_bytes" => {
                    let n = val
                        .as_f64()
                        .ok_or_else(|| anyhow!("recycle_cap_bytes must be a number"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        bail!(
                            "recycle_cap_bytes must be a non-negative integer \
                             (0 = no buffer recycling)"
                        );
                    }
                    cfg.recycle_cap_bytes = Some(n as u64);
                }
                "tenants" => {
                    let n =
                        val.as_f64().ok_or_else(|| anyhow!("tenants must be a number"))?;
                    if n < 1.0 || n.fract() != 0.0 {
                        bail!("tenants must be a positive integer");
                    }
                    cfg.tenants = Some(n as usize);
                }
                "bench_db" => {
                    let path =
                        val.as_str().ok_or_else(|| anyhow!("bench_db must be a string"))?;
                    if path.is_empty() {
                        bail!("bench_db must not be empty (omit the key and pass --db instead)");
                    }
                    cfg.bench_db = Some(path.to_string());
                }
                "train_stream" => {
                    cfg.train_stream = Some(
                        val.as_bool()
                            .ok_or_else(|| anyhow!("train_stream must be a boolean"))?,
                    );
                }
                "recompute_policy" => {
                    let s = val
                        .as_str()
                        .ok_or_else(|| anyhow!("recompute_policy must be a string"))?;
                    // Validate eagerly so typos fail at config-load time, not
                    // mid-training.
                    s.parse::<crate::gcn::RecomputePolicy>()
                        .map_err(|e| anyhow!("recompute_policy: {e}"))?;
                    cfg.recompute_policy = Some(s.to_string());
                }
                "retry_max" => {
                    let n =
                        val.as_f64().ok_or_else(|| anyhow!("retry_max must be a number"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        bail!("retry_max must be a non-negative integer (0 = fail fast)");
                    }
                    cfg.retry_max = Some(n as usize);
                }
                "retry_backoff_ios" => {
                    let n = val
                        .as_f64()
                        .ok_or_else(|| anyhow!("retry_backoff_ios must be a number"))?;
                    if n < 0.0 || n.fract() != 0.0 {
                        bail!("retry_backoff_ios must be a non-negative integer (0 = no charge)");
                    }
                    cfg.retry_backoff_ios = Some(n as u64);
                }
                "checkpoint_dir" => {
                    let dir = val
                        .as_str()
                        .ok_or_else(|| anyhow!("checkpoint_dir must be a string"))?;
                    if dir.is_empty() {
                        bail!("checkpoint_dir must not be empty (omit the key to disable)");
                    }
                    cfg.checkpoint_dir = Some(dir.to_string());
                }
                "mmap_segments" => {
                    cfg.mmap_segments = Some(
                        val.as_bool()
                            .ok_or_else(|| anyhow!("mmap_segments must be a boolean"))?,
                    );
                }
                "seg_encoding" => {
                    let s = val
                        .as_str()
                        .ok_or_else(|| anyhow!("seg_encoding must be a string"))?;
                    // Validate eagerly so typos fail at config-load time,
                    // not mid-spill.
                    s.parse::<crate::sparse::segio::SegEncoding>()
                        .map_err(|e| anyhow!("seg_encoding: {e}"))?;
                    cfg.seg_encoding = Some(s.to_string());
                }
                "datasets" => {
                    let arr =
                        val.as_arr().ok_or_else(|| anyhow!("datasets must be an array"))?;
                    for d in arr {
                        let name =
                            d.as_str().ok_or_else(|| anyhow!("dataset names are strings"))?;
                        if crate::graphgen::catalog::by_name(name).is_none() {
                            bail!("unknown dataset {name:?} (see `aires catalog`)");
                        }
                        cfg.datasets.push(name.to_string());
                    }
                }
                other => bail!("unknown config key {other:?}"),
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config {path}: {e}"))?;
        Self::from_json_str(&text)
    }

    /// Staging depth for the executed pipeline: the config's key when set,
    /// else the double-buffering default of 2 (floored at 1).
    pub fn resolved_prefetch_depth(&self) -> usize {
        self.prefetch_depth.unwrap_or(2).max(1)
    }

    /// The catalog entries this config selects.
    pub fn selected_datasets(&self) -> Vec<&'static crate::graphgen::DatasetStats> {
        if self.datasets.is_empty() {
            crate::graphgen::CATALOG.iter().collect()
        } else {
            self.datasets
                .iter()
                .filter_map(|n| crate::graphgen::catalog::by_name(n))
                .collect()
        }
    }

    /// Serialize back to JSON (for `aires config-dump`).
    pub fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let cm = &self.cost_model;
        let mut cm_map = BTreeMap::new();
        for (k, v) in [
            ("pcie_h2d_gbps", cm.pcie_h2d_gbps),
            ("pcie_d2h_gbps", cm.pcie_d2h_gbps),
            ("nvme_read_gbps", cm.nvme_read_gbps),
            ("nvme_write_gbps", cm.nvme_write_gbps),
            ("gds_read_gbps", cm.gds_read_gbps),
            ("gds_write_gbps", cm.gds_write_gbps),
            ("um_gbps", cm.um_gbps),
            ("host_memcpy_gbps", cm.host_memcpy_gbps),
            ("cpu_partition_gbps", cm.cpu_partition_gbps),
            ("gpu_spgemm_gflops", cm.gpu_spgemm_gflops),
            ("gpu_sparse_bw_gbps", cm.gpu_sparse_bw_gbps),
            ("gpu_dense_gflops", cm.gpu_dense_gflops),
            ("cpu_spgemm_gflops", cm.cpu_spgemm_gflops),
            ("op_latency_s", cm.op_latency_s),
            ("um_fault_latency_s", cm.um_fault_latency_s),
            ("gpu_malloc_s", cm.gpu_malloc_s),
            ("kernel_launch_s", cm.kernel_launch_s),
            ("cpu_threads", cm.cpu_threads),
            ("cpu_parallel_eff", cm.cpu_parallel_eff),
            ("partition_threads", cm.partition_threads),
            ("prefetch_depth", cm.prefetch_depth),
        ] {
            cm_map.insert(k.to_string(), Json::Num(v));
        }
        let mut root = BTreeMap::new();
        root.insert("cost_model".to_string(), Json::Obj(cm_map));
        root.insert("feat_dim".to_string(), Json::Num(self.feat_dim as f64));
        root.insert("layers".to_string(), Json::Num(self.layers as f64));
        root.insert("threads".to_string(), Json::Num(self.threads as f64));
        if let Some(d) = self.prefetch_depth {
            root.insert("prefetch_depth".to_string(), Json::Num(d as f64));
        }
        if let Some(dir) = &self.segment_dir {
            root.insert("segment_dir".to_string(), Json::Str(dir.clone()));
        }
        if let Some(b) = self.host_cache_bytes {
            root.insert("host_cache_bytes".to_string(), Json::Num(b as f64));
        }
        if let Some(b) = self.recycle_cap_bytes {
            root.insert("recycle_cap_bytes".to_string(), Json::Num(b as f64));
        }
        if let Some(dir) = &self.panel_dir {
            root.insert("panel_dir".to_string(), Json::Str(dir.clone()));
        }
        if let Some(t) = self.tenants {
            root.insert("tenants".to_string(), Json::Num(t as f64));
        }
        if let Some(path) = &self.bench_db {
            root.insert("bench_db".to_string(), Json::Str(path.clone()));
        }
        if let Some(b) = self.train_stream {
            root.insert("train_stream".to_string(), Json::Bool(b));
        }
        if let Some(p) = &self.recompute_policy {
            root.insert("recompute_policy".to_string(), Json::Str(p.clone()));
        }
        if let Some(n) = self.retry_max {
            root.insert("retry_max".to_string(), Json::Num(n as f64));
        }
        if let Some(n) = self.retry_backoff_ios {
            root.insert("retry_backoff_ios".to_string(), Json::Num(n as f64));
        }
        if let Some(dir) = &self.checkpoint_dir {
            root.insert("checkpoint_dir".to_string(), Json::Str(dir.clone()));
        }
        if let Some(b) = self.mmap_segments {
            root.insert("mmap_segments".to_string(), Json::Bool(b));
        }
        if let Some(e) = &self.seg_encoding {
            root.insert("seg_encoding".to_string(), Json::Str(e.clone()));
        }
        root.insert(
            "datasets".to_string(),
            Json::Arr(self.datasets.iter().map(|d| Json::Str(d.clone())).collect()),
        );
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_roundtrip() {
        let cfg = Config::default();
        let text = cfg.to_json().to_string();
        let back = Config::from_json_str(&text).unwrap();
        assert_eq!(back.feat_dim, cfg.feat_dim);
        assert_eq!(back.cost_model.nvme_read_gbps, cfg.cost_model.nvme_read_gbps);
    }

    #[test]
    fn overrides_apply() {
        let cfg = Config::from_json_str(
            r#"{"cost_model":{"gds_read_gbps":10.5},"feat_dim":128,"datasets":["kP1a"]}"#,
        )
        .unwrap();
        assert_eq!(cfg.cost_model.gds_read_gbps, 10.5);
        assert_eq!(cfg.feat_dim, 128);
        assert_eq!(cfg.selected_datasets().len(), 1);
        // Untouched fields keep defaults.
        assert_eq!(cfg.cost_model.pcie_h2d_gbps, CostModel::default().pcie_h2d_gbps);
    }

    #[test]
    fn rejects_unknown_keys_and_bad_values() {
        assert!(Config::from_json_str(r#"{"cost_model":{"gsd_read_gbps":1}}"#).is_err());
        assert!(Config::from_json_str(r#"{"typo_key":1}"#).is_err());
        assert!(Config::from_json_str(r#"{"cost_model":{"um_gbps":-1}}"#).is_err());
        assert!(Config::from_json_str(r#"{"datasets":["nope"]}"#).is_err());
        assert!(Config::from_json_str(r#"{"feat_dim":0}"#).is_err());
    }

    #[test]
    fn threads_key_roundtrips_and_validates() {
        let cfg = Config::from_json_str(r#"{"threads":4}"#).unwrap();
        assert_eq!(cfg.threads, 4);
        let auto = Config::from_json_str(r#"{"threads":0}"#).unwrap();
        assert_eq!(auto.threads, 0);
        assert!(Config::from_json_str(r#"{"threads":-1}"#).is_err());
        assert!(Config::from_json_str(r#"{"threads":2.5}"#).is_err());
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.threads, 4);
    }

    #[test]
    fn prefetch_depth_key_roundtrips_and_validates() {
        let cfg = Config::from_json_str(r#"{"prefetch_depth":4}"#).unwrap();
        assert_eq!(cfg.prefetch_depth, Some(4));
        assert_eq!(cfg.resolved_prefetch_depth(), 4);
        let unset = Config::default();
        assert_eq!(unset.prefetch_depth, None);
        assert_eq!(unset.resolved_prefetch_depth(), 2, "double buffering by default");
        assert!(Config::from_json_str(r#"{"prefetch_depth":0}"#).is_err());
        assert!(Config::from_json_str(r#"{"prefetch_depth":1.5}"#).is_err());
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.prefetch_depth, Some(4), "set key survives the roundtrip");
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.prefetch_depth, None, "unset stays unset through the roundtrip");
        // The simulator-side hooks stay neutral unless explicitly set.
        assert_eq!(cfg.cost_model.prefetch_depth, 1.0);
        assert_eq!(cfg.cost_model.partition_threads, 1.0);
        let cm = Config::from_json_str(
            r#"{"cost_model":{"prefetch_depth":2,"partition_threads":8}}"#,
        )
        .unwrap()
        .cost_model;
        assert_eq!(cm.staging_exposure(), 0.5);
        assert!(cm.partition_parallelism() > 6.0);
    }

    #[test]
    fn segment_store_keys_roundtrip_and_validate() {
        let cfg = Config::from_json_str(
            r#"{"segment_dir":"/tmp/segs","host_cache_bytes":1048576}"#,
        )
        .unwrap();
        assert_eq!(cfg.segment_dir.as_deref(), Some("/tmp/segs"));
        assert_eq!(cfg.host_cache_bytes, Some(1048576));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.segment_dir, cfg.segment_dir);
        assert_eq!(back.host_cache_bytes, cfg.host_cache_bytes);
        // Unset stays unset through the roundtrip (in-memory staging).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!((unset.segment_dir.clone(), unset.host_cache_bytes), (None, None));
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.segment_dir, None);
        // Bad values fail loudly.
        assert!(Config::from_json_str(r#"{"segment_dir":""}"#).is_err());
        assert!(Config::from_json_str(r#"{"segment_dir":7}"#).is_err());
        assert!(Config::from_json_str(r#"{"host_cache_bytes":-1}"#).is_err());
        assert!(Config::from_json_str(r#"{"host_cache_bytes":1.5}"#).is_err());
        // 0 is a valid bound: disk staging with the host tier disabled.
        assert_eq!(
            Config::from_json_str(r#"{"host_cache_bytes":0}"#).unwrap().host_cache_bytes,
            Some(0)
        );
    }

    #[test]
    fn panel_dir_key_roundtrips_and_validates() {
        let cfg = Config::from_json_str(r#"{"panel_dir":"/tmp/panels"}"#).unwrap();
        assert_eq!(cfg.panel_dir.as_deref(), Some("/tmp/panels"));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.panel_dir, cfg.panel_dir);
        // Unset stays unset (intermediate panels stay in host RAM).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!(unset.panel_dir, None);
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.panel_dir, None);
        assert!(Config::from_json_str(r#"{"panel_dir":""}"#).is_err());
        assert!(Config::from_json_str(r#"{"panel_dir":3}"#).is_err());
    }

    #[test]
    fn recycle_cap_key_roundtrips_and_validates() {
        let cfg = Config::from_json_str(r#"{"recycle_cap_bytes":1048576}"#).unwrap();
        assert_eq!(cfg.recycle_cap_bytes, Some(1 << 20));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.recycle_cap_bytes, Some(1 << 20));
        // Unset stays unset (the CLI then applies the default cap).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!(unset.recycle_cap_bytes, None);
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.recycle_cap_bytes, None);
        // 0 is valid: recycling disabled (the fresh-allocation oracle).
        assert_eq!(
            Config::from_json_str(r#"{"recycle_cap_bytes":0}"#).unwrap().recycle_cap_bytes,
            Some(0)
        );
        assert!(Config::from_json_str(r#"{"recycle_cap_bytes":-1}"#).is_err());
        assert!(Config::from_json_str(r#"{"recycle_cap_bytes":1.5}"#).is_err());
    }

    #[test]
    fn tenants_key_roundtrips_and_validates() {
        let cfg = Config::from_json_str(r#"{"tenants":8}"#).unwrap();
        assert_eq!(cfg.tenants, Some(8));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.tenants, Some(8), "set key survives the roundtrip");
        // Unset stays unset (the CLI then applies its own default).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!(unset.tenants, None);
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.tenants, None);
        assert!(Config::from_json_str(r#"{"tenants":0}"#).is_err());
        assert!(Config::from_json_str(r#"{"tenants":-2}"#).is_err());
        assert!(Config::from_json_str(r#"{"tenants":1.5}"#).is_err());
        assert!(Config::from_json_str(r#"{"tenants":"four"}"#).is_err());
    }

    #[test]
    fn bench_db_key_roundtrips_and_validates() {
        let cfg = Config::from_json_str(r#"{"bench_db":"perf/trajectory.jsonl"}"#).unwrap();
        assert_eq!(cfg.bench_db.as_deref(), Some("perf/trajectory.jsonl"));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.bench_db, cfg.bench_db, "set key survives the roundtrip");
        // Unset stays unset (the CLI then requires --db).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!(unset.bench_db, None);
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.bench_db, None);
        assert!(Config::from_json_str(r#"{"bench_db":""}"#).is_err());
        assert!(Config::from_json_str(r#"{"bench_db":9}"#).is_err());
    }

    #[test]
    fn train_stream_keys_roundtrip_and_validate() {
        let cfg = Config::from_json_str(
            r#"{"train_stream":true,"recompute_policy":"recompute"}"#,
        )
        .unwrap();
        assert_eq!(cfg.train_stream, Some(true));
        assert_eq!(cfg.recompute_policy.as_deref(), Some("recompute"));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.train_stream, Some(true), "set keys survive the roundtrip");
        assert_eq!(back.recompute_policy, cfg.recompute_policy);
        // false is distinct from unset and also roundtrips.
        let off = Config::from_json_str(r#"{"train_stream":false}"#).unwrap();
        assert_eq!(off.train_stream, Some(false));
        let off_back = Config::from_json_str(&off.to_json().to_string()).unwrap();
        assert_eq!(off_back.train_stream, Some(false));
        // Unset stays unset (the CLI then uses the artifact path / auto).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!((unset.train_stream, unset.recompute_policy.clone()), (None, None));
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.train_stream, None);
        assert_eq!(unset_back.recompute_policy, None);
        // All three policies are accepted; anything else fails at load time.
        for p in ["reload", "recompute", "auto"] {
            let text = format!("{{\"recompute_policy\":{p:?}}}");
            assert!(Config::from_json_str(&text).is_ok(), "policy {p}");
        }
        assert!(Config::from_json_str(r#"{"recompute_policy":"fast"}"#).is_err());
        assert!(Config::from_json_str(r#"{"recompute_policy":3}"#).is_err());
        assert!(Config::from_json_str(r#"{"train_stream":1}"#).is_err());
        assert!(Config::from_json_str(r#"{"train_stream":"yes"}"#).is_err());
    }

    #[test]
    fn healing_keys_roundtrip_and_validate() {
        let cfg = Config::from_json_str(
            r#"{"retry_max":3,"retry_backoff_ios":2,"checkpoint_dir":"/tmp/ckpt"}"#,
        )
        .unwrap();
        assert_eq!(cfg.retry_max, Some(3));
        assert_eq!(cfg.retry_backoff_ios, Some(2));
        assert_eq!(cfg.checkpoint_dir.as_deref(), Some("/tmp/ckpt"));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.retry_max, Some(3), "set keys survive the roundtrip");
        assert_eq!(back.retry_backoff_ios, Some(2));
        assert_eq!(back.checkpoint_dir, cfg.checkpoint_dir);
        // Unset stays unset (fail fast, no backoff, no checkpointing).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!(
            (unset.retry_max, unset.retry_backoff_ios, unset.checkpoint_dir.clone()),
            (None, None, None)
        );
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.retry_max, None);
        assert_eq!(unset_back.checkpoint_dir, None);
        // 0 is valid for both counters (explicit fail-fast / zero charge).
        let zero = Config::from_json_str(r#"{"retry_max":0,"retry_backoff_ios":0}"#).unwrap();
        assert_eq!((zero.retry_max, zero.retry_backoff_ios), (Some(0), Some(0)));
        // Bad values fail loudly.
        assert!(Config::from_json_str(r#"{"retry_max":-1}"#).is_err());
        assert!(Config::from_json_str(r#"{"retry_max":1.5}"#).is_err());
        assert!(Config::from_json_str(r#"{"retry_backoff_ios":-2}"#).is_err());
        assert!(Config::from_json_str(r#"{"checkpoint_dir":""}"#).is_err());
        assert!(Config::from_json_str(r#"{"checkpoint_dir":4}"#).is_err());
    }

    #[test]
    fn storage_v2_keys_roundtrip_and_validate() {
        let cfg =
            Config::from_json_str(r#"{"mmap_segments":true,"seg_encoding":"packed"}"#).unwrap();
        assert_eq!(cfg.mmap_segments, Some(true));
        assert_eq!(cfg.seg_encoding.as_deref(), Some("packed"));
        let back = Config::from_json_str(&cfg.to_json().to_string()).unwrap();
        assert_eq!(back.mmap_segments, Some(true), "set keys survive the roundtrip");
        assert_eq!(back.seg_encoding, cfg.seg_encoding);
        // false is distinct from unset and also roundtrips.
        let off = Config::from_json_str(r#"{"mmap_segments":false}"#).unwrap();
        assert_eq!(off.mmap_segments, Some(false));
        let off_back = Config::from_json_str(&off.to_json().to_string()).unwrap();
        assert_eq!(off_back.mmap_segments, Some(false));
        // Unset stays unset (copying reads, raw encoding).
        let unset = Config::from_json_str("{}").unwrap();
        assert_eq!((unset.mmap_segments, unset.seg_encoding.clone()), (None, None));
        let unset_back = Config::from_json_str(&unset.to_json().to_string()).unwrap();
        assert_eq!(unset_back.mmap_segments, None);
        assert_eq!(unset_back.seg_encoding, None);
        // All three encodings are accepted; anything else fails at load time.
        for e in ["raw", "packed", "auto"] {
            let text = format!("{{\"seg_encoding\":{e:?}}}");
            assert!(Config::from_json_str(&text).is_ok(), "encoding {e}");
        }
        assert!(Config::from_json_str(r#"{"seg_encoding":"zip"}"#).is_err());
        assert!(Config::from_json_str(r#"{"seg_encoding":2}"#).is_err());
        assert!(Config::from_json_str(r#"{"mmap_segments":1}"#).is_err());
        assert!(Config::from_json_str(r#"{"mmap_segments":"on"}"#).is_err());
    }

    #[test]
    fn cpu_thread_hook_overrides_apply() {
        let cfg = Config::from_json_str(
            r#"{"cost_model":{"cpu_threads":4,"cpu_parallel_eff":0.9}}"#,
        )
        .unwrap();
        assert_eq!(cfg.cost_model.cpu_threads, 4.0);
        assert!(cfg.cost_model.host_parallelism() > 3.5);
        // Default config keeps the hook neutral (calibration unchanged).
        assert_eq!(Config::default().cost_model.host_parallelism(), 1.0);
    }

    #[test]
    fn empty_selection_means_all() {
        let cfg = Config::from_json_str("{}").unwrap();
        assert_eq!(cfg.selected_datasets().len(), 7);
    }

    #[test]
    fn faster_storage_config_shrinks_latency() {
        // A config with 2x NVMe/GDS must not slow AIRES down.
        let base = Config::default();
        let fast = Config::from_json_str(
            r#"{"cost_model":{"nvme_read_gbps":13.2,"gds_read_gbps":11.6,"gds_write_gbps":10.0}}"#,
        )
        .unwrap();
        let d = crate::graphgen::catalog::by_name("kP1a").unwrap();
        let w = crate::sched::Workload::from_catalog(d, 256, 1);
        use crate::sched::Scheduler;
        let t_base = crate::sched::Aires.run_epoch(&w, &base.cost_model).makespan_s.unwrap();
        let t_fast = crate::sched::Aires.run_epoch(&w, &fast.cost_model).makespan_s.unwrap();
        assert!(t_fast <= t_base);
    }
}
