//! Row block-wise (RoBW) partitioning — paper Algorithm 1.
//!
//! Given CSR A and a GPU byte budget `m_a`, produce segments of *complete*
//! rows whose memory footprint (`calcMem`) stays within budget. Complete
//! rows are the whole point: the GPU never receives a fragment it has to
//! ship back for host-side merging (the Fig. 3 overhead).
//!
//! This is the hot CPU-side preprocessing pass (runs once per matrix in
//! Phase I), so the planning walk is allocation-free over `rowptr` and the
//! copy loop is a straight memcpy per array — see §Perf in EXPERIMENTS.md.

use crate::runtime::pool::{chunk_ranges, Pool};
use crate::sparse::{Csr, IDX_BYTES, PTR_BYTES, VAL_BYTES};

/// One RoBW segment: complete rows `[row_lo, row_hi)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RobwSegment {
    /// First row of the segment (inclusive).
    pub row_lo: usize,
    /// One past the last row of the segment (exclusive).
    pub row_hi: usize,
    /// Non-zeros in the segment.
    pub nnz: usize,
    /// `calcMem` footprint in bytes (rowptr + colidx + vals).
    pub bytes: u64,
}

/// `calcMem(k, q)` from Algorithm 1: bytes to hold `k` rows with `q`
/// non-zeros in CSR form on the GPU.
#[inline]
pub fn calc_mem(k: usize, q: usize) -> u64 {
    (k as u64 + 1) * PTR_BYTES + q as u64 * (VAL_BYTES + IDX_BYTES)
}

/// Algorithm 1: plan RoBW segments for `a` under per-segment budget `m_a`
/// bytes. A single row larger than the budget becomes its own segment
/// (the GPU-side kernel streams it; the alternative is an unservable
/// input) — flagged via `RobwSegment::bytes > m_a`.
pub fn robw_partition(a: &Csr, m_a: u64) -> Vec<RobwSegment> {
    let n = a.nrows;
    let mut segs = Vec::new();
    let mut start = 0usize;
    while start < n {
        let mut end = start;
        let mut z = 0usize; // non-zeros in block
        // Grow while the block *including the next row* fits (Alg. 1 l.5-8).
        loop {
            if end >= n {
                break;
            }
            let next_q = z + a.row_nnz(end);
            let next_k = end - start + 1;
            if calc_mem(next_k, next_q) <= m_a || end == start {
                // Always take at least one row (oversized-row escape).
                z = next_q;
                end += 1;
                if calc_mem(next_k, next_q) > m_a {
                    break; // oversized single row: close the segment
                }
            } else {
                break;
            }
        }
        segs.push(RobwSegment {
            row_lo: start,
            row_hi: end,
            nnz: z,
            bytes: calc_mem(end - start, z),
        });
        start = end;
    }
    segs
}

/// Build the [`RobwSegment`] record for rows `[row_lo, row_hi)`.
fn make_segment(a: &Csr, row_lo: usize, row_hi: usize) -> RobwSegment {
    let nnz = a.rowptr[row_hi] - a.rowptr[row_lo];
    RobwSegment { row_lo, row_hi, nnz, bytes: calc_mem(row_hi - row_lo, nnz) }
}

/// Greedy boundary from `start`: the largest `e` with
/// `calc_mem(e - start, nnz(start..e)) <= m_a`, floored at one row (the
/// oversized-row escape). `rowptr` is already the nnz prefix sum and the
/// footprint is strictly increasing in `e`, so the boundary is found by
/// binary search in O(log n) instead of the serial walk's O(rows) —
/// exactly the same boundary Algorithm 1's row-at-a-time loop produces.
fn segment_end(a: &Csr, m_a: u64, start: usize) -> usize {
    let cost = |e: usize| calc_mem(e - start, a.rowptr[e] - a.rowptr[start]);
    if cost(start + 1) > m_a {
        return start + 1;
    }
    // Invariant: `lo` is feasible, everything past `hi` is not.
    let (mut lo, mut hi) = (start + 1, a.nrows);
    while lo < hi {
        let mid = lo + (hi - lo + 1) / 2;
        if cost(mid) <= m_a {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

/// Parallel Algorithm 1 on [`runtime::pool`](crate::runtime::pool):
/// produces a plan **identical** to [`robw_partition`] at every thread
/// count (the PR-1 determinism rule, extended to planning).
///
/// Phase 1 splits the rows into one fixed contiguous range per worker and
/// plans greedy segments anchored at each range start; every planned
/// segment is a true greedy segment (its end ignores the range boundary),
/// so it is globally valid whenever its start row lies on the global
/// boundary chain. Phase 2 is the ordered segment-boundary merge: walk the
/// ranges in order, re-deriving boundaries from the live position until it
/// coincides with a locally planned start, then splice the remainder of
/// that range's plan wholesale. Plans are equal to the serial planner by
/// construction — enforced across thread counts in
/// `rust/tests/differential.rs`.
pub fn robw_partition_par(a: &Csr, m_a: u64, pool: &Pool) -> Vec<RobwSegment> {
    let n = a.nrows;
    if pool.threads() <= 1 || n < 2 * pool.threads() {
        return robw_partition(a, m_a);
    }
    let ranges = chunk_ranges(n, pool.threads());
    let local: Vec<Vec<RobwSegment>> = pool.map_tasks(ranges.len(), |ci| {
        let r = &ranges[ci];
        let mut out = Vec::new();
        let mut pos = r.start;
        while pos < r.end {
            let e = segment_end(a, m_a, pos);
            out.push(make_segment(a, pos, e));
            pos = e;
        }
        out
    });
    let mut segs: Vec<RobwSegment> = Vec::new();
    let mut pos = 0usize;
    for (ci, r) in ranges.iter().enumerate() {
        // A segment spliced earlier may overrun this whole range.
        if pos >= r.end {
            continue;
        }
        let plan = &local[ci];
        while pos < r.end {
            // Local starts are sorted; an exact hit synchronizes the chains
            // (a greedy segment depends only on its start row).
            if let Ok(k) = plan.binary_search_by_key(&pos, |s| s.row_lo) {
                segs.extend_from_slice(&plan[k..]);
                pos = segs.last().expect("spliced plan is non-empty").row_hi;
                break;
            }
            let e = segment_end(a, m_a, pos);
            segs.push(make_segment(a, pos, e));
            pos = e;
        }
    }
    segs
}

/// Materialize a planned segment (Alg. 1 lines 9-18: the copy loop).
pub fn materialize(a: &Csr, seg: &RobwSegment) -> Csr {
    a.slice_rows(seg.row_lo, seg.row_hi)
}

/// [`materialize`] into caller-owned scratch (see [`Csr::slice_rows_into`]):
/// the in-memory staging producer reuses one recycled scratch matrix per
/// in-flight segment instead of allocating three fresh sections each time.
pub fn materialize_into(a: &Csr, seg: &RobwSegment, out: &mut Csr) {
    a.slice_rows_into(seg.row_lo, seg.row_hi, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn segments_cover_all_rows_disjointly() {
        let mut rng = Pcg::seed(100);
        let a = random_csr(&mut rng, 200, 64, 0.1);
        let segs = robw_partition(&a, 1024);
        assert_eq!(segs[0].row_lo, 0);
        assert_eq!(segs.last().unwrap().row_hi, 200);
        for w in segs.windows(2) {
            assert_eq!(w[0].row_hi, w[1].row_lo, "contiguous, no overlap");
        }
    }

    #[test]
    fn segments_respect_budget_except_oversized_rows() {
        let mut rng = Pcg::seed(101);
        let a = random_csr(&mut rng, 300, 128, 0.08);
        let budget = 800u64;
        for seg in robw_partition(&a, budget) {
            if seg.row_hi - seg.row_lo > 1 {
                assert!(seg.bytes <= budget, "multi-row segment over budget: {seg:?}");
            }
        }
    }

    #[test]
    fn oversized_single_row_becomes_own_segment() {
        // One row with 100 nnz, budget fits ~10.
        let mut coo = Coo::new(3, 200);
        for c in 0..100 {
            coo.push(1, c, 1.0);
        }
        coo.push(0, 0, 1.0);
        coo.push(2, 0, 1.0);
        let a = coo.to_csr();
        let segs = robw_partition(&a, 120);
        assert!(segs.iter().any(|s| s.row_lo == 1 && s.row_hi == 2));
    }

    #[test]
    fn materialized_segments_reassemble_exactly() {
        let mut rng = Pcg::seed(102);
        let a = random_csr(&mut rng, 150, 50, 0.12);
        let segs = robw_partition(&a, 600);
        let parts: Vec<Csr> = segs.iter().map(|s| materialize(&a, s)).collect();
        assert_eq!(Csr::vstack(&parts).unwrap(), a);
    }

    #[test]
    fn larger_budget_fewer_segments() {
        let mut rng = Pcg::seed(103);
        let a = random_csr(&mut rng, 400, 64, 0.1);
        let small = robw_partition(&a, 512).len();
        let large = robw_partition(&a, 4096).len();
        assert!(large < small, "{large} !< {small}");
    }

    #[test]
    fn nnz_accounting_is_exact() {
        let mut rng = Pcg::seed(104);
        let a = random_csr(&mut rng, 100, 40, 0.15);
        let segs = robw_partition(&a, 700);
        let total: usize = segs.iter().map(|s| s.nnz).sum();
        assert_eq!(total, a.nnz());
        for s in &segs {
            assert_eq!(s.nnz, a.rowptr[s.row_hi] - a.rowptr[s.row_lo]);
        }
    }

    #[test]
    fn empty_matrix_single_pass() {
        let a = Csr::empty(10, 10);
        let segs = robw_partition(&a, 1 << 20);
        assert_eq!(segs.len(), 1);
        assert_eq!((segs[0].row_lo, segs[0].row_hi), (0, 10));
    }

    #[test]
    fn parallel_plan_equals_serial_plan() {
        let mut rng = Pcg::seed(105);
        for (nrows, density, budget) in
            [(200usize, 0.1, 600u64), (500, 0.05, 1024), (937, 0.02, 400)]
        {
            let a = random_csr(&mut rng, nrows, 64, density);
            let want = robw_partition(&a, budget);
            for threads in [1usize, 2, 3, 4, 8] {
                let got = robw_partition_par(&a, budget, &Pool::new(threads));
                assert_eq!(got, want, "nrows={nrows} budget={budget} threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_plan_handles_oversized_rows_and_tiny_budgets() {
        // Hub row far over budget + near-zero budget (every row its own
        // segment) — the splice must still reproduce the serial chain.
        let mut coo = Coo::new(64, 300);
        for c in 0..200 {
            coo.push(17, c, 1.0);
        }
        for r in 0..64u32 {
            coo.push(r, (r % 300) as u32, 2.0);
        }
        let a = coo.to_csr();
        for budget in [1u64, 64, 120, 1 << 20] {
            let want = robw_partition(&a, budget);
            for threads in [2usize, 4, 8] {
                let got = robw_partition_par(&a, budget, &Pool::new(threads));
                assert_eq!(got, want, "budget={budget} threads={threads}");
            }
        }
    }

    #[test]
    fn parallel_plan_empty_and_small_inputs() {
        let pool = Pool::new(8);
        let empty = Csr::empty(10, 10);
        assert_eq!(robw_partition_par(&empty, 1 << 20, &pool), robw_partition(&empty, 1 << 20));
        let none = Csr::empty(0, 5);
        assert_eq!(robw_partition_par(&none, 1 << 20, &pool), robw_partition(&none, 1 << 20));
    }
}
