//! Row block-wise (RoBW) partitioning — paper Algorithm 1.
//!
//! Given CSR A and a GPU byte budget `m_a`, produce segments of *complete*
//! rows whose memory footprint (`calcMem`) stays within budget. Complete
//! rows are the whole point: the GPU never receives a fragment it has to
//! ship back for host-side merging (the Fig. 3 overhead).
//!
//! This is the hot CPU-side preprocessing pass (runs once per matrix in
//! Phase I), so the planning walk is allocation-free over `rowptr` and the
//! copy loop is a straight memcpy per array — see §Perf in EXPERIMENTS.md.

use crate::sparse::{Csr, IDX_BYTES, PTR_BYTES, VAL_BYTES};

/// One RoBW segment: complete rows `[row_lo, row_hi)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RobwSegment {
    pub row_lo: usize,
    pub row_hi: usize,
    /// Non-zeros in the segment.
    pub nnz: usize,
    /// `calcMem` footprint in bytes (rowptr + colidx + vals).
    pub bytes: u64,
}

/// `calcMem(k, q)` from Algorithm 1: bytes to hold `k` rows with `q`
/// non-zeros in CSR form on the GPU.
#[inline]
pub fn calc_mem(k: usize, q: usize) -> u64 {
    (k as u64 + 1) * PTR_BYTES + q as u64 * (VAL_BYTES + IDX_BYTES)
}

/// Algorithm 1: plan RoBW segments for `a` under per-segment budget `m_a`
/// bytes. A single row larger than the budget becomes its own segment
/// (the GPU-side kernel streams it; the alternative is an unservable
/// input) — flagged via `RobwSegment::bytes > m_a`.
pub fn robw_partition(a: &Csr, m_a: u64) -> Vec<RobwSegment> {
    let n = a.nrows;
    let mut segs = Vec::new();
    let mut start = 0usize;
    while start < n {
        let mut end = start;
        let mut z = 0usize; // non-zeros in block
        // Grow while the block *including the next row* fits (Alg. 1 l.5-8).
        loop {
            if end >= n {
                break;
            }
            let next_q = z + a.row_nnz(end);
            let next_k = end - start + 1;
            if calc_mem(next_k, next_q) <= m_a || end == start {
                // Always take at least one row (oversized-row escape).
                z = next_q;
                end += 1;
                if calc_mem(next_k, next_q) > m_a {
                    break; // oversized single row: close the segment
                }
            } else {
                break;
            }
        }
        segs.push(RobwSegment {
            row_lo: start,
            row_hi: end,
            nnz: z,
            bytes: calc_mem(end - start, z),
        });
        start = end;
    }
    segs
}

/// Materialize a planned segment (Alg. 1 lines 9-18: the copy loop).
pub fn materialize(a: &Csr, seg: &RobwSegment) -> Csr {
    a.slice_rows(seg.row_lo, seg.row_hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn segments_cover_all_rows_disjointly() {
        let mut rng = Pcg::seed(100);
        let a = random_csr(&mut rng, 200, 64, 0.1);
        let segs = robw_partition(&a, 1024);
        assert_eq!(segs[0].row_lo, 0);
        assert_eq!(segs.last().unwrap().row_hi, 200);
        for w in segs.windows(2) {
            assert_eq!(w[0].row_hi, w[1].row_lo, "contiguous, no overlap");
        }
    }

    #[test]
    fn segments_respect_budget_except_oversized_rows() {
        let mut rng = Pcg::seed(101);
        let a = random_csr(&mut rng, 300, 128, 0.08);
        let budget = 800u64;
        for seg in robw_partition(&a, budget) {
            if seg.row_hi - seg.row_lo > 1 {
                assert!(seg.bytes <= budget, "multi-row segment over budget: {seg:?}");
            }
        }
    }

    #[test]
    fn oversized_single_row_becomes_own_segment() {
        // One row with 100 nnz, budget fits ~10.
        let mut coo = Coo::new(3, 200);
        for c in 0..100 {
            coo.push(1, c, 1.0);
        }
        coo.push(0, 0, 1.0);
        coo.push(2, 0, 1.0);
        let a = coo.to_csr();
        let segs = robw_partition(&a, 120);
        assert!(segs.iter().any(|s| s.row_lo == 1 && s.row_hi == 2));
    }

    #[test]
    fn materialized_segments_reassemble_exactly() {
        let mut rng = Pcg::seed(102);
        let a = random_csr(&mut rng, 150, 50, 0.12);
        let segs = robw_partition(&a, 600);
        let parts: Vec<Csr> = segs.iter().map(|s| materialize(&a, s)).collect();
        assert_eq!(Csr::vstack(&parts).unwrap(), a);
    }

    #[test]
    fn larger_budget_fewer_segments() {
        let mut rng = Pcg::seed(103);
        let a = random_csr(&mut rng, 400, 64, 0.1);
        let small = robw_partition(&a, 512).len();
        let large = robw_partition(&a, 4096).len();
        assert!(large < small, "{large} !< {small}");
    }

    #[test]
    fn nnz_accounting_is_exact() {
        let mut rng = Pcg::seed(104);
        let a = random_csr(&mut rng, 100, 40, 0.15);
        let segs = robw_partition(&a, 700);
        let total: usize = segs.iter().map(|s| s.nnz).sum();
        assert_eq!(total, a.nnz());
        for s in &segs {
            assert_eq!(s.nnz, a.rowptr[s.row_hi] - a.rowptr[s.row_lo]);
        }
    }

    #[test]
    fn empty_matrix_single_pass() {
        let a = Csr::empty(10, 10);
        let segs = robw_partition(&a, 1 << 20);
        assert_eq!(segs.len(), 1);
        assert_eq!((segs[0].row_lo, segs[0].row_hi), (0, 10));
    }
}
