//! Naive byte-granular segmentation — the baseline RoBW replaces.
//!
//! "A naive way to maximize the available GPU memory space is to send out
//! as many rows or columns as possible. [...] segments often contain
//! partial rows, which cannot be processed at the current computation
//! cycle [and] must be repetitively transferred back to host memory to
//! merge with the remaining data" (paper §III-A, Fig. 3).
//!
//! This module reproduces that behaviour precisely so the merging overhead
//! can be measured: segments are cut at exact byte boundaries, and every
//! cut that lands mid-row produces a *partial tail* that the GPU returns
//! (DtoH) for the host to merge (memcpy) into the next segment (HtoD again).

use crate::sparse::{Csr, IDX_BYTES, VAL_BYTES};

/// One naive segment: nnz range `[nnz_lo, nnz_hi)`, cutting rows freely.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NaiveSegment {
    /// First non-zero of the segment (inclusive).
    pub nnz_lo: usize,
    /// One past the last non-zero (exclusive).
    pub nnz_hi: usize,
    /// First row touched and whether the segment starts mid-row.
    pub row_lo: usize,
    /// True when the segment begins inside a row cut by the previous one.
    pub starts_partial: bool,
    /// Last row touched and whether the segment ends mid-row.
    pub row_hi: usize,
    /// True when the segment's final row continues into the next segment.
    pub ends_partial: bool,
    /// Bytes of the partial tail (the data that must round-trip to host).
    pub partial_tail_bytes: u64,
}

/// Cut CSR A into segments of at most `m_a` bytes of nnz payload
/// (values + colidx), ignoring row boundaries — maximum memory packing.
pub fn naive_partition(a: &Csr, m_a: u64) -> Vec<NaiveSegment> {
    let entry_bytes = VAL_BYTES + IDX_BYTES;
    let per_seg = (m_a / entry_bytes).max(1) as usize;
    let nnz = a.nnz();
    let mut segs = Vec::new();
    let mut lo = 0usize;
    while lo < nnz || (nnz == 0 && lo == 0) {
        let hi = (lo + per_seg).min(nnz);
        let row_lo = row_of(a, lo);
        let row_hi = if hi == 0 { 0 } else { row_of(a, hi - 1) };
        let starts_partial = a.rowptr[row_lo] != lo;
        let ends_partial = hi < nnz && a.rowptr[row_hi + 1] != hi;
        let partial_tail = if ends_partial { hi - a.rowptr[row_hi] } else { 0 };
        segs.push(NaiveSegment {
            nnz_lo: lo,
            nnz_hi: hi,
            row_lo,
            starts_partial,
            row_hi,
            ends_partial,
            partial_tail_bytes: partial_tail as u64 * entry_bytes,
        });
        if nnz == 0 {
            break;
        }
        lo = hi;
    }
    segs
}

/// Row containing nnz index `p` (binary search over rowptr).
fn row_of(a: &Csr, p: usize) -> usize {
    // partition_point: first row whose rowptr[r+1] > p.
    let mut lo = 0usize;
    let mut hi = a.nrows;
    while lo < hi {
        let mid = (lo + hi) / 2;
        if a.rowptr[mid + 1] <= p {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo
}

/// Merging-overhead summary for a naive partitioning (Fig. 3's quantity):
/// total bytes that make the extra DtoH -> host-merge -> HtoD round trip.
#[derive(Debug, Clone, Copy, Default)]
pub struct MergeOverhead {
    /// Number of segment boundaries that landed mid-row.
    pub partial_cuts: u64,
    /// Bytes returned to host (DtoH) as unprocessable partial rows.
    pub dtoh_bytes: u64,
    /// Bytes merged on the host (memcpy of partial + head of next row part).
    pub host_merge_bytes: u64,
    /// Bytes re-sent to the GPU (the merged rows travel again).
    pub resend_bytes: u64,
}

/// Quantify the merge overhead of a naive partitioning.
pub fn merge_overhead(segs: &[NaiveSegment]) -> MergeOverhead {
    let mut ov = MergeOverhead::default();
    for s in segs {
        if s.ends_partial {
            ov.partial_cuts += 1;
            ov.dtoh_bytes += s.partial_tail_bytes;
            // Host merges the tail with the head arriving in the next
            // segment: both halves are touched by the memcpy.
            ov.host_merge_bytes += 2 * s.partial_tail_bytes;
            ov.resend_bytes += s.partial_tail_bytes;
        }
    }
    ov
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::robw::robw_partition;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn segments_tile_the_nnz_range() {
        let mut rng = Pcg::seed(110);
        let a = random_csr(&mut rng, 120, 80, 0.1);
        let segs = naive_partition(&a, 512);
        assert_eq!(segs[0].nnz_lo, 0);
        assert_eq!(segs.last().unwrap().nnz_hi, a.nnz());
        for w in segs.windows(2) {
            assert_eq!(w[0].nnz_hi, w[1].nnz_lo);
        }
    }

    #[test]
    fn detects_partial_rows() {
        // 2 rows x 6 nnz each; budget of 4 entries cuts mid-row.
        let mut coo = Coo::new(2, 10);
        for c in 0..6 {
            coo.push(0, c, 1.0);
            coo.push(1, c, 1.0);
        }
        let a = coo.to_csr();
        let segs = naive_partition(&a, 4 * 8); // 4 entries per segment
        assert!(segs.iter().any(|s| s.ends_partial));
        let ov = merge_overhead(&segs);
        assert!(ov.partial_cuts >= 1);
        assert!(ov.dtoh_bytes > 0);
    }

    #[test]
    fn row_aligned_budget_produces_no_partials() {
        // Rows of exactly 4 nnz, budget exactly 2 rows -> clean cuts.
        let mut coo = Coo::new(8, 16);
        for r in 0..8 {
            for c in 0..4 {
                coo.push(r, c * 2, 1.0);
            }
        }
        let a = coo.to_csr();
        let segs = naive_partition(&a, 8 * 8);
        let ov = merge_overhead(&segs);
        assert_eq!(ov.partial_cuts, 0);
        assert_eq!(ov.dtoh_bytes, 0);
    }

    #[test]
    fn robw_never_has_merge_overhead_naive_usually_does() {
        // The paper's core claim, as a property: on irregular matrices the
        // naive cut produces partials; RoBW by construction cannot.
        let mut rng = Pcg::seed(111);
        let mut naive_partials = 0u64;
        for _ in 0..10 {
            let density = 0.07 + rng.f64() * 0.1;
            let a = random_csr(&mut rng, 64, 64, density);
            let budget = 300 + rng.below(500);
            naive_partials += merge_overhead(&naive_partition(&a, budget)).partial_cuts;
            // RoBW: every segment is whole rows; reassembly is exact.
            let segs = robw_partition(&a, budget);
            for s in &segs {
                assert_eq!(s.nnz, a.rowptr[s.row_hi] - a.rowptr[s.row_lo]);
            }
        }
        assert!(naive_partials > 0, "naive should cut rows on irregular data");
    }

    #[test]
    fn smaller_memory_more_overhead() {
        // Fig. 3's second observation: overhead grows as memory shrinks.
        let mut rng = Pcg::seed(112);
        let a = random_csr(&mut rng, 400, 128, 0.08);
        let big = merge_overhead(&naive_partition(&a, 16 << 10));
        let small = merge_overhead(&naive_partition(&a, 1 << 10));
        assert!(small.partial_cuts >= big.partial_cuts);
        assert!(small.dtoh_bytes >= big.dtoh_bytes);
    }
}
