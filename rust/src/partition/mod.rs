//! Partitioning: the paper's algorithm-level contribution.
//!
//! * [`robw`] — Algorithm 1, row block-wise (RoBW) alignment: segments
//!   always contain complete rows, sized to a GPU byte budget.
//! * [`naive`] — the baseline byte-granular segmentation (maximize memory
//!   use, cut rows mid-stream) whose merging overhead motivates the paper
//!   (Fig. 3).
//! * [`tiling`] — the tiling planner that maps an aligned segment onto the
//!   fixed-shape `bsr_spmm` accelerator artifacts.

pub mod naive;
pub mod robw;
pub mod tiling;

pub use naive::{naive_partition, NaiveSegment};
pub use robw::{robw_partition, robw_partition_par, RobwSegment};
pub use tiling::{plan_tiles, TilePlan};
