//! Tiling planner: maps a RoBW-aligned segment onto the fixed-shape
//! `bsr_spmm` accelerator artifacts (paper §III-A "specialized tiling for
//! block-wise partitioned data", adapted to MXU tiles — DESIGN.md
//! §Hardware-Adaptation).
//!
//! Given the segment's shape/occupancy and the available artifact variants,
//! pick the variant minimizing estimated execution cost: padded-tile waste
//! trades against per-call overhead. Also produces the VMEM-footprint and
//! MXU-utilization estimates recorded in EXPERIMENTS.md §Perf (interpret
//! mode gives no real TPU timings, so structure is what we optimize).

/// One available artifact shape (mirrors `aot.py` SPMM_VARIANTS).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpmmVariant {
    /// Artifact name stem.
    pub name: &'static str,
    /// Row-block slots per call.
    pub r: usize,
    /// Padded tile slots per row block.
    pub nb: usize,
    /// Tile height.
    pub bm: usize,
    /// Tile width.
    pub bk: usize,
    /// Feature-panel rows (K) the artifact was lowered with.
    pub k: usize,
    /// Feature width.
    pub f: usize,
}

/// The tiling decision for a segment.
#[derive(Debug, Clone)]
pub struct TilePlan {
    /// The artifact variant the planner selected.
    pub variant: SpmmVariant,
    /// Number of artifact invocations needed.
    pub calls: usize,
    /// Fraction of streamed tile payload that is real data (1.0 = no waste).
    pub payload_efficiency: f64,
    /// Estimated VMEM-resident bytes per call on a real TPU
    /// (tile payloads + feature panel + output block).
    pub vmem_bytes: u64,
    /// Estimated MXU utilization: useful MACs / issued MACs.
    pub mxu_utilization: f64,
}

/// Estimate tiles-per-row-block for a segment with `rows` rows, `nnz`
/// non-zeros and `ncols` columns under (bm, bk) blocking, assuming the
/// near-banded structure of RoBW-aligned graph segments: non-zeros cluster,
/// so tiles-per-block ~ nnz_per_block_rows / fill, with fill the expected
/// occupancy of a touched tile.
fn est_tiles_per_block(rows: usize, nnz: usize, ncols: usize, bm: usize, bk: usize) -> f64 {
    if rows == 0 || nnz == 0 {
        return 0.0;
    }
    let nnz_per_block = nnz as f64 * bm as f64 / rows as f64;
    // Expected distinct tiles touched by n nnz spread over ncols/bk tiles
    // (balls in bins).
    let bins = (ncols as f64 / bk as f64).max(1.0);
    let touched = bins * (1.0 - (1.0 - 1.0 / bins).powf(nnz_per_block));
    touched.max(1.0)
}

/// Choose the best artifact variant for a segment.
///
/// `rows`/`nnz`/`ncols` describe the RoBW segment; `f` is the feature width
/// needed. Returns `None` if no variant matches the feature width.
pub fn plan_tiles(
    variants: &[SpmmVariant],
    rows: usize,
    nnz: usize,
    ncols: usize,
    f: usize,
) -> Option<TilePlan> {
    let mut best: Option<(f64, TilePlan)> = None;
    for &v in variants.iter().filter(|v| v.f == f && v.k >= ncols.min(v.k)) {
        let tiles_per_block = est_tiles_per_block(rows, nnz, ncols, v.bm, v.bk);
        let nblocks = rows.div_ceil(v.bm);
        // Each row block needs ceil(tiles/nb) slots; calls batch r slots.
        let slots = nblocks as f64 * (tiles_per_block / v.nb as f64).ceil();
        let calls = (slots / v.r as f64).ceil().max(1.0) as usize;
        // Efficiency: real nnz vs streamed dense payload.
        let streamed = calls as f64 * (v.r * v.nb * v.bm * v.bk) as f64;
        let payload_efficiency = (nnz as f64 / streamed).min(1.0);
        // MXU: useful MACs = nnz * f; issued = streamed * f.
        let mxu = payload_efficiency;
        // VMEM model: one call's blocks + feature panel + outputs resident.
        let vmem = (v.r * v.nb * v.bm * v.bk + v.k * v.f + v.r * v.bm * v.f) as u64 * 4;
        // Cost model: per-call overhead + streamed payload work.
        let cost = calls as f64 * 1.0 + streamed / (v.bm * v.bk) as f64 * 0.01;
        let plan = TilePlan {
            variant: v,
            calls,
            payload_efficiency,
            vmem_bytes: vmem,
            mxu_utilization: mxu,
        };
        if best.as_ref().map_or(true, |(c, _)| cost < *c) {
            best = Some((cost, plan));
        }
    }
    best.map(|(_, p)| p)
}

/// The artifact variants built by `aot.py` (kept in sync by the
/// `runtime::artifacts` loader, which validates against manifest.json).
pub const DEFAULT_VARIANTS: [SpmmVariant; 3] = [
    SpmmVariant { name: "bsr_spmm_r8_nb16_b32_k1024_f64", r: 8, nb: 16, bm: 32, bk: 32, k: 1024, f: 64 },
    SpmmVariant { name: "bsr_spmm_r4_nb8_b64_k1024_f64", r: 4, nb: 8, bm: 64, bk: 64, k: 1024, f: 64 },
    SpmmVariant { name: "bsr_spmm_r8_nb16_b32_k1024_f128", r: 8, nb: 16, bm: 32, bk: 32, k: 1024, f: 128 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_matching_feature_width() {
        let plan = plan_tiles(&DEFAULT_VARIANTS, 256, 2048, 1024, 128).unwrap();
        assert_eq!(plan.variant.f, 128);
    }

    #[test]
    fn no_variant_for_unknown_f() {
        assert!(plan_tiles(&DEFAULT_VARIANTS, 256, 2048, 1024, 7).is_none());
    }

    #[test]
    fn denser_segments_prefer_bigger_tiles() {
        // Very dense: fewer, larger tiles win (fill is high either way,
        // fewer calls). Very sparse: small tiles waste less padding.
        let dense = plan_tiles(&DEFAULT_VARIANTS, 512, 200_000, 1024, 64).unwrap();
        let sparse = plan_tiles(&DEFAULT_VARIANTS, 512, 1_500, 1024, 64).unwrap();
        assert!(dense.payload_efficiency > sparse.payload_efficiency);
    }

    #[test]
    fn vmem_fits_16mb_budget() {
        // DESIGN.md §Perf: per-call VMEM must stay under a TPU-core-class
        // budget for every shipped variant.
        for v in DEFAULT_VARIANTS {
            let plan = plan_tiles(&[v], 256, 4096, v.k, v.f).unwrap();
            assert!(plan.vmem_bytes < 16 << 20, "{}: {} B", v.name, plan.vmem_bytes);
        }
    }

    #[test]
    fn call_count_scales_with_rows() {
        let small = plan_tiles(&DEFAULT_VARIANTS, 128, 1024, 1024, 64).unwrap();
        let large = plan_tiles(&DEFAULT_VARIANTS, 4096, 32768, 1024, 64).unwrap();
        assert!(large.calls > small.calls);
    }
}
