//! Road-network generator (paper's road_usa): planar grid with perturbed
//! connectivity — degree <= 4-ish, huge diameter, extremely low bandwidth
//! CSR structure.

use super::edges_to_adjacency;
use crate::sparse::Csr;
use crate::util::rng::Pcg;

/// Grid road network over ~n vertices (rounded to a w x h grid), with a
/// fraction of missing streets and occasional diagonal shortcuts.
pub fn generate(rng: &mut Pcg, n: usize) -> Csr {
    let w = (n as f64).sqrt().ceil() as usize;
    let h = n.div_ceil(w);
    let n = w * h;
    let idx = |x: usize, y: usize| (y * w + x) as u32;
    let mut edges = Vec::with_capacity(2 * n);
    for y in 0..h {
        for x in 0..w {
            // Right + down neighbours, each present with prob 0.92 (dead
            // ends / rivers), mimicking real road sparsity.
            if x + 1 < w && rng.chance(0.92) {
                edges.push((idx(x, y), idx(x + 1, y)));
            }
            if y + 1 < h && rng.chance(0.92) {
                edges.push((idx(x, y), idx(x, y + 1)));
            }
            // Rare diagonal (highway ramp).
            if x + 1 < w && y + 1 < h && rng.chance(0.02) {
                edges.push((idx(x, y), idx(x + 1, y + 1)));
            }
        }
    }
    edges_to_adjacency(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_degrees_bounded() {
        let mut rng = Pcg::seed(60);
        let a = generate(&mut rng, 2500);
        a.validate().unwrap();
        let max_deg = (0..a.nrows).map(|i| a.row_nnz(i)).max().unwrap();
        assert!(max_deg <= 8, "max degree {max_deg}");
        let avg = a.nnz() as f64 / a.nrows as f64;
        assert!((2.0..4.2).contains(&avg), "avg {avg}");
    }

    #[test]
    fn csr_is_banded() {
        // Grid ordering keeps neighbours within ~w of the diagonal.
        let mut rng = Pcg::seed(61);
        let a = generate(&mut rng, 900); // 30x30
        for i in 0..a.nrows {
            for (c, _) in a.row(i) {
                assert!((c as i64 - i as i64).unsigned_abs() <= 31);
            }
        }
    }
}
