//! kmer/GenBank-family generator (paper's kP1a/kU1a/kV2a/kA2a/kV1r).
//!
//! SuiteSparse's kmer_* graphs are de Bruijn-style assembly graphs from
//! GenBank: enormous vertex counts, *near-regular tiny degrees* (average
//! ~2.1-4.3, max degree bounded by the alphabet) and long chain-like
//! structure. We emulate that: vertices form noisy chains (successor k-mer
//! edges) plus a small fraction of branch edges (repeats), giving the same
//! banded-but-not-exactly-banded CSR structure that makes RoBW partitioning
//! interesting.

use super::edges_to_adjacency;
use crate::sparse::Csr;
use crate::util::rng::Pcg;

/// Generate a kmer-like graph with `n` vertices and ~`avg_degree * n / 2`
/// undirected edges.
pub fn generate(rng: &mut Pcg, n: usize, avg_degree: f64) -> Csr {
    assert!(n >= 2);
    let target_edges = ((n as f64) * avg_degree / 2.0) as usize;
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(target_edges);

    // Backbone chains: shuffled vertex order broken into chains, mimicking
    // contigs. Chain edges connect successive k-mers.
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let chain_len = 64.max(n / 1024);
    for chunk in order.chunks(chain_len) {
        for w in chunk.windows(2) {
            edges.push((w[0], w[1]));
        }
    }

    // Branch/repeat edges: short-range skips (repeats land near each other
    // in assembly order), filling the remaining edge budget.
    while edges.len() < target_edges {
        let u = rng.below(n as u64) as i64;
        // Geometric-ish short hop, occasionally long (repeat across contigs).
        let hop = if rng.chance(0.9) { 1 + rng.below(16) as i64 } else { rng.below(n as u64) as i64 };
        let v = (u + hop).rem_euclid(n as i64);
        if u != v {
            edges.push((u as u32, v as u32));
        }
    }
    edges_to_adjacency(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn degree_structure_is_near_regular() {
        let mut rng = Pcg::seed(50);
        let n = 4000;
        let a = generate(&mut rng, n, 3.4);
        a.validate().unwrap();
        let avg = a.nnz() as f64 / n as f64;
        assert!((2.0..5.0).contains(&avg), "avg degree {avg}");
        let max_deg = (0..n).map(|i| a.row_nnz(i)).max().unwrap();
        // kmer graphs have bounded max degree; our generator stays modest.
        assert!(max_deg < 64, "max degree {max_deg}");
    }

    #[test]
    fn deterministic() {
        let a = generate(&mut Pcg::seed(1), 500, 3.0);
        let b = generate(&mut Pcg::seed(1), 500, 3.0);
        assert_eq!(a, b);
    }
}
