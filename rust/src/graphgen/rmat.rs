//! RMAT / Kronecker generator (paper's soc-LiveJournal1 stand-in): power-law
//! degree distribution, community structure, high-degree hubs — the
//! adversarial case for row-block balance.

use super::edges_to_adjacency;
use crate::sparse::Csr;
use crate::util::rng::Pcg;

/// RMAT parameters (Graph500 defaults a=0.57, b=0.19, c=0.19, d=0.05).
#[derive(Debug, Clone, Copy)]
pub struct RmatParams {
    /// Top-left quadrant probability (hub mass).
    pub a: f64,
    /// Top-right quadrant probability.
    pub b: f64,
    /// Bottom-left quadrant probability (d = 1 - a - b - c).
    pub c: f64,
}

impl Default for RmatParams {
    fn default() -> Self {
        RmatParams { a: 0.57, b: 0.19, c: 0.19 }
    }
}

/// Generate an RMAT graph with 2^scale vertices and `edge_factor * 2^scale`
/// undirected edges.
pub fn generate(rng: &mut Pcg, scale: u32, edge_factor: usize, p: RmatParams) -> Csr {
    let n = 1usize << scale;
    let nedges = edge_factor * n;
    let mut edges = Vec::with_capacity(nedges);
    for _ in 0..nedges {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < p.a {
                (0, 0)
            } else if r < p.a + p.b {
                (0, 1)
            } else if r < p.a + p.b + p.c {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        if u != v {
            edges.push((u as u32, v as u32));
        }
    }
    edges_to_adjacency(n, &edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_law_has_hubs() {
        let mut rng = Pcg::seed(70);
        let a = generate(&mut rng, 10, 8, RmatParams::default());
        a.validate().unwrap();
        let n = a.nrows;
        let mut degs: Vec<usize> = (0..n).map(|i| a.row_nnz(i)).collect();
        degs.sort_unstable_by(|x, y| y.cmp(x));
        let avg = a.nnz() as f64 / n as f64;
        // Hubs: top vertex degree far above average.
        assert!(degs[0] as f64 > 8.0 * avg, "top {} vs avg {avg}", degs[0]);
    }

    #[test]
    fn size_matches_scale() {
        let mut rng = Pcg::seed(71);
        let a = generate(&mut rng, 8, 4, RmatParams::default());
        assert_eq!(a.nrows, 256);
        assert!(a.nnz() > 256);
    }
}
