//! Synthetic graph generators + the paper's dataset catalog.
//!
//! The paper evaluates on SuiteSparse graphs up to 214 M vertices / 27 GB
//! (Table II) which we cannot download offline; per the substitution rule
//! (DESIGN.md) we carry:
//!  * generators whose degree structure matches each dataset family —
//!    kmer/GenBank de Bruijn-like graphs (near-regular, avg degree ~2-4),
//!    road networks (planar grid, degree <= 4), social graphs (power-law
//!    via RMAT) — used to exercise the *real* compute path at small scale;
//!  * a catalog carrying the exact Table II statistics, which drive the
//!    paper-scale *scheduling simulation* (bytes moved, memory pressure)
//!    without materializing the matrices.

pub mod catalog;
pub mod kmer;
pub mod rmat;
pub mod road;

pub use catalog::{DatasetStats, CATALOG};

use crate::sparse::{Coo, Csr};
use crate::util::rng::Pcg;

/// Make an undirected edge list symmetric + loop-free and convert to CSR
/// with unit weights.
pub fn edges_to_adjacency(n: usize, edges: &[(u32, u32)]) -> Csr {
    let mut coo = Coo::new(n, n);
    for &(u, v) in edges {
        if u == v {
            continue;
        }
        coo.push(u, v, 1.0);
        coo.push(v, u, 1.0);
    }
    // to_csr sums duplicates; clamp back to unit weights.
    let mut csr = coo.to_csr();
    for v in csr.vals.iter_mut() {
        *v = 1.0;
    }
    csr
}

/// Uniformly random sparse feature matrix in CSR (the paper's B operand:
/// "feature matrix dimension of 256 with 99% uniform sparsity ratio").
pub fn random_sparse_features(
    rng: &mut Pcg,
    nrows: usize,
    ncols: usize,
    sparsity_pct: f64,
) -> Csr {
    let density = 1.0 - sparsity_pct / 100.0;
    let mut coo = Coo::new(nrows, ncols);
    let expected = (nrows as f64 * ncols as f64 * density) as usize;
    // Sample ~expected entries; duplicates collapse on conversion.
    for _ in 0..expected {
        let r = rng.below(nrows as u64) as u32;
        let c = rng.below(ncols as u64) as u32;
        coo.push(r, c, rng.normal() as f32);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adjacency_is_symmetric_loop_free() {
        let edges = vec![(0, 1), (1, 2), (2, 2), (0, 1)]; // dup + self loop
        let a = edges_to_adjacency(4, &edges);
        a.validate().unwrap();
        let d = a.to_dense();
        for i in 0..4 {
            assert_eq!(d[i * 4 + i], 0.0, "self loop at {i}");
            for j in 0..4 {
                assert_eq!(d[i * 4 + j], d[j * 4 + i]);
            }
        }
        assert_eq!(a.nnz(), 4); // (0,1),(1,0),(1,2),(2,1)
    }

    #[test]
    fn sparse_features_hit_target_sparsity() {
        let mut rng = Pcg::seed(40);
        let f = random_sparse_features(&mut rng, 200, 64, 99.0);
        let s = f.sparsity_pct();
        assert!(s > 98.0 && s < 99.9, "sparsity {s}");
    }
}
