//! The paper's dataset catalog (Table II), carried verbatim.
//!
//! These statistics drive the paper-scale *simulation* experiments: the
//! scheduler/memsim only needs vertex/edge counts, operand byte sizes and
//! the memory constraint, not the actual matrices (which are 3-27 GB and
//! unavailable offline). `scaled(n)` materializes a structurally similar
//! small instance for the real-compute path.

use super::{kmer, rmat, road};
use crate::sparse::Csr;
use crate::util::rng::Pcg;

/// Which SuiteSparse family a dataset belongs to (decides the generator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Family {
    /// GenBank de Bruijn-like assembly graphs (kmer_*).
    Kmer,
    /// Street networks (road_usa).
    Road,
    /// Social networks (soc-LiveJournal1).
    Social,
}

/// One Table II row.
#[derive(Debug, Clone, Copy)]
pub struct DatasetStats {
    /// Dataset name as the paper abbreviates it (e.g. "kP1a").
    pub name: &'static str,
    /// Graph family the generators substitute for it.
    pub family: Family,
    /// Vertices, in millions (paper Table II col 2).
    pub vertices_m: f64,
    /// Edges, in millions (col 3).
    pub edges_m: f64,
    /// Combined A+B+C GPU memory requirement, GB (col 4).
    pub memory_req_gb: f64,
    /// Evaluated GPU memory constraint, GB (col 5).
    pub memory_constraint_gb: f64,
}

impl DatasetStats {
    /// Vertex count.
    pub fn vertices(&self) -> u64 {
        (self.vertices_m * 1e6) as u64
    }
    /// Undirected edge count.
    pub fn edges(&self) -> u64 {
        (self.edges_m * 1e6) as u64
    }
    /// Stored non-zeros of the symmetric adjacency (2 per edge).
    pub fn nnz(&self) -> u64 {
        2 * self.edges()
    }
    /// Average stored non-zeros per row.
    pub fn avg_row_nnz(&self) -> f64 {
        self.nnz() as f64 / self.vertices() as f64
    }
    /// CSR A byte size (vals + colidx @4B each, rowptr @8B).
    pub fn csr_a_bytes(&self) -> u64 {
        self.nnz() * 8 + (self.vertices() + 1) * 8
    }
    /// CSC B byte size for `feat_dim` features at `sparsity_pct` sparsity
    /// (paper model config: 256 features, 99% sparse).
    pub fn csc_b_bytes(&self, feat_dim: usize, sparsity_pct: f64) -> u64 {
        let nnz_b =
            (self.vertices() as f64 * feat_dim as f64 * (1.0 - sparsity_pct / 100.0)) as u64;
        nnz_b * 8 + (feat_dim as u64 + 1) * 8
    }
    /// Memory constraint in bytes.
    pub fn constraint_bytes(&self) -> u64 {
        (self.memory_constraint_gb * 1e9) as u64
    }

    /// Materialize a scaled-down instance (~`n` vertices) with matching
    /// degree structure for the real-compute path.
    pub fn scaled(&self, rng: &mut Pcg, n: usize) -> Csr {
        match self.family {
            Family::Kmer => kmer::generate(rng, n, self.avg_row_nnz()),
            Family::Road => road::generate(rng, n),
            Family::Social => {
                let scale = (n as f64).log2().round().max(4.0) as u32;
                let ef = (self.avg_row_nnz() / 2.0).round().max(2.0) as usize;
                rmat::generate(rng, scale, ef, rmat::RmatParams::default())
            }
        }
    }
}

/// Table II, in the paper's row order.
pub const CATALOG: [DatasetStats; 7] = [
    DatasetStats {
        name: "rUSA",
        family: Family::Road,
        vertices_m: 23.94,
        edges_m: 57.70,
        memory_req_gb: 3.31,
        memory_constraint_gb: 3.0,
    },
    DatasetStats {
        name: "kV2a",
        family: Family::Kmer,
        vertices_m: 55.04,
        edges_m: 117.21,
        memory_req_gb: 6.87,
        memory_constraint_gb: 6.0,
    },
    DatasetStats {
        name: "kU1a",
        family: Family::Kmer,
        vertices_m: 67.71,
        edges_m: 138.77,
        memory_req_gb: 8.2,
        memory_constraint_gb: 8.0,
    },
    DatasetStats {
        name: "socLJ1",
        family: Family::Social,
        vertices_m: 4.84,
        edges_m: 68.99,
        memory_req_gb: 12.14,
        memory_constraint_gb: 11.0,
    },
    DatasetStats {
        name: "kP1a",
        family: Family::Kmer,
        vertices_m: 139.35,
        edges_m: 297.82,
        memory_req_gb: 17.45,
        memory_constraint_gb: 16.0,
    },
    DatasetStats {
        name: "kA2a",
        family: Family::Kmer,
        vertices_m: 170.72,
        edges_m: 360.58,
        memory_req_gb: 21.18,
        memory_constraint_gb: 18.0,
    },
    DatasetStats {
        name: "kV1r",
        family: Family::Kmer,
        vertices_m: 214.00,
        edges_m: 465.41,
        memory_req_gb: 27.18,
        memory_constraint_gb: 23.0,
    },
];

/// Look up a catalog entry by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<&'static DatasetStats> {
    CATALOG.iter().find(|d| d.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_table2() {
        assert_eq!(CATALOG.len(), 7);
        let kv1r = by_name("kV1r").unwrap();
        assert_eq!(kv1r.vertices(), 214_000_000);
        assert_eq!(kv1r.edges(), 465_410_000);
        assert!((kv1r.memory_req_gb - 27.18).abs() < 1e-9);
        assert!((kv1r.memory_constraint_gb - 23.0).abs() < 1e-9);
    }

    #[test]
    fn constraint_below_requirement_for_all() {
        // The whole point of Table II: every dataset is out-of-core.
        for d in &CATALOG {
            assert!(
                d.memory_constraint_gb < d.memory_req_gb,
                "{} should be memory constrained",
                d.name
            );
        }
    }

    #[test]
    fn kmer_average_degrees_are_small() {
        for d in CATALOG.iter().filter(|d| d.family == Family::Kmer) {
            let avg = d.avg_row_nnz();
            assert!((2.0..6.0).contains(&avg), "{}: {avg}", d.name);
        }
    }

    #[test]
    fn scaled_instances_generate() {
        let mut rng = Pcg::seed(80);
        for d in &CATALOG {
            let g = d.scaled(&mut rng, 800);
            g.validate().unwrap();
            assert!(g.nrows >= 256, "{} scaled too small", d.name);
            assert!(g.nnz() > 0);
        }
    }

    #[test]
    fn byte_model_ordering_follows_table() {
        // Datasets are listed in increasing memory requirement; our CSR A
        // byte model should be monotone in the same order for same-family
        // entries (kmer).
        let kmers: Vec<&DatasetStats> =
            CATALOG.iter().filter(|d| d.family == Family::Kmer).collect();
        for w in kmers.windows(2) {
            assert!(w[1].csr_a_bytes() > w[0].csr_a_bytes());
        }
    }
}
