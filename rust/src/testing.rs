//! In-tree property-based testing (proptest is unavailable in the offline
//! crate cache). Deterministic seed-sweep model: a property is a function
//! of a [`Pcg`] generator; `check` runs it across N derived seeds and
//! reports the failing seed, so failures reproduce exactly.

use crate::util::rng::Pcg;

/// Number of cases per property (override with `AIRES_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("AIRES_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run `prop` across `cases` generator streams derived from `seed`.
/// Panics with the failing stream id on the first failure.
pub fn check<F: FnMut(&mut Pcg) -> Result<(), String>>(name: &str, seed: u64, mut prop: F) {
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Pcg::new(seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed at seed={seed} stream={case}: {msg}");
        }
    }
}

/// Property helpers for building random instances.
pub mod gen {
    use crate::sparse::{Coo, Csr};
    use crate::util::rng::Pcg;

    /// Random CSR with shape in [1, max_dim] and density in (0, max_density].
    pub fn csr(rng: &mut Pcg, max_dim: usize, max_density: f64) -> Csr {
        let nrows = rng.range(1, max_dim + 1);
        let ncols = rng.range(1, max_dim + 1);
        let density = rng.f64() * max_density;
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, (rng.normal() as f32).max(-10.0).min(10.0));
                }
            }
        }
        coo.to_csr()
    }

    /// Random square symmetric adjacency (unit weights, no self loops).
    pub fn adjacency(rng: &mut Pcg, max_dim: usize, max_density: f64) -> Csr {
        let n = rng.range(2, max_dim + 1);
        let density = rng.f64() * max_density;
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                if rng.chance(density) {
                    edges.push((i, j));
                }
            }
        }
        crate::graphgen::edges_to_adjacency(n, &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 1, |rng| {
            let v = rng.below(10);
            if v < 10 { Ok(()) } else { Err(format!("{v}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failing")]
    fn check_reports_failures() {
        check("failing", 1, |rng| {
            if rng.below(8) == 7 { Err("hit".into()) } else { Ok(()) }
        });
    }

    #[test]
    fn generated_csr_is_valid() {
        check("gen-csr-valid", 2, |rng| {
            gen::csr(rng, 24, 0.4).validate()
        });
    }
}
