//! In-tree property-based testing (proptest is unavailable in the offline
//! crate cache). Deterministic seed-sweep model: a property is a function
//! of a [`Pcg`] generator; `check` runs it across N derived seeds and
//! reports the failing seed, so failures reproduce exactly.

use crate::util::rng::Pcg;

/// RAII scratch directory for tests and bench fixtures (the offline crate
/// cache has no `tempfile`). Unique per (process, instance); removed on
/// drop, so aborted streams cannot leak segment files between test runs.
pub struct TempDir {
    path: std::path::PathBuf,
}

impl TempDir {
    /// Create `std::env::temp_dir()/aires-<prefix>-<pid>-<n>`.
    pub fn new(prefix: &str) -> TempDir {
        use std::sync::atomic::{AtomicU64, Ordering};
        static NEXT: AtomicU64 = AtomicU64::new(0);
        let n = NEXT.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("aires-{prefix}-{}-{n}", std::process::id()));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    /// The directory's path.
    pub fn path(&self) -> &std::path::Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

/// Number of cases per property (override with `AIRES_PROP_CASES`).
pub fn default_cases() -> u64 {
    std::env::var("AIRES_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run `prop` across `cases` generator streams derived from `seed`.
/// Panics with the failing stream id on the first failure.
pub fn check<F: FnMut(&mut Pcg) -> Result<(), String>>(name: &str, seed: u64, mut prop: F) {
    let cases = default_cases();
    for case in 0..cases {
        let mut rng = Pcg::new(seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!("property {name} failed at seed={seed} stream={case}: {msg}");
        }
    }
}

/// Property helpers for building random instances.
pub mod gen {
    use crate::sparse::spmm::Dense;
    use crate::sparse::{Bsr, Coo, Csc, Csr};
    use crate::util::rng::Pcg;

    /// Density drawn from `[0.1 * max, max]` — never (near-)zero, so
    /// properties over generated matrices cannot pass vacuously on empty
    /// operands. (A plain `rng.f64() * max` draw can produce ~0-density
    /// matrices; deliberately-empty shapes come from [`pathological`].)
    fn floored_density(rng: &mut Pcg, max_density: f64) -> f64 {
        assert!(max_density > 0.0, "max_density must be positive");
        max_density * (0.1 + 0.9 * rng.f64())
    }

    /// Random CSR with shape in [1, max_dim] and density in
    /// [0.1 * max_density, max_density] (see [`floored_density`]).
    pub fn csr(rng: &mut Pcg, max_dim: usize, max_density: f64) -> Csr {
        let nrows = rng.range(1, max_dim + 1);
        let ncols = rng.range(1, max_dim + 1);
        csr_with_shape(rng, nrows, ncols, max_density)
    }

    /// Random CSR with an exact shape (for dimension-compatible operand
    /// pairs in differential tests).
    pub fn csr_with_shape(rng: &mut Pcg, nrows: usize, ncols: usize, max_density: f64) -> Csr {
        let density = floored_density(rng, max_density);
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, (rng.normal() as f32).max(-10.0).min(10.0));
                }
            }
        }
        coo.to_csr()
    }

    /// Random CSC matrix (column-compressed operand, the paper's B side).
    pub fn csc(rng: &mut Pcg, max_dim: usize, max_density: f64) -> Csc {
        csr(rng, max_dim, max_density).to_csc()
    }

    /// Random block-sparse matrix with power-of-two tiles, plus the CSR it
    /// was extracted from (the oracle for block-level properties).
    pub fn bsr(rng: &mut Pcg, max_dim: usize, max_density: f64) -> (Bsr, Csr) {
        let a = csr(rng, max_dim, max_density);
        let bm = 1usize << rng.range(0, 5);
        let bk = 1usize << rng.range(0, 5);
        (Bsr::from_csr(&a, bm, bk), a)
    }

    /// Random dense row-major matrix with standard-normal entries.
    pub fn dense(rng: &mut Pcg, nrows: usize, ncols: usize) -> Dense {
        Dense::from_vec(nrows, ncols, (0..nrows * ncols).map(|_| rng.normal() as f32).collect())
    }

    /// Pathological shapes the kernels must survive: all-empty rows, a
    /// single hub row (RMAT's adversarial case for row-range balance),
    /// 1×N row vectors, N×1 column vectors, and interleaved empty rows.
    pub fn pathological(rng: &mut Pcg, max_dim: usize) -> Csr {
        let n = rng.range(1, max_dim + 1);
        match rng.range(0, 5) {
            0 => Csr::empty(n, rng.range(1, max_dim + 1)),
            1 => {
                // Single hub row: row 0 fully dense, the rest nearly empty.
                let m = rng.range(1, max_dim + 1);
                let mut coo = Coo::new(n, m);
                for c in 0..m {
                    coo.push(0, c as u32, 1.0 + c as f32);
                }
                for r in 1..n {
                    if rng.chance(0.1) {
                        coo.push(r as u32, rng.below(m as u64) as u32, 1.0);
                    }
                }
                coo.to_csr()
            }
            2 => {
                // 1×N row vector (N beyond max_dim to stress wide shapes).
                let m = rng.range(1, max_dim * 4 + 1);
                let mut coo = Coo::new(1, m);
                for c in 0..m {
                    if rng.chance(0.5) {
                        coo.push(0, c as u32, rng.normal() as f32);
                    }
                }
                coo.to_csr()
            }
            3 => {
                // N×1 column vector.
                let rows = rng.range(1, max_dim * 4 + 1);
                let mut coo = Coo::new(rows, 1);
                for r in 0..rows {
                    if rng.chance(0.5) {
                        coo.push(r as u32, 0, rng.normal() as f32);
                    }
                }
                coo.to_csr()
            }
            _ => {
                // Interleaved empty rows (only even rows populated).
                let m = rng.range(1, max_dim + 1);
                let mut coo = Coo::new(n, m);
                for r in (0..n).step_by(2) {
                    for c in 0..m {
                        if rng.chance(0.4) {
                            coo.push(r as u32, c as u32, rng.normal() as f32);
                        }
                    }
                }
                coo.to_csr()
            }
        }
    }

    /// Random square symmetric adjacency (unit weights, no self loops).
    pub fn adjacency(rng: &mut Pcg, max_dim: usize, max_density: f64) -> Csr {
        let n = rng.range(2, max_dim + 1);
        let density = floored_density(rng, max_density);
        let mut edges = Vec::new();
        for i in 0..n as u32 {
            for j in (i + 1)..n as u32 {
                if rng.chance(density) {
                    edges.push((i, j));
                }
            }
        }
        crate::graphgen::edges_to_adjacency(n, &edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 1, |rng| {
            let v = rng.below(10);
            if v < 10 { Ok(()) } else { Err(format!("{v}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property failing")]
    fn check_reports_failures() {
        // Fails on every stream so the panic fires at any AIRES_PROP_CASES
        // setting (a probabilistic trigger breaks under low-case CI runs).
        check("failing", 1, |rng| {
            let v = rng.below(8);
            Err(format!("hit {v}"))
        });
    }

    #[test]
    fn generated_csr_is_valid() {
        check("gen-csr-valid", 2, |rng| {
            gen::csr(rng, 24, 0.4).validate()
        });
    }

    #[test]
    fn generated_csr_honors_density_floor() {
        // The floor exists so differential properties cannot pass
        // vacuously: a 10x10+ matrix at max_density 0.5 keeps >= floor/2
        // expected density; demand at least one stored entry.
        check("gen-csr-density-floor", 3, |rng| {
            let a = gen::csr_with_shape(rng, 16, 16, 0.9);
            if a.nnz() == 0 { Err("vacuously empty generated CSR".into()) } else { Ok(()) }
        });
    }

    #[test]
    fn generated_csc_is_valid() {
        check("gen-csc-valid", 4, |rng| {
            gen::csc(rng, 24, 0.4).validate()
        });
    }

    #[test]
    fn generated_bsr_matches_source_csr() {
        check("gen-bsr-dense", 5, |rng| {
            let (bsr, a) = gen::bsr(rng, 24, 0.3);
            if bsr.to_dense() == a.to_dense() {
                Ok(())
            } else {
                Err(format!("bsr/csr dense mismatch at tiles {}x{}", bsr.bm, bsr.bk))
            }
        });
    }

    #[test]
    fn generated_pathological_shapes_are_valid() {
        check("gen-pathological-valid", 6, |rng| {
            gen::pathological(rng, 24).validate()
        });
    }

    #[test]
    fn robw_parallel_plan_equals_serial_property() {
        use crate::partition::robw::{robw_partition, robw_partition_par};
        use crate::runtime::pool::Pool;
        check("robw_partition_par == robw_partition", 7, |rng| {
            let a =
                if rng.chance(0.25) { gen::pathological(rng, 48) } else { gen::csr(rng, 48, 0.3) };
            let budget = rng.range(1, 2048) as u64;
            let want = robw_partition(&a, budget);
            for threads in [2usize, 4, 8] {
                if robw_partition_par(&a, budget, &Pool::new(threads)) != want {
                    return Err(format!(
                        "plan diverged at {threads} threads (budget={budget}, {}x{})",
                        a.nrows, a.ncols
                    ));
                }
            }
            Ok(())
        });
    }
}
