//! `aires` CLI — the L3 leader entrypoint.
//!
//! Subcommands (in-tree arg parsing; clap unavailable offline):
//!   catalog            print the Table II dataset catalog
//!   features           print the Table I feature matrix
//!   fig3|fig6|fig7|fig8|fig9|table3
//!                      regenerate one paper artifact as markdown
//!   report [--out F]   regenerate the full evaluation report
//!   train [--steps N] [--lr X] [--nodes N] [--train-stream]
//!         [--layers L] [--budget BYTES] [--recompute-policy P]
//!         [--panel-dir DIR] [--checkpoint-dir DIR]
//!                      e2e GCN training: the dense PJRT artifact path
//!                      by default; --train-stream streams the forward
//!                      AND backward pass out of core instead (RoBW
//!                      segments, activation/gradient panels through
//!                      the tiered store, recompute-vs-reload policy P
//!                      in reload|recompute|auto) and verifies every
//!                      step's loss bitwise against the dense CPU
//!                      oracle — no compiled artifacts needed.
//!                      --checkpoint-dir persists a versioned checksummed
//!                      checkpoint after every step and resumes from it
//!                      on the next run: a run killed between steps
//!                      finishes with bitwise-identical final parameters
//!   spgemm [--nodes N] [--budget BYTES] [--prefetch-depth D]
//!                      one out-of-core aggregation through the artifacts,
//!                      verified against the CPU oracle (--segment-dir
//!                      stages from spilled files instead of memory)
//!   segcheck [--nodes N] [--budget BYTES] [--segment-dir DIR]
//!            [--host-cache-bytes N] [--seg-encoding E] [--mmap]
//!                      spill RoBW segments to disk, stream the forward
//!                      pass from the files through the host-cache tier,
//!                      and verify byte-identity against the in-memory
//!                      oracle (no compiled artifacts needed). Every
//!                      disk-staging subcommand honours --seg-encoding
//!                      {raw|packed|auto} (on-disk colidx encoding of the
//!                      spilled segments) and --mmap (zero-copy mapped
//!                      reads instead of copying through read buffers);
//!                      served bytes are identical across every combination
//!   gcnstream [--layers L] [--nodes N] [--budget BYTES]
//!             [--segment-dir DIR] [--panel-dir DIR]
//!                      run an L-layer forward through the cross-layer
//!                      streaming pipeline (one scheduler, no drain at
//!                      layer boundaries; --panel-dir spills intermediate
//!                      feature panels) and verify byte-identity against
//!                      the per-layer sequential oracle (artifact-free)
//!   faultcheck [--nodes N] [--budget BYTES]
//!                      chaos-engineering check of the self-healing
//!                      tiered store (no compiled artifacts needed):
//!                      injects transient I/O faults, a slow read, and
//!                      persistent on-disk corruption, heals them by
//!                      bounded retry and quarantine-and-rebuild, and
//!                      verifies the healed output byte-identical to
//!                      the fault-free oracle; then kills a streamed
//!                      training run between steps and verifies the
//!                      checkpoint-resumed parameters match the
//!                      uninterrupted run bitwise
//!   serve [--scale S] [--feat F] [--budget BYTES] [--tenants N]
//!         [--requests R] [--rate-hz HZ] [--max-batch B] [--out F]
//!                      multi-tenant batched inference under open-loop
//!                      load: one staged pass of the adjacency serves
//!                      every admitted tenant per batch; reports
//!                      per-tenant p50/p99 latency and segments/s
//!                      (--out writes the ServeReport as JSON)
//!   bench ingest --db F [--json F] [--commit C]
//!                      flatten a BENCH_streaming.json emission into the
//!                      append-only perf-trajectory store (one line per
//!                      scenario/metric datapoint, stamped commit+ts)
//!   bench report --db F
//!                      per-scenario min/p50/p99/latest table across all
//!                      stored runs, plus cross-commit trend lines for
//!                      the gated metrics (each run's value and delta
//!                      vs the previous commit; defective lines are
//!                      skipped with a warning, never fatal)
//!   bench gate --db F --max-regress-pct X
//!                      compare the newest run's gated metrics
//!                      (ns/segment, ns/layer, serve p99) against the
//!                      median of all prior runs; exit 1 on any
//!                      regression beyond X% (an empty or single-run
//!                      store passes vacuously — it seeds the baseline)
//!   prep DATASET       one-time RoBW preprocessing cost estimate

use aires::config::Config;
use aires::coordinator::report;
use aires::coordinator::*;
use aires::runtime::pool::Pool;
use aires::util::rng::Pcg;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

/// Report a malformed invocation and exit with the conventional usage
/// code (2). Flag mistakes must be *usage errors*, not `expect()` panics
/// with a backtrace.
fn usage_fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    eprintln!("run `aires` with no arguments for usage, or see README.md");
    std::process::exit(2);
}

/// Value of `--key V`; a flag present without a value is a usage error
/// (previously it was silently ignored).
fn flag_value(args: &[String], key: &str) -> Option<String> {
    let i = args.iter().position(|a| a == key)?;
    match args.get(i + 1) {
        Some(v) => Some(v.clone()),
        None => usage_fail(&format!("{key} requires a value")),
    }
}

/// Parsed value of `--key V`; a parse failure is a usage error naming the
/// flag and the offending input.
fn parsed_flag<T: std::str::FromStr>(args: &[String], key: &str, what: &str) -> Option<T> {
    flag_value(args, key).map(|v| {
        v.parse::<T>()
            .unwrap_or_else(|_| usage_fail(&format!("{key} expects {what}, got {v:?}")))
    })
}

/// Phase II staging configuration shared by the streaming subcommands
/// (`spgemm`, `gcnstream`): in-memory slicing by default, disk-backed via
/// `open_or_spill_encoded` when a segment directory is selected (colidx
/// encoding per `--seg-encoding`), recycled when the buffer pool is
/// enabled, zero-copy mapped when `--mmap` is set. A spill failure is a
/// fatal runtime error (exit 1), not a usage error.
#[allow(clippy::too_many_arguments)]
fn staging_for(
    a_hat: &aires::sparse::Csr,
    budget: u64,
    segment_dir: &Option<String>,
    host_cache_bytes: u64,
    prefetch_depth: usize,
    recycle_pool: &Option<std::sync::Arc<aires::runtime::BufferPool>>,
    heal: aires::runtime::HealPolicy,
    mmap: bool,
    seg_encoding: aires::sparse::segio::SegEncoding,
) -> aires::gcn::oocgcn::StagingConfig {
    use aires::gcn::oocgcn::StagingConfig;
    let mut staging = match segment_dir {
        None => StagingConfig::depth(prefetch_depth),
        Some(dir) => {
            let segs = aires::partition::robw::robw_partition(a_hat, budget);
            let store = aires::runtime::SegmentStore::open_or_spill_encoded(
                a_hat,
                &segs,
                std::path::Path::new(dir),
                host_cache_bytes,
                seg_encoding,
            )
            .unwrap_or_else(|e| {
                eprintln!("error: spilling segments to {dir}: {e}");
                std::process::exit(1);
            });
            StagingConfig::disk(std::sync::Arc::new(store), prefetch_depth)
        }
    };
    if let Some(rp) = recycle_pool {
        staging = staging.with_recycle(rp.clone());
    }
    staging.with_heal(heal).with_mmap(mmap)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    // Every subcommand honours --config <file> (cost-model + workload
    // overrides; see rust/src/config.rs for the schema).
    let cfg = match flag_value(&args, "--config") {
        Some(path) => Config::from_file(&path)
            .unwrap_or_else(|e| usage_fail(&format!("--config {path}: {e}"))),
        None => Config::default(),
    };
    // Every subcommand honours --threads N (0 = one per hardware thread):
    // it sizes the runtime::pool the real kernels run on, and mirrors the
    // resolved worker count into the simulator's host-compute hook so the
    // modelled experiments and the executed kernels agree.
    let threads_flag: Option<usize> =
        parsed_flag(&args, "--threads", "a non-negative integer (0 = auto)");
    let pool = Pool::new(threads_flag.unwrap_or(cfg.threads));
    // --prefetch-depth N sizes the executed Phase II staging pipeline
    // (1 = serial staging, 2 = double buffering; output is byte-identical
    // at every depth). CLI flag wins over the config's `prefetch_depth`;
    // neither set -> the double-buffering default of 2. A requested depth
    // of 0 is clamped to 1 *with a warning* (previously a silent floor).
    let prefetch_flag: Option<usize> =
        parsed_flag(&args, "--prefetch-depth", "a positive integer (1 = serial staging)")
            .map(|d: usize| {
                if d == 0 {
                    eprintln!(
                        "warning: --prefetch-depth 0 is not a valid depth; \
                         using 1 (serial staging)"
                    );
                    1
                } else {
                    d
                }
            });
    let prefetch_depth = prefetch_flag.unwrap_or_else(|| cfg.resolved_prefetch_depth());
    // Disk-backed staging surface: --segment-dir selects the spill/serve
    // directory (config key `segment_dir` as fallback; neither = in-memory
    // staging) and --host-cache-bytes bounds the host-RAM tier between
    // the segment files and the GpuMem ledger (0 = no cache; unset =
    // unbounded).
    let segment_dir: Option<String> =
        flag_value(&args, "--segment-dir").or_else(|| cfg.segment_dir.clone());
    let host_cache_bytes: u64 =
        parsed_flag(&args, "--host-cache-bytes", "a byte count (0 = no host cache)")
            .or(cfg.host_cache_bytes)
            .unwrap_or(aires::runtime::segstore::UNBOUNDED_CACHE);
    // Storage engine v2 surface: --mmap maps spilled segment and panel
    // files into the address space instead of copying them through read
    // buffers (config key `mmap_segments` as fallback), and
    // --seg-encoding selects the on-disk colidx encoding for spilled
    // RoBW segments: raw (the seed layout), packed (delta + bit-packed),
    // or auto (per segment, smaller file wins; config key `seg_encoding`
    // as fallback, default raw). Served bytes are identical across every
    // combination; only file sizes and copy traffic change.
    let mmap: bool = args.iter().any(|a| a == "--mmap") || cfg.mmap_segments == Some(true);
    let seg_encoding: aires::sparse::segio::SegEncoding =
        parsed_flag(&args, "--seg-encoding", "one of raw, packed, auto")
            .or_else(|| {
                // The config loader already rejected unknown encoding
                // strings, so this re-parse cannot fail.
                cfg.seg_encoding
                    .as_ref()
                    .map(|s| s.parse().expect("validated at config load"))
            })
            .unwrap_or_default();
    // --recycle-cap-bytes bounds the staging buffer-recycle pool
    // (`runtime::recycle`): staged-segment scratch circulates through the
    // pipeline instead of being reallocated per segment. 0 disables
    // recycling (the fresh-allocation baseline); unset = the default cap.
    // Output is byte-identical either way.
    let recycle_cap_bytes: u64 =
        parsed_flag(&args, "--recycle-cap-bytes", "a byte count (0 = no buffer recycling)")
            .or(cfg.recycle_cap_bytes)
            .unwrap_or(aires::runtime::recycle::DEFAULT_RECYCLE_CAP);
    let recycle_pool = (recycle_cap_bytes > 0)
        .then(|| std::sync::Arc::new(aires::runtime::BufferPool::new(recycle_cap_bytes)));
    // Self-healing tiered-store reads (`runtime::heal`): --retry-max
    // bounds per-read retries of transient I/O faults (0 = fail fast, the
    // historical behaviour) and --retry-backoff-ios sets the deterministic
    // virtual-time backoff charged between attempts, in multiples of the
    // faulted file's size. Any non-zero retry budget also arms
    // quarantine-and-rebuild for persistent segment corruption. Config
    // keys `retry_max` / `retry_backoff_ios` as fallback. Healed output
    // is byte-identical to a fault-free run; only HealStats differ.
    let retry_max: usize =
        parsed_flag(&args, "--retry-max", "a retry count (0 = fail fast)")
            .or(cfg.retry_max)
            .unwrap_or(0);
    let retry_backoff_ios: u64 = parsed_flag(
        &args,
        "--retry-backoff-ios",
        "a backoff charge in file-sized I/Os (0 = no charge)",
    )
    .or(cfg.retry_backoff_ios)
    .unwrap_or(0);
    let heal = aires::runtime::HealPolicy {
        retry_max,
        backoff_ios: retry_backoff_ios,
        rebuild: retry_max > 0,
    };
    let mut cm = cfg.cost_model.clone();
    // --threads always wins; otherwise the config's `threads` key flows
    // into the hook too, unless the config pinned cost_model.cpu_threads
    // away from the serial default (a pin to exactly 1.0 is
    // indistinguishable from "unset" and gets mirrored — pin any other
    // value, e.g. 1.01, to decouple the simulated host from the pool).
    if threads_flag.is_some() || cm.cpu_threads == 1.0 {
        cm.cpu_threads = pool.threads() as f64;
    }
    // The RoBW partition scan only discounts when the parallel planner
    // (`robw_partition_par`) is the selected code path — i.e. the pool is
    // actually parallel (same pin escape hatch as cpu_threads).
    if pool.threads() > 1 && cm.partition_threads == 1.0 {
        cm.partition_threads = pool.threads() as f64;
    }
    // The simulator's overlap hook follows the staging depth whenever one
    // was *requested* (CLI flag or config key) — executed and modelled
    // Phase II then move together. Untouched, the CostModel stays the
    // depth-1 calibration baseline, so every figure is unchanged by
    // default (the execution-side default of 2 never leaks in on its own).
    // A cost_model.prefetch_depth pinned away from 1.0 in the config wins
    // over the mirror (same pin escape hatch as cpu_threads).
    if (prefetch_flag.is_some() || cfg.prefetch_depth.is_some()) && cm.prefetch_depth == 1.0 {
        cm.prefetch_depth = prefetch_depth as f64;
    }
    let cm = cm;

    match cmd {
        "catalog" => print!("{}", report::table2_md()),
        "features" => print!("{}", report::table1_md()),
        "fig3" => print!("{}", report::fig3_md(&fig3_merging(&cm))),
        "fig6" => print!("{}", report::fig6_md(&fig6_speedup(&cm))),
        "fig7" => print!("{}", report::fig7_md(&fig7_io_breakdown(&cm))),
        "fig8" => print!("{}", report::fig8_md(&fig8_bandwidth(&cm))),
        "fig9" => {
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            print!("{}", report::fig9_md(&fig9_feature_size(&cm, &ds)));
        }
        "table3" => print!("{}", report::table3_md(&table3_memcap(&cm))),
        "config-dump" => println!("{}", cfg.to_json()),
        "trace" => {
            // Export one scheduler's simulated epoch as a Chrome trace.
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            let sched = arg_value(&args, "--scheduler").unwrap_or_else(|| "AIRES".into());
            let out = arg_value(&args, "--out").unwrap_or_else(|| "trace.json".into());
            let d = aires::graphgen::catalog::by_name(&ds).expect("unknown dataset");
            let w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
            let r = aires::sched::all_schedulers()
                .iter()
                .find(|s| s.name().eq_ignore_ascii_case(&sched))
                .expect("unknown scheduler")
                .run_epoch(&w, &cm);
            match r.makespan_s {
                Some(t) => {
                    std::fs::write(&out, aires::memsim::trace::chrome_trace_log(&r.log))
                        .expect("write trace");
                    println!("{ds}/{sched}: {t:.2}s epoch, {} ops -> {out} (open in chrome://tracing)", r.log.len());
                }
                None => println!("{ds}/{sched}: OOM — {}", r.oom.unwrap()),
            }
        }
        "sweep" => {
            // Latency sweep over memory constraints for one dataset.
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            let points: usize = parsed_flag(&args, "--points", "a point count").unwrap_or(8);
            let d = aires::graphgen::catalog::by_name(&ds).expect("unknown dataset");
            println!("{:>9} {:>11} {:>9} {:>9} {:>9}", "cap (GB)", "MaxMemory", "UCG", "ETC", "AIRES");
            for i in 0..points {
                let cap = d.memory_constraint_gb * (1.0 - i as f64 / points as f64 * 0.7);
                let mut w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
                w.gpu_mem_bytes = (cap * 1e9) as u64;
                let cells: Vec<String> = aires::sched::all_schedulers()
                    .iter()
                    .map(|s| {
                        s.run_epoch(&w, &cm)
                            .makespan_s
                            .map_or("OOM".into(), |t| format!("{t:.2}s"))
                    })
                    .collect();
                println!("{:>9.1} {:>11} {:>9} {:>9} {:>9}", cap, cells[0], cells[1], cells[2], cells[3]);
            }
        }
        "report" => {
            let text = report::full_report(&cm);
            match arg_value(&args, "--out") {
                Some(path) => {
                    std::fs::write(&path, &text).expect("write report");
                    println!("wrote {path}");
                }
                None => print!("{text}"),
            }
        }
        "prep" => {
            let name = args.get(1).cloned().unwrap_or_else(|| "kP1a".into());
            let d = aires::graphgen::catalog::by_name(&name).expect("unknown dataset");
            let w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
            let t = aires::sched::Aires::prep_time(&w, &cm);
            println!(
                "{name}: one-time RoBW preprocessing (NVMe load + CPU partition): {}",
                aires::util::human_secs(t)
            );
        }
        "train" => {
            // --steps 0 is clamped to 1 with a warning: both trainers
            // treat a zero-step run as a typed error (no losses to
            // report), and the CLI convention for 0-valued count flags
            // is warn-and-clamp (same as --prefetch-depth 0).
            let steps: usize = parsed_flag(&args, "--steps", "a step count")
                .map(|s: usize| {
                    if s == 0 {
                        eprintln!("warning: --steps 0 trains nothing; using 1");
                        1
                    } else {
                        s
                    }
                })
                .unwrap_or(100);
            let lr: f32 = parsed_flag(&args, "--lr", "a learning rate").unwrap_or(2.0);
            let nodes: usize = parsed_flag(&args, "--nodes", "a node count").unwrap_or(1024);
            let stream =
                args.iter().any(|a| a == "--train-stream") || cfg.train_stream == Some(true);
            if !stream {
                // Dense artifact path: runtime failures are exit-1
                // errors naming the failing stage (previously `expect`
                // panics with a backtrace — the last CLI path on the
                // old convention).
                let mut exec = aires::runtime::Executor::from_env().unwrap_or_else(|e| {
                    eprintln!("error: loading PJRT artifacts: {e}");
                    eprintln!("hint: `train --train-stream` needs no compiled artifacts");
                    std::process::exit(1);
                });
                let mut rng = Pcg::seed(42);
                let g = aires::graphgen::kmer::generate(&mut rng, nodes, 3.2);
                let mut tr = aires::gcn::Trainer::new(&exec, &g, 42).unwrap_or_else(|e| {
                    eprintln!("error: binding the train-step artifact: {e}");
                    std::process::exit(1);
                });
                println!(
                    "training 2-layer GCN (n={}, f0={}, h={}, c={}) for {steps} steps",
                    tr.n, tr.f0, tr.hidden, tr.classes
                );
                for step in 0..steps {
                    let loss = tr.step(&mut exec, lr).unwrap_or_else(|e| {
                        eprintln!("error: training step {step}: {e}");
                        std::process::exit(1);
                    });
                    if step % 10 == 0 || step + 1 == steps {
                        println!("step {step:4}  loss {loss:.4}");
                    }
                }
            } else {
                // Streamed out-of-core path (artifact-free): the
                // forward AND backward pass stream the concatenated
                // RoBW plan, activations and gradients ride the panel
                // tier, and every step's loss is checked bitwise
                // against the dense CPU oracle before the next step.
                use aires::gcn::train_stream::{dense_step_oracle, synthetic_labels};
                use aires::gcn::{RecomputePolicy, StreamedTrainer, TrainStreamConfig};
                use aires::memsim::GpuMem;
                use aires::runtime::PanelStore;
                use aires::sparse::spmm::Dense;
                use aires::util::Stopwatch;

                let budget: u64 =
                    parsed_flag(&args, "--budget", "a byte budget").unwrap_or(4096);
                let layers_n: usize =
                    parsed_flag(&args, "--layers", "a positive layer count (the model depth)")
                        .map(|l: usize| {
                            if l == 0 {
                                eprintln!(
                                    "warning: --layers 0 is not a valid model depth; \
                                     using 1 (single layer)"
                                );
                                1
                            } else {
                                l
                            }
                        })
                        .unwrap_or((cfg.layers as usize).max(1));
                let policy: RecomputePolicy = parsed_flag(
                    &args,
                    "--recompute-policy",
                    "one of reload, recompute, auto",
                )
                .or_else(|| {
                    // The config loader already rejected unknown policy
                    // strings, so this re-parse cannot fail.
                    cfg.recompute_policy
                        .as_ref()
                        .map(|s| s.parse().expect("validated at config load"))
                })
                .unwrap_or(RecomputePolicy::Auto);

                let (f0, classes) = (16usize, 4usize);
                let mut rng = Pcg::seed(42);
                let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.2);
                let a_hat = aires::sparse::norm::normalize_adjacency(&a);
                let x = Dense::from_vec(
                    nodes,
                    f0,
                    (0..nodes * f0).map(|_| rng.normal() as f32).collect(),
                );
                let layers: Vec<aires::gcn::OocGcnLayer> = (0..layers_n)
                    .map(|l| {
                        let out = if l + 1 == layers_n { classes } else { f0 };
                        aires::gcn::OocGcnLayer {
                            w: Dense::from_vec(
                                f0,
                                out,
                                (0..f0 * out).map(|_| (rng.normal() * 0.3) as f32).collect(),
                            ),
                            b: vec![0.0; out],
                            relu: l + 1 < layers_n,
                            seg_budget: budget,
                        }
                    })
                    .collect();
                let labels = synthetic_labels(&x, classes, &mut rng);

                let staging = staging_for(
                    &a_hat,
                    budget,
                    &segment_dir,
                    host_cache_bytes,
                    prefetch_depth,
                    &recycle_pool,
                    heal,
                    mmap,
                    seg_encoding,
                );
                // Panel tier for spilled activations, aggregated inputs
                // and the rotating gradient hand-off. Cacheless: every
                // spilled panel is read back exactly once per step, so
                // caching would pin in host RAM exactly what spilling
                // exists to evict. An ephemeral scratch dir when no
                // --panel-dir / config `panel_dir` is given (same
                // convention as segcheck's segment scratch).
                let (panel_path, ephemeral) =
                    match flag_value(&args, "--panel-dir").or_else(|| cfg.panel_dir.clone()) {
                        Some(d) => (std::path::PathBuf::from(d), false),
                        None => (
                            std::env::temp_dir()
                                .join(format!("aires-train-{}", std::process::id())),
                            true,
                        ),
                    };
                let panels = std::sync::Arc::new(
                    PanelStore::new(&panel_path, 0).unwrap_or_else(|e| {
                        eprintln!("error: opening panel dir {}: {e}", panel_path.display());
                        std::process::exit(1);
                    }),
                );
                let tcfg = TrainStreamConfig::new(staging, panels).with_policy(policy);

                let mut oracle_layers = layers.clone();
                let mut tr =
                    StreamedTrainer::new(layers, labels.clone()).unwrap_or_else(|e| {
                        eprintln!("error: building the streamed trainer: {e}");
                        std::process::exit(1);
                    });
                println!(
                    "streamed training: {layers_n}-layer GCN (n={nodes}, f0={f0}, \
                     c={classes}) for {steps} steps, budget {budget}, policy {policy}"
                );
                // --checkpoint-dir DIR (config key `checkpoint_dir` as
                // fallback): persist a versioned, checksummed checkpoint
                // after every step (write-temp-then-rename, so a kill
                // mid-write never corrupts the published file) and resume
                // from it on start-up. The dense oracle replays the
                // completed steps so the bitwise loss check keeps holding
                // after a resume.
                let checkpoint_dir: Option<std::path::PathBuf> =
                    flag_value(&args, "--checkpoint-dir")
                        .or_else(|| cfg.checkpoint_dir.clone())
                        .map(std::path::PathBuf::from);
                let mut start_step = 0usize;
                if let Some(dir) = &checkpoint_dir {
                    match aires::gcn::checkpoint::load(dir) {
                        Err(e) => {
                            eprintln!(
                                "error: loading checkpoint from {}: {e}",
                                dir.display()
                            );
                            std::process::exit(1);
                        }
                        Ok(None) => {}
                        Ok(Some(ck)) => {
                            let done = tr.restore(&ck).unwrap_or_else(|e| {
                                eprintln!(
                                    "error: restoring checkpoint from {}: {e}",
                                    dir.display()
                                );
                                std::process::exit(1);
                            });
                            for s in 0..done {
                                dense_step_oracle(
                                    &mut oracle_layers,
                                    &a_hat,
                                    &x,
                                    &labels,
                                    lr,
                                )
                                .unwrap_or_else(|e| {
                                    eprintln!("error: replaying oracle step {s}: {e}");
                                    std::process::exit(1);
                                });
                            }
                            start_step = done.min(steps as u64) as usize;
                            println!(
                                "resumed from checkpoint: {done} step(s) already complete"
                            );
                        }
                    }
                }
                let mut mem = GpuMem::new(1 << 30);
                let sw = Stopwatch::start();
                let mut last_rep = None;
                for step in start_step..steps {
                    let rep = tr
                        .step(&a_hat, &x, &mut mem, &pool, &tcfg, lr)
                        .unwrap_or_else(|e| {
                            eprintln!("error: streamed training step {step}: {e}");
                            std::process::exit(1);
                        });
                    let want = dense_step_oracle(&mut oracle_layers, &a_hat, &x, &labels, lr)
                        .unwrap_or_else(|e| {
                            eprintln!("error: dense oracle step {step}: {e}");
                            std::process::exit(1);
                        });
                    if rep.loss.to_bits() != want.to_bits() {
                        eprintln!(
                            "error: streamed loss DIVERGED from the dense oracle at \
                             step {step}: {} vs {want}",
                            rep.loss
                        );
                        std::process::exit(1);
                    }
                    if step % 10 == 0 || step + 1 == steps {
                        println!("step {step:4}  loss {:.4}", rep.loss);
                    }
                    if let Some(dir) = &checkpoint_dir {
                        let ck = aires::gcn::Checkpoint {
                            step: (step + 1) as u64,
                            policy,
                            rng: rng.state(),
                            losses: tr.losses.clone(),
                            layers: tr.layers.clone(),
                        };
                        aires::gcn::checkpoint::save(dir, &ck).unwrap_or_else(|e| {
                            eprintln!(
                                "error: publishing checkpoint to {}: {e}",
                                dir.display()
                            );
                            std::process::exit(1);
                        });
                    }
                    last_rep = Some(rep);
                }
                let wall = sw.secs();
                if let Some(rep) = &last_rep {
                    let fwd = rep.forward.merged();
                    println!(
                        "per step: {} forward + {} backward segments (policy {}), \
                         activation panels read {}, aggregation spill {} / read {}, \
                         gradient spill {} / read {}",
                        fwd.segments,
                        rep.backward_segments,
                        rep.policy,
                        aires::util::human_bytes(rep.act_read_bytes),
                        aires::util::human_bytes(rep.agg_spill_bytes),
                        aires::util::human_bytes(rep.agg_read_bytes),
                        aires::util::human_bytes(rep.grad_spill_bytes),
                        aires::util::human_bytes(rep.grad_read_bytes),
                    );
                    let ran = steps - start_step;
                    println!(
                        "ns_per_step {}  ({:.2}s wall for {ran} steps, peak {})",
                        (wall * 1e9 / ran as f64) as u64,
                        wall,
                        aires::util::human_bytes(rep.peak_gpu_bytes)
                    );
                    if rep.heal.any() {
                        println!(
                            "heal: {} injected, {} retries, {} slow reads, \
                             {} quarantined / {} rebuilt, backoff {}",
                            rep.heal.injected,
                            rep.heal.retries,
                            rep.heal.slow_reads,
                            rep.heal.quarantined,
                            rep.heal.rebuilt,
                            aires::util::human_bytes(rep.heal.backoff_bytes)
                        );
                    }
                } else {
                    println!(
                        "checkpoint already covers all {steps} step(s); nothing left to train"
                    );
                }
                if let Some(rp) = &recycle_pool {
                    let st = rp.stats();
                    println!(
                        "recycle pool: {} hits / {} misses, {} returned ({} dropped by the cap)",
                        st.hits, st.misses, st.returns, st.drops
                    );
                }
                if ephemeral {
                    let _ = std::fs::remove_dir_all(&panel_path);
                }
                // Deterministic parameter fingerprint (FNV-1a 64 over the
                // exact f32 bit patterns): two runs that print the same
                // hash hold bitwise-identical parameters — the line the
                // resume e2e test compares across a kill/restart.
                let mut h = aires::sparse::segio::Fnv64::new();
                for l in &tr.layers {
                    for v in &l.w.data {
                        h.update(&v.to_bits().to_le_bytes());
                    }
                    for v in &l.b {
                        h.update(&v.to_bits().to_le_bytes());
                    }
                }
                println!("final params fnv64: 0x{:016x}", h.finish());
                println!("streamed loss matches dense oracle: OK");
            }
        }
        "spgemm" => {
            let nodes: usize = parsed_flag(&args, "--nodes", "a node count").unwrap_or(600);
            let budget: u64 = parsed_flag(&args, "--budget", "a byte budget").unwrap_or(8192);
            let mut exec = aires::runtime::Executor::from_env().expect("executor");
            let mut rng = Pcg::seed(7);
            let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let x = aires::sparse::spmm::Dense::from_vec(
                nodes,
                64,
                (0..nodes * 64).map(|_| rng.normal() as f32).collect(),
            );
            let layer = aires::gcn::OocGcnLayer {
                w: aires::sparse::spmm::Dense::from_vec(
                    64,
                    64,
                    (0..64 * 64).map(|_| (rng.normal() * 0.2) as f32).collect(),
                ),
                b: vec![0.0; 64],
                relu: true,
                seg_budget: budget,
            };
            let mut mem = aires::memsim::GpuMem::new(256 << 20);
            // --segment-dir switches staging from in-memory slicing to
            // real file reads through the host-cache tier.
            let staging = staging_for(
                &a_hat,
                budget,
                &segment_dir,
                host_cache_bytes,
                prefetch_depth,
                &recycle_pool,
                heal,
                mmap,
                seg_encoding,
            );
            let (out, rep) = layer
                .forward_staged(&mut exec, &a_hat, &x, &mut mem, &pool, &staging)
                .expect("forward");
            println!(
                "out-of-core aggregation: {} segments (prefetch depth {}), ~{} artifact calls, peak {}, H2D {}",
                rep.segments,
                rep.prefetch_depth,
                rep.artifact_calls_estimate,
                aires::util::human_bytes(rep.peak_gpu_bytes),
                aires::util::human_bytes(rep.h2d_bytes)
            );
            if segment_dir.is_some() {
                println!(
                    "disk-backed staging: {} from disk, {} cache hits / {} misses",
                    aires::util::human_bytes(rep.disk_bytes),
                    rep.cache_hits,
                    rep.cache_misses
                );
            }
            // Verify against the CPU oracle.
            let want = aires::gcn::model::dense_affine(
                &aires::sparse::spmm::spmm(&a_hat, &x),
                &layer.w,
                &layer.b,
                true,
            );
            let diff = out.max_abs_diff(&want);
            println!("max |accelerator - oracle| = {diff:.2e} -> {}", if diff < 1e-3 { "OK" } else { "MISMATCH" });
        }
        "segcheck" => {
            // Disk-backed staging surface that needs no compiled
            // artifacts: generate a graph, spill its RoBW segments to
            // --segment-dir (a scratch dir when unset), stream the forward
            // pass from the files through the host-cache tier, and verify
            // byte-identity against the in-memory serial oracle.
            use aires::gcn::oocgcn::StagingConfig;
            use aires::memsim::GpuMem;
            use aires::sparse::spmm::Dense;

            let nodes: usize =
                parsed_flag(&args, "--nodes", "a node count").unwrap_or(400);
            let budget: u64 =
                parsed_flag(&args, "--budget", "a byte budget").unwrap_or(4096);
            let mut rng = Pcg::seed(13);
            let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let x = Dense::from_vec(
                nodes,
                32,
                (0..nodes * 32).map(|_| rng.normal() as f32).collect(),
            );
            let layer = aires::gcn::OocGcnLayer {
                w: Dense::from_vec(
                    32,
                    32,
                    (0..32 * 32).map(|_| (rng.normal() * 0.2) as f32).collect(),
                ),
                b: vec![0.0; 32],
                relu: true,
                seg_budget: budget,
            };
            let (dir, ephemeral) = match &segment_dir {
                Some(d) => (std::path::PathBuf::from(d), false),
                None => (
                    std::env::temp_dir().join(format!("aires-segcheck-{}", std::process::id())),
                    true,
                ),
            };
            let segs = aires::partition::robw::robw_partition(&a_hat, budget);
            let store = aires::runtime::SegmentStore::open_or_spill_encoded(
                &a_hat,
                &segs,
                &dir,
                host_cache_bytes,
                seg_encoding,
            )
            .unwrap_or_else(|e| {
                eprintln!("error: spilling segments to {}: {e}", dir.display());
                std::process::exit(1);
            });
            let spilled: u64 = (0..store.len()).map(|i| store.meta(i).file_bytes).sum();
            println!(
                "spilled {} segments ({}, {seg_encoding} encoding) to {}",
                store.len(),
                aires::util::human_bytes(spilled),
                dir.display()
            );
            let mut staging =
                StagingConfig::disk(std::sync::Arc::new(store), prefetch_depth);
            if let Some(rp) = &recycle_pool {
                staging = staging.with_recycle(rp.clone());
            }
            let staging = staging.with_heal(heal).with_mmap(mmap);
            let mut mem = GpuMem::new(1 << 30);
            let (got, rep) = layer
                .forward_cpu(&a_hat, &x, &mut mem, &pool, &staging)
                .expect("disk-backed forward");
            let mut mem2 = GpuMem::new(1 << 30);
            let (want, _) = layer
                .forward_cpu(&a_hat, &x, &mut mem2, &Pool::serial(), &StagingConfig::serial())
                .expect("oracle forward");
            println!(
                "streamed {} segments (prefetch depth {}): {} from disk, {} cache hits / {} misses",
                rep.segments,
                rep.prefetch_depth,
                aires::util::human_bytes(rep.disk_bytes),
                rep.cache_hits,
                rep.cache_misses
            );
            if ephemeral {
                let _ = std::fs::remove_dir_all(&dir);
            }
            if let Some(rp) = &recycle_pool {
                let st = rp.stats();
                println!(
                    "recycle pool: {} hits / {} misses, {} returned ({} dropped by the cap)",
                    st.hits, st.misses, st.returns, st.drops
                );
            }
            if got == want {
                println!("disk-backed output byte-identical to the in-memory oracle: OK");
            } else {
                eprintln!("error: disk-backed output DIVERGED from the in-memory oracle");
                std::process::exit(1);
            }
        }
        "faultcheck" => {
            // Chaos-engineering surface for the self-healing tiered store
            // (no compiled artifacts needed). Three scenarios, all checked
            // against the house determinism rule — a healed run serves
            // bytes identical to the fault-free oracle, only HealStats
            // differ:
            //   1. transient I/O faults + a slow read, healed by bounded
            //      retry with deterministic virtual-time backoff;
            //   2. persistent on-disk corruption, healed by quarantining
            //      the segment file and rebuilding it from the source
            //      matrix + the RoBW plan;
            //   3. a streamed training run killed between steps, resumed
            //      from its checkpoint to bitwise-identical parameters.
            use aires::gcn::oocgcn::StagingConfig;
            use aires::gcn::train_stream::synthetic_labels;
            use aires::gcn::{OocGcnLayer, StreamedTrainer, TrainStreamConfig};
            use aires::memsim::GpuMem;
            use aires::runtime::{
                FaultKind, FaultPlan, FaultSpec, HealPolicy, PanelStore, SegmentStore, Tier,
            };
            use aires::sparse::spmm::Dense;

            let nodes: usize = parsed_flag(&args, "--nodes", "a node count").unwrap_or(240);
            let budget: u64 = parsed_flag(&args, "--budget", "a byte budget").unwrap_or(4096);
            let mut rng = Pcg::seed(31);
            let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let x = Dense::from_vec(
                nodes,
                24,
                (0..nodes * 24).map(|_| rng.normal() as f32).collect(),
            );
            let layer = OocGcnLayer {
                w: Dense::from_vec(
                    24,
                    24,
                    (0..24 * 24).map(|_| (rng.normal() * 0.2) as f32).collect(),
                ),
                b: vec![0.05; 24],
                relu: true,
                seg_budget: budget,
            };
            let scratch = std::env::temp_dir()
                .join(format!("aires-faultcheck-{}", std::process::id()));
            let fatal = |msg: String| -> ! {
                eprintln!("error: {msg}");
                std::process::exit(1);
            };
            // Cacheless store: every read hits the file, so injected and
            // real on-disk faults cannot be masked by the host-RAM tier.
            let segs = aires::partition::robw::robw_partition(&a_hat, budget);
            let store = std::sync::Arc::new(
                SegmentStore::open_or_spill(&a_hat, &segs, &scratch.join("segments"), 0)
                    .unwrap_or_else(|e| fatal(format!("spilling segments: {e}"))),
            );
            println!(
                "faultcheck: {nodes} nodes, {} segments (budget {budget}, \
                 prefetch depth {prefetch_depth})",
                store.len()
            );

            // Fault-free oracle pass.
            let mut mem0 = GpuMem::new(1 << 30);
            let (want, _) = layer
                .forward_cpu(
                    &a_hat,
                    &x,
                    &mut mem0,
                    &pool,
                    &StagingConfig::disk(store.clone(), prefetch_depth),
                )
                .unwrap_or_else(|e| fatal(format!("fault-free oracle forward: {e}")));
            let mut balanced = mem0.used == 0;

            // Scenario 1: transient faults + a slow read, healed by retry.
            let healp = HealPolicy { retry_max: 3, backoff_ios: 2, rebuild: true };
            let plan = std::sync::Arc::new(FaultPlan::new(vec![
                FaultSpec {
                    tier: Tier::Segment,
                    index: 0,
                    kind: FaultKind::TransientIo { times: 2 },
                },
                FaultSpec {
                    tier: Tier::Segment,
                    index: store.len() - 1,
                    kind: FaultKind::SlowRead { times: 1, charge_bytes: 1 << 16 },
                },
            ]));
            let staging1 = StagingConfig::disk(store.clone(), prefetch_depth)
                .with_heal(healp)
                .with_chaos(plan);
            let mut mem1 = GpuMem::new(1 << 30);
            let (got1, rep1) = layer
                .forward_cpu(&a_hat, &x, &mut mem1, &pool, &staging1)
                .unwrap_or_else(|e| fatal(format!("healing transient faults: {e}")));
            balanced &= mem1.used == 0;
            println!(
                "scenario 1 (transient faults): {} injected, {} retries, {} slow reads, \
                 backoff {}",
                rep1.heal.injected,
                rep1.heal.retries,
                rep1.heal.slow_reads,
                aires::util::human_bytes(rep1.heal.backoff_bytes)
            );
            let s1 = got1 == want && rep1.heal.retries > 0 && rep1.heal.slow_reads == 1;

            // Scenario 2: persistent corruption, quarantine + rebuild.
            // Flip the victim file's last payload byte on disk — the
            // payload checksum rejects it on every subsequent read, so
            // retries alone cannot heal it.
            let victim = store.len() - 1;
            let vpath = store.meta(victim).path.clone();
            let mut bytes = std::fs::read(&vpath)
                .unwrap_or_else(|e| fatal(format!("reading {}: {e}", vpath.display())));
            *bytes.last_mut().expect("segment files are never empty") ^= 0xff;
            std::fs::write(&vpath, &bytes)
                .unwrap_or_else(|e| fatal(format!("corrupting {}: {e}", vpath.display())));
            let staging2 =
                StagingConfig::disk(store.clone(), prefetch_depth).with_heal(healp);
            let mut mem2 = GpuMem::new(1 << 30);
            let (got2, rep2) = layer
                .forward_cpu(&a_hat, &x, &mut mem2, &pool, &staging2)
                .unwrap_or_else(|e| fatal(format!("healing on-disk corruption: {e}")));
            balanced &= mem2.used == 0;
            let mut qname = vpath.as_os_str().to_owned();
            qname.push(".quarantined");
            let quarantined_file = std::path::PathBuf::from(qname).exists();
            println!(
                "scenario 2 (corruption): {} quarantined, {} rebuilt, \
                 quarantine file present: {quarantined_file}",
                rep2.heal.quarantined, rep2.heal.rebuilt
            );
            let s2 = got2 == want
                && rep2.heal.quarantined == 1
                && rep2.heal.rebuilt == 1
                && quarantined_file;
            if s1 && s2 {
                println!("healed output matches oracle: OK");
            } else {
                let _ = std::fs::remove_dir_all(&scratch);
                fatal("healed output DIVERGED from the fault-free oracle".into());
            }

            // Scenario 3: kill a streamed training run between steps and
            // resume it from the checkpoint; final parameters must match
            // the uninterrupted run bitwise.
            let (f0, classes, steps, lr) = (12usize, 3usize, 4usize, 1.0f32);
            let mut trng = Pcg::seed(53);
            let tx = Dense::from_vec(
                nodes,
                f0,
                (0..nodes * f0).map(|_| trng.normal() as f32).collect(),
            );
            let tlayers: Vec<OocGcnLayer> = (0..2)
                .map(|l| {
                    let out = if l == 1 { classes } else { f0 };
                    OocGcnLayer {
                        w: Dense::from_vec(
                            f0,
                            out,
                            (0..f0 * out).map(|_| (trng.normal() * 0.3) as f32).collect(),
                        ),
                        b: vec![0.0; out],
                        relu: l == 0,
                        seg_budget: budget,
                    }
                })
                .collect();
            let labels = synthetic_labels(&tx, classes, &mut trng);
            let params_fnv = |layers: &[OocGcnLayer]| -> u64 {
                let mut h = aires::sparse::segio::Fnv64::new();
                for l in layers {
                    for v in &l.w.data {
                        h.update(&v.to_bits().to_le_bytes());
                    }
                    for v in &l.b {
                        h.update(&v.to_bits().to_le_bytes());
                    }
                }
                h.finish()
            };
            let run = |layers: Vec<OocGcnLayer>,
                       panel_dir: &std::path::Path,
                       from: usize,
                       to: usize,
                       restore_from: Option<&std::path::Path>,
                       save_to: Option<&std::path::Path>|
             -> StreamedTrainer {
                let panels = std::sync::Arc::new(
                    PanelStore::new(panel_dir, 0)
                        .unwrap_or_else(|e| fatal(format!("opening panel dir: {e}"))),
                );
                let tcfg = TrainStreamConfig::new(
                    StagingConfig::depth(prefetch_depth),
                    panels,
                );
                let mut tr = StreamedTrainer::new(layers, labels.clone())
                    .unwrap_or_else(|e| fatal(format!("building trainer: {e}")));
                if let Some(dir) = restore_from {
                    let ck = aires::gcn::checkpoint::load(dir)
                        .unwrap_or_else(|e| fatal(format!("loading checkpoint: {e}")))
                        .unwrap_or_else(|| {
                            fatal(format!("no checkpoint in {}", dir.display()))
                        });
                    let done = tr
                        .restore(&ck)
                        .unwrap_or_else(|e| fatal(format!("restoring checkpoint: {e}")));
                    if done != from as u64 {
                        fatal(format!("checkpoint at step {done}, expected {from}"));
                    }
                }
                let mut mem = GpuMem::new(1 << 30);
                for step in from..to {
                    tr.step(&a_hat, &tx, &mut mem, &pool, &tcfg, lr).unwrap_or_else(
                        |e| fatal(format!("streamed training step {step}: {e}")),
                    );
                    if let Some(dir) = save_to {
                        let ck = aires::gcn::Checkpoint {
                            step: (step + 1) as u64,
                            policy: aires::gcn::RecomputePolicy::Auto,
                            rng: trng.state(),
                            losses: tr.losses.clone(),
                            layers: tr.layers.clone(),
                        };
                        aires::gcn::checkpoint::save(dir, &ck).unwrap_or_else(|e| {
                            fatal(format!("publishing checkpoint: {e}"))
                        });
                    }
                }
                if mem.used != 0 {
                    fatal(format!("ledger not balanced after training: {} bytes", mem.used));
                }
                tr
            };
            let ckdir = scratch.join("ck");
            let full = run(tlayers.clone(), &scratch.join("panels-full"), 0, steps, None, None);
            // "Kill" after 2 steps: the first trainer is dropped with its
            // checkpoint published; a fresh trainer resumes from disk.
            let _killed =
                run(tlayers.clone(), &scratch.join("panels-a"), 0, 2, None, Some(&ckdir));
            let resumed = run(
                tlayers.clone(),
                &scratch.join("panels-b"),
                2,
                steps,
                Some(&ckdir),
                Some(&ckdir),
            );
            let (fa, fb) = (params_fnv(&full.layers), params_fnv(&resumed.layers));
            println!(
                "scenario 3 (kill/resume): uninterrupted fnv64 0x{fa:016x}, \
                 resumed fnv64 0x{fb:016x}"
            );
            let _ = std::fs::remove_dir_all(&scratch);
            if fa == fb {
                println!("resumed parameters match uninterrupted run: OK");
            } else {
                fatal("resumed parameters DIVERGED from the uninterrupted run".into());
            }
            if balanced {
                println!("ledger balanced after every scenario: OK");
            } else {
                fatal("ledger NOT balanced after a scenario".into());
            }
        }
        "gcnstream" => {
            // Multi-layer cross-layer streaming surface (no compiled
            // artifacts needed): build an L-layer model, run it through
            // the pipelined executor — layer l+1's segments stage while
            // layer l's combine runs — and verify the output is
            // byte-identical to the drain-at-boundary per-layer oracle.
            use aires::gcn::pipeline::{OocGcnModel, PipelineConfig};
            use aires::memsim::GpuMem;
            use aires::runtime::PanelStore;
            use aires::sparse::spmm::Dense;

            let nodes: usize = parsed_flag(&args, "--nodes", "a node count").unwrap_or(300);
            let budget: u64 = parsed_flag(&args, "--budget", "a byte budget").unwrap_or(4096);
            // --layers L sizes the model; 0 is clamped to 1 with a
            // warning (same convention as --prefetch-depth 0); unset
            // falls back to the config's `layers` key.
            let layers_n: usize =
                parsed_flag(&args, "--layers", "a positive layer count (the model depth)")
                    .map(|l: usize| {
                        if l == 0 {
                            eprintln!(
                                "warning: --layers 0 is not a valid model depth; \
                                 using 1 (single layer)"
                            );
                            1
                        } else {
                            l
                        }
                    })
                    .unwrap_or((cfg.layers as usize).max(1));
            let f = 16usize;
            let mut rng = Pcg::seed(17);
            let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let x = Dense::from_vec(
                nodes,
                f,
                (0..nodes * f).map(|_| rng.normal() as f32).collect(),
            );
            let model = OocGcnModel::new(
                (0..layers_n)
                    .map(|_| aires::gcn::OocGcnLayer {
                        w: Dense::from_vec(
                            f,
                            f,
                            (0..f * f).map(|_| (rng.normal() * 0.2) as f32).collect(),
                        ),
                        b: vec![0.05; f],
                        relu: true,
                        seg_budget: budget,
                    })
                    .collect(),
            )
            .expect("equal-width layers always chain");

            // Segment backing: in-memory slicing, or real file reads when
            // --segment-dir / config `segment_dir` is set (one store
            // serves every layer).
            let staging = staging_for(
                &a_hat,
                budget,
                &segment_dir,
                host_cache_bytes,
                prefetch_depth,
                &recycle_pool,
                heal,
                mmap,
                seg_encoding,
            );
            // Panel spilling: --panel-dir / config `panel_dir` routes
            // every intermediate feature panel through the disk tier.
            // The panel tier runs cacheless here: each intermediate panel
            // is read back exactly once per pass, so caching it would
            // just pin the activations in host RAM — the residency
            // spilling exists to avoid.
            let panel_dir: Option<String> =
                flag_value(&args, "--panel-dir").or_else(|| cfg.panel_dir.clone());
            let mut pcfg = PipelineConfig::staged(staging);
            let panel_store = panel_dir.as_ref().map(|dir| {
                let store = PanelStore::new(std::path::Path::new(dir), 0).unwrap_or_else(|e| {
                    eprintln!("error: opening panel dir {dir}: {e}");
                    std::process::exit(1);
                });
                std::sync::Arc::new(store)
            });
            if let Some(ps) = &panel_store {
                pcfg = pcfg.with_panel_spill(ps.clone());
            }

            let mut mem = GpuMem::new(1 << 30);
            let (got, rep) = model
                .forward_cpu(&a_hat, &x, &mut mem, &pool, &pcfg)
                .expect("pipelined multi-layer forward");
            let mut mem2 = GpuMem::new(1 << 30);
            let (want, _) = model
                .forward_cpu_sequential(
                    &a_hat,
                    &x,
                    &mut mem2,
                    &Pool::serial(),
                    &PipelineConfig::serial(),
                )
                .expect("sequential oracle forward");

            let merged = rep.merged();
            println!(
                "gcnstream: {layers_n} layers over {nodes} nodes, {} segments total \
                 (prefetch depth {}, one cross-layer pipeline)",
                merged.segments, merged.prefetch_depth
            );
            for (l, r) in rep.per_layer.iter().enumerate() {
                let disk = if segment_dir.is_some() {
                    format!(
                        ", {} from disk, {} hits / {} misses",
                        aires::util::human_bytes(r.disk_bytes),
                        r.cache_hits,
                        r.cache_misses
                    )
                } else {
                    String::new()
                };
                println!(
                    "  layer {l}: {} segments, H2D {}{disk}",
                    r.segments,
                    aires::util::human_bytes(r.h2d_bytes)
                );
            }
            println!(
                "merged: H2D {}, peak {}",
                aires::util::human_bytes(merged.h2d_bytes),
                aires::util::human_bytes(merged.peak_gpu_bytes)
            );
            if let Some(ps) = &panel_store {
                println!(
                    "panel spill: wrote {} ({} panels) to {}, read back {} \
                     ({} hits / {} misses)",
                    aires::util::human_bytes(rep.panel_spill_bytes),
                    ps.len(),
                    ps.dir().display(),
                    aires::util::human_bytes(rep.panel_read_bytes),
                    rep.panel_cache_hits,
                    rep.panel_cache_misses
                );
            }
            if let Some(rp) = &recycle_pool {
                let st = rp.stats();
                println!(
                    "recycle pool: {} hits / {} misses, {} returned ({} dropped by the cap)",
                    st.hits, st.misses, st.returns, st.drops
                );
            }
            if got == want {
                println!(
                    "pipelined multi-layer output byte-identical to the per-layer oracle: OK"
                );
            } else {
                eprintln!(
                    "error: pipelined multi-layer output DIVERGED from the per-layer oracle"
                );
                std::process::exit(1);
            }
        }
        "serve" => {
            // Multi-tenant batched inference surface (no compiled
            // artifacts needed): N tenant queries share one staged pass
            // of the adjacency per batch under open-loop load.
            use aires::gcn::serve::{serve_open_loop, OpenLoopConfig, TenantQuery};
            use aires::memsim::GpuMem;
            use aires::sparse::spmm::Dense;

            let scale: u32 = parsed_flag(&args, "--scale", "an RMAT scale").unwrap_or(8);
            let feat: usize = parsed_flag(&args, "--feat", "a feature width").unwrap_or(32);
            let budget: u64 = parsed_flag(&args, "--budget", "a byte budget").unwrap_or(8192);
            // --tenants N (config key `tenants` as fallback, default 4);
            // 0 is clamped to 1 with a warning (same convention as
            // --prefetch-depth 0).
            let tenants: usize = parsed_flag(&args, "--tenants", "a tenant count")
                .map(|t: usize| {
                    if t == 0 {
                        eprintln!("warning: --tenants 0 serves nobody; using 1");
                        1
                    } else {
                        t
                    }
                })
                .unwrap_or_else(|| cfg.tenants.unwrap_or(4));
            let requests: usize =
                parsed_flag(&args, "--requests", "a per-tenant request count")
                    .map(|r: usize| {
                        if r == 0 {
                            eprintln!("warning: --requests 0 issues nothing; using 1");
                            1
                        } else {
                            r
                        }
                    })
                    .unwrap_or(8);
            let rate_hz: f64 = parsed_flag(&args, "--rate-hz", "an aggregate arrival rate")
                .map(|r: f64| {
                    if r <= 0.0 {
                        eprintln!("warning: --rate-hz {r} is not an arrival rate; using 200");
                        200.0
                    } else {
                        r
                    }
                })
                .unwrap_or(200.0);
            let max_batch: usize = parsed_flag(&args, "--max-batch", "a batch bound")
                .map(|b: usize| {
                    if b == 0 {
                        eprintln!("warning: --max-batch 0 admits nothing; using 1");
                        1
                    } else {
                        b
                    }
                })
                .unwrap_or(16);

            let mut rng = Pcg::seed(23);
            let a = aires::graphgen::rmat::generate(&mut rng, scale, 8, Default::default());
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let nodes = a_hat.nrows;
            let queries: Vec<TenantQuery> = (0..tenants)
                .map(|_| TenantQuery {
                    x: Dense::from_vec(
                        nodes,
                        feat,
                        (0..nodes * feat).map(|_| rng.normal() as f32).collect(),
                    ),
                    layer: aires::gcn::OocGcnLayer {
                        w: Dense::from_vec(
                            feat,
                            feat,
                            (0..feat * feat).map(|_| (rng.normal() * 0.2) as f32).collect(),
                        ),
                        b: vec![0.05; feat],
                        relu: true,
                        seg_budget: budget,
                    },
                })
                .collect();
            let staging = staging_for(
                &a_hat,
                budget,
                &segment_dir,
                host_cache_bytes,
                prefetch_depth,
                &recycle_pool,
                heal,
                mmap,
                seg_encoding,
            );
            let mut mem = GpuMem::new(256 << 20);
            println!(
                "serve: rmat-{scale} ({nodes} nodes, {} nnz), {tenants} tenants x \
                 {requests} requests at {rate_hz} req/s aggregate (batch <= {max_batch}, \
                 prefetch depth {prefetch_depth})",
                a_hat.nnz()
            );
            let olc = OpenLoopConfig { requests_per_tenant: requests, rate_hz, max_batch };
            let rep = serve_open_loop(&a_hat, &queries, &mut mem, &pool, &staging, &olc);
            println!(
                "served {} requests in {} batches ({} segments streamed, {:.1} segments/s, \
                 {:.2}s wall)",
                rep.requests, rep.batches, rep.segments_streamed, rep.segments_per_s, rep.wall_s
            );
            for t in &rep.per_tenant {
                println!(
                    "  tenant {}: p50 {:.2}ms, p99 {:.2}ms ({} completed, {} rejected)",
                    t.tenant,
                    t.p50_s * 1e3,
                    t.p99_s * 1e3,
                    t.completed,
                    t.rejected
                );
            }
            // Rejected-work visibility: admission-control drops are real
            // served-load loss, so they get a first-class, grep-able line
            // (the CI serve smoke gates on this reading 0).
            println!("tenants rejected: {}", rep.rejected_total);
            if rep.heal.any() {
                println!(
                    "heal: {} injected, {} retries, {} slow reads, \
                     {} quarantined / {} rebuilt, backoff {}",
                    rep.heal.injected,
                    rep.heal.retries,
                    rep.heal.slow_reads,
                    rep.heal.quarantined,
                    rep.heal.rebuilt,
                    aires::util::human_bytes(rep.heal.backoff_bytes)
                );
            }
            if let Some(rp) = &recycle_pool {
                let st = rp.stats();
                println!(
                    "recycle pool: {} hits / {} misses, {} returned ({} dropped by the cap)",
                    st.hits, st.misses, st.returns, st.drops
                );
            }
            if let Some(out) = flag_value(&args, "--out") {
                std::fs::write(&out, format!("{}\n", rep.to_json())).unwrap_or_else(|e| {
                    eprintln!("error: writing serve report to {out}: {e}");
                    std::process::exit(1);
                });
                println!("wrote {out}");
            }
            if rep.ledger_balanced {
                println!("ledger balanced after every batch: OK");
            } else {
                eprintln!("error: ledger NOT balanced after a batch");
                std::process::exit(1);
            }
        }
        "parcheck" => {
            // Serial-vs-parallel differential check + timing of the hot
            // kernels on generated graphs: the runtime surface for
            // `--threads` that needs no compiled artifacts.
            use aires::sparse::spgemm::{spgemm_gustavson, spgemm_gustavson_par};
            use aires::sparse::spmm::{spmm, spmm_par, Dense};
            use aires::util::{human_secs, Stopwatch};

            let scale: u32 = parsed_flag(&args, "--scale", "an RMAT scale").unwrap_or(11);
            let feat: usize = parsed_flag(&args, "--feat", "a feature width").unwrap_or(64);
            let mut rng = Pcg::seed(77);
            let a = aires::graphgen::rmat::generate(&mut rng, scale, 8, Default::default());
            let h = Dense::from_vec(
                a.ncols,
                feat,
                (0..a.ncols * feat).map(|_| rng.normal() as f32).collect(),
            );
            println!(
                "parcheck: rmat-{scale} ({} nodes, {} nnz), feat {feat}, pool {} threads",
                a.nrows,
                a.nnz(),
                pool.threads()
            );

            let sw = Stopwatch::start();
            let c_ser = spgemm_gustavson(&a, &a);
            let t_spgemm = sw.secs();
            let sw = Stopwatch::start();
            let m_ser = spmm(&a, &h);
            let t_spmm = sw.secs();
            println!("{:>28} {:>10} {:>10} {:>9}", "kernel", "serial", "parallel", "speedup");

            let mut counts = vec![1usize, 2, 4, 8];
            if !counts.contains(&pool.threads()) {
                counts.push(pool.threads());
            }
            for t in counts {
                let p = Pool::new(t);
                let sw = Stopwatch::start();
                let c_par = spgemm_gustavson_par(&a, &a, &p);
                let tp = sw.secs();
                assert_eq!(c_par, c_ser, "spgemm parallel output diverged at {t} threads");
                println!(
                    "{:>28} {:>10} {:>10} {:>8.2}x",
                    format!("spgemm_gustavson_par({t}t)"),
                    human_secs(t_spgemm),
                    human_secs(tp),
                    t_spgemm / tp
                );
                let sw = Stopwatch::start();
                let m_par = spmm_par(&a, &h, &p);
                let tp = sw.secs();
                assert_eq!(m_par, m_ser, "spmm parallel output diverged at {t} threads");
                println!(
                    "{:>28} {:>10} {:>10} {:>8.2}x",
                    format!("spmm_par({t}t)"),
                    human_secs(t_spmm),
                    human_secs(tp),
                    t_spmm / tp
                );
            }
            println!("OK: parallel outputs byte-identical to the serial oracles");
        }
        "bench" => {
            // Perf-trajectory store: ingest BENCH_streaming.json emissions,
            // render the trajectory, and gate the newest run against the
            // stored baseline. See rust/src/benchdb/ for the record schema
            // and gate semantics.
            use aires::benchdb;

            let action = args
                .get(1)
                .map(String::as_str)
                .unwrap_or_else(|| usage_fail("bench requires an action: ingest, report, or gate"));
            // The store path is required (config key `bench_db` as
            // fallback): every action reads or extends the same file.
            let db: String = flag_value(&args, "--db")
                .or_else(|| cfg.bench_db.clone())
                .unwrap_or_else(|| {
                    usage_fail(
                        "bench requires --db <trajectory.jsonl> (or the `bench_db` config key)",
                    )
                });
            let db_path = std::path::Path::new(&db);
            let warn_skipped = |traj: &benchdb::Trajectory| {
                for s in &traj.skipped {
                    eprintln!("warning: {db}:{}: skipped line: {}", s.line, s.error);
                }
            };
            match action {
                "ingest" => {
                    let json_path: String = flag_value(&args, "--json")
                        .or_else(|| std::env::var("AIRES_BENCH_JSON").ok())
                        .unwrap_or_else(|| "BENCH_streaming.json".into());
                    let commit: String = flag_value(&args, "--commit")
                        .or_else(|| std::env::var("GITHUB_SHA").ok())
                        .unwrap_or_else(|| "unknown".into());
                    let ts = std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .map(|d| d.as_secs())
                        .unwrap_or(0);
                    let text = std::fs::read_to_string(&json_path).unwrap_or_else(|e| {
                        eprintln!("error: reading {json_path}: {e}");
                        std::process::exit(1);
                    });
                    let records = benchdb::records_from_bench_json(&text, &commit, ts)
                        .unwrap_or_else(|e| {
                            eprintln!("error: {json_path}: {e}");
                            std::process::exit(1);
                        });
                    benchdb::append_records(db_path, &records).unwrap_or_else(|e| {
                        eprintln!("error: {e}");
                        std::process::exit(1);
                    });
                    println!(
                        "ingested {} records from {json_path} into {db} \
                         (run: commit {commit}, ts {ts})",
                        records.len()
                    );
                }
                "report" => {
                    let traj = benchdb::read_trajectory(db_path).unwrap_or_else(|e| {
                        eprintln!("error: {e}");
                        std::process::exit(1);
                    });
                    warn_skipped(&traj);
                    let stats = benchdb::scenario_stats(&traj);
                    print!("{}", report::bench_trajectory_md(&stats, traj.runs().len()));
                    // Commit-to-commit view of the gated series: where
                    // the trajectory moved, not just its aggregate.
                    print!("{}", report::bench_trend_md(&benchdb::trend_lines(&traj)));
                }
                "gate" => {
                    let pct: f64 = parsed_flag(
                        &args,
                        "--max-regress-pct",
                        "a percentage (e.g. 10 allows +10%)",
                    )
                    .unwrap_or_else(|| {
                        usage_fail("bench gate requires --max-regress-pct <percent>")
                    });
                    if !pct.is_finite() {
                        usage_fail(&format!("--max-regress-pct must be finite, got {pct}"));
                    }
                    // A store that does not exist yet cannot gate anything:
                    // warn and pass, so the first CI run seeds the baseline
                    // instead of failing the pipeline.
                    if !db_path.exists() {
                        eprintln!("warning: trajectory {db} does not exist yet; nothing to gate");
                        println!("bench gate: PASS (no stored runs)");
                        return;
                    }
                    let traj = benchdb::read_trajectory(db_path).unwrap_or_else(|e| {
                        eprintln!("error: {e}");
                        std::process::exit(1);
                    });
                    warn_skipped(&traj);
                    let outcome = benchdb::gate(&traj, pct);
                    if outcome.baseline_runs == 0 {
                        // Empty store or a single run: no baseline median
                        // exists, so there is nothing to divide by — the
                        // newest run seeds the baseline instead.
                        eprintln!(
                            "warning: {} stored run(s) — no baseline to compare against",
                            traj.runs().len()
                        );
                        println!("bench gate: PASS (baseline seeded, not judged)");
                        return;
                    }
                    print!("{}", report::bench_gate_md(&outcome));
                    if outcome.passed() {
                        println!("bench gate: PASS (threshold {pct}%)");
                    } else {
                        eprintln!("error: bench gate: FAIL — regression beyond {pct}%");
                        std::process::exit(1);
                    }
                }
                other => usage_fail(&format!(
                    "unknown bench action {other:?}; expected ingest, report, or gate"
                )),
            }
        }
        _ => {
            println!(
                "aires — out-of-core GCN co-design (AIRES reproduction)\n\n\
                 usage: aires <catalog|features|fig3|fig6|fig7|fig8|fig9|table3|report|prep|train|spgemm|segcheck|faultcheck|gcnstream|serve|bench|parcheck|trace|sweep|config-dump> [--config F] [--threads N] [--prefetch-depth D] [--segment-dir DIR] [--host-cache-bytes N] [--seg-encoding E] [--mmap] [--recycle-cap-bytes N] [--retry-max N] [--retry-backoff-ios N] [--checkpoint-dir DIR] [--layers L] [--panel-dir DIR] [--tenants N] [--db F] [--train-stream] [--recompute-policy P] [args]\n\
                 see README.md for details"
            );
        }
    }
}
