//! `aires` CLI — the L3 leader entrypoint.
//!
//! Subcommands (in-tree arg parsing; clap unavailable offline):
//!   catalog            print the Table II dataset catalog
//!   features           print the Table I feature matrix
//!   fig3|fig6|fig7|fig8|fig9|table3
//!                      regenerate one paper artifact as markdown
//!   report [--out F]   regenerate the full evaluation report
//!   train [--steps N] [--lr X] [--nodes N]
//!                      e2e GCN training through the PJRT artifacts
//!   spgemm [--nodes N] [--budget BYTES]
//!                      one out-of-core aggregation through the artifacts,
//!                      verified against the CPU oracle
//!   prep DATASET       one-time RoBW preprocessing cost estimate

use aires::config::Config;
use aires::coordinator::report;
use aires::coordinator::*;
use aires::util::rng::Pcg;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    // Every subcommand honours --config <file> (cost-model + workload
    // overrides; see rust/src/config.rs for the schema).
    let cfg = match arg_value(&args, "--config") {
        Some(path) => Config::from_file(&path).expect("config"),
        None => Config::default(),
    };
    let cm = cfg.cost_model.clone();

    match cmd {
        "catalog" => print!("{}", report::table2_md()),
        "features" => print!("{}", report::table1_md()),
        "fig3" => print!("{}", report::fig3_md(&fig3_merging(&cm))),
        "fig6" => print!("{}", report::fig6_md(&fig6_speedup(&cm))),
        "fig7" => print!("{}", report::fig7_md(&fig7_io_breakdown(&cm))),
        "fig8" => print!("{}", report::fig8_md(&fig8_bandwidth(&cm))),
        "fig9" => {
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            print!("{}", report::fig9_md(&fig9_feature_size(&cm, &ds)));
        }
        "table3" => print!("{}", report::table3_md(&table3_memcap(&cm))),
        "config-dump" => println!("{}", cfg.to_json()),
        "trace" => {
            // Export one scheduler's simulated epoch as a Chrome trace.
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            let sched = arg_value(&args, "--scheduler").unwrap_or_else(|| "AIRES".into());
            let out = arg_value(&args, "--out").unwrap_or_else(|| "trace.json".into());
            let d = aires::graphgen::catalog::by_name(&ds).expect("unknown dataset");
            let w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
            let r = aires::sched::all_schedulers()
                .iter()
                .find(|s| s.name().eq_ignore_ascii_case(&sched))
                .expect("unknown scheduler")
                .run_epoch(&w, &cm);
            match r.makespan_s {
                Some(t) => {
                    std::fs::write(&out, aires::memsim::trace::chrome_trace_log(&r.log))
                        .expect("write trace");
                    println!("{ds}/{sched}: {t:.2}s epoch, {} ops -> {out} (open in chrome://tracing)", r.log.len());
                }
                None => println!("{ds}/{sched}: OOM — {}", r.oom.unwrap()),
            }
        }
        "sweep" => {
            // Latency sweep over memory constraints for one dataset.
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            let points: usize =
                arg_value(&args, "--points").and_then(|v| v.parse().ok()).unwrap_or(8);
            let d = aires::graphgen::catalog::by_name(&ds).expect("unknown dataset");
            println!("{:>9} {:>11} {:>9} {:>9} {:>9}", "cap (GB)", "MaxMemory", "UCG", "ETC", "AIRES");
            for i in 0..points {
                let cap = d.memory_constraint_gb * (1.0 - i as f64 / points as f64 * 0.7);
                let mut w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
                w.gpu_mem_bytes = (cap * 1e9) as u64;
                let cells: Vec<String> = aires::sched::all_schedulers()
                    .iter()
                    .map(|s| {
                        s.run_epoch(&w, &cm)
                            .makespan_s
                            .map_or("OOM".into(), |t| format!("{t:.2}s"))
                    })
                    .collect();
                println!("{:>9.1} {:>11} {:>9} {:>9} {:>9}", cap, cells[0], cells[1], cells[2], cells[3]);
            }
        }
        "report" => {
            let text = report::full_report(&cm);
            match arg_value(&args, "--out") {
                Some(path) => {
                    std::fs::write(&path, &text).expect("write report");
                    println!("wrote {path}");
                }
                None => print!("{text}"),
            }
        }
        "prep" => {
            let name = args.get(1).cloned().unwrap_or_else(|| "kP1a".into());
            let d = aires::graphgen::catalog::by_name(&name).expect("unknown dataset");
            let w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
            let t = aires::sched::Aires::prep_time(&w, &cm);
            println!(
                "{name}: one-time RoBW preprocessing (NVMe load + CPU partition): {}",
                aires::util::human_secs(t)
            );
        }
        "train" => {
            let steps: usize =
                arg_value(&args, "--steps").and_then(|v| v.parse().ok()).unwrap_or(100);
            let lr: f32 = arg_value(&args, "--lr").and_then(|v| v.parse().ok()).unwrap_or(2.0);
            let nodes: usize =
                arg_value(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(1024);
            let mut exec = aires::runtime::Executor::from_env().expect("executor");
            let mut rng = Pcg::seed(42);
            let g = aires::graphgen::kmer::generate(&mut rng, nodes, 3.2);
            let mut tr = aires::gcn::Trainer::new(&exec, &g, 42).expect("trainer");
            println!("training 2-layer GCN (n={}, f0={}, h={}, c={}) for {steps} steps", tr.n, tr.f0, tr.hidden, tr.classes);
            for step in 0..steps {
                let loss = tr.step(&mut exec, lr).expect("step");
                if step % 10 == 0 || step + 1 == steps {
                    println!("step {step:4}  loss {loss:.4}");
                }
            }
        }
        "spgemm" => {
            let nodes: usize =
                arg_value(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(600);
            let budget: u64 =
                arg_value(&args, "--budget").and_then(|v| v.parse().ok()).unwrap_or(8192);
            let mut exec = aires::runtime::Executor::from_env().expect("executor");
            let mut rng = Pcg::seed(7);
            let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let x = aires::sparse::spmm::Dense::from_vec(
                nodes,
                64,
                (0..nodes * 64).map(|_| rng.normal() as f32).collect(),
            );
            let layer = aires::gcn::OocGcnLayer {
                w: aires::sparse::spmm::Dense::from_vec(
                    64,
                    64,
                    (0..64 * 64).map(|_| (rng.normal() * 0.2) as f32).collect(),
                ),
                b: vec![0.0; 64],
                relu: true,
                seg_budget: budget,
            };
            let mut mem = aires::memsim::GpuMem::new(256 << 20);
            let (out, rep) = layer.forward(&mut exec, &a_hat, &x, &mut mem).expect("forward");
            println!(
                "out-of-core aggregation: {} segments, ~{} artifact calls, peak {}, H2D {}",
                rep.segments,
                rep.artifact_calls_estimate,
                aires::util::human_bytes(rep.peak_gpu_bytes),
                aires::util::human_bytes(rep.h2d_bytes)
            );
            // Verify against the CPU oracle.
            let want = aires::gcn::model::dense_affine(
                &aires::sparse::spmm::spmm(&a_hat, &x),
                &layer.w,
                &layer.b,
                true,
            );
            let diff = out.max_abs_diff(&want);
            println!("max |accelerator - oracle| = {diff:.2e} -> {}", if diff < 1e-3 { "OK" } else { "MISMATCH" });
        }
        _ => {
            println!(
                "aires — out-of-core GCN co-design (AIRES reproduction)\n\n\
                 usage: aires <catalog|features|fig3|fig6|fig7|fig8|fig9|table3|report|prep|train|spgemm|trace|sweep|config-dump> [--config F] [args]\n\
                 see README.md for details"
            );
        }
    }
}
