//! `aires` CLI — the L3 leader entrypoint.
//!
//! Subcommands (in-tree arg parsing; clap unavailable offline):
//!   catalog            print the Table II dataset catalog
//!   features           print the Table I feature matrix
//!   fig3|fig6|fig7|fig8|fig9|table3
//!                      regenerate one paper artifact as markdown
//!   report [--out F]   regenerate the full evaluation report
//!   train [--steps N] [--lr X] [--nodes N]
//!                      e2e GCN training through the PJRT artifacts
//!   spgemm [--nodes N] [--budget BYTES] [--prefetch-depth D]
//!                      one out-of-core aggregation through the artifacts,
//!                      verified against the CPU oracle
//!   prep DATASET       one-time RoBW preprocessing cost estimate

use aires::config::Config;
use aires::coordinator::report;
use aires::coordinator::*;
use aires::runtime::pool::Pool;
use aires::util::rng::Pcg;

fn arg_value(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1).cloned())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    // Every subcommand honours --config <file> (cost-model + workload
    // overrides; see rust/src/config.rs for the schema).
    let cfg = match arg_value(&args, "--config") {
        Some(path) => Config::from_file(&path).expect("config"),
        None => Config::default(),
    };
    // Every subcommand honours --threads N (0 = one per hardware thread):
    // it sizes the runtime::pool the real kernels run on, and mirrors the
    // resolved worker count into the simulator's host-compute hook so the
    // modelled experiments and the executed kernels agree.
    let threads_flag = arg_value(&args, "--threads").map(|v| v.parse::<usize>().expect("--threads"));
    let pool = Pool::new(threads_flag.unwrap_or(cfg.threads));
    // --prefetch-depth N sizes the executed Phase II staging pipeline
    // (1 = serial staging, 2 = double buffering; output is byte-identical
    // at every depth). CLI flag wins over the config's `prefetch_depth`;
    // neither set -> the double-buffering default of 2.
    let prefetch_flag = arg_value(&args, "--prefetch-depth")
        .map(|v| v.parse::<usize>().expect("--prefetch-depth"));
    let prefetch_depth =
        prefetch_flag.map(|d| d.max(1)).unwrap_or_else(|| cfg.resolved_prefetch_depth());
    let mut cm = cfg.cost_model.clone();
    // --threads always wins; otherwise the config's `threads` key flows
    // into the hook too, unless the config pinned cost_model.cpu_threads
    // away from the serial default (a pin to exactly 1.0 is
    // indistinguishable from "unset" and gets mirrored — pin any other
    // value, e.g. 1.01, to decouple the simulated host from the pool).
    if threads_flag.is_some() || cm.cpu_threads == 1.0 {
        cm.cpu_threads = pool.threads() as f64;
    }
    // The RoBW partition scan only discounts when the parallel planner
    // (`robw_partition_par`) is the selected code path — i.e. the pool is
    // actually parallel (same pin escape hatch as cpu_threads).
    if pool.threads() > 1 && cm.partition_threads == 1.0 {
        cm.partition_threads = pool.threads() as f64;
    }
    // The simulator's overlap hook follows the staging depth whenever one
    // was *requested* (CLI flag or config key) — executed and modelled
    // Phase II then move together. Untouched, the CostModel stays the
    // depth-1 calibration baseline, so every figure is unchanged by
    // default (the execution-side default of 2 never leaks in on its own).
    // A cost_model.prefetch_depth pinned away from 1.0 in the config wins
    // over the mirror (same pin escape hatch as cpu_threads).
    if (prefetch_flag.is_some() || cfg.prefetch_depth.is_some()) && cm.prefetch_depth == 1.0 {
        cm.prefetch_depth = prefetch_depth as f64;
    }
    let cm = cm;

    match cmd {
        "catalog" => print!("{}", report::table2_md()),
        "features" => print!("{}", report::table1_md()),
        "fig3" => print!("{}", report::fig3_md(&fig3_merging(&cm))),
        "fig6" => print!("{}", report::fig6_md(&fig6_speedup(&cm))),
        "fig7" => print!("{}", report::fig7_md(&fig7_io_breakdown(&cm))),
        "fig8" => print!("{}", report::fig8_md(&fig8_bandwidth(&cm))),
        "fig9" => {
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            print!("{}", report::fig9_md(&fig9_feature_size(&cm, &ds)));
        }
        "table3" => print!("{}", report::table3_md(&table3_memcap(&cm))),
        "config-dump" => println!("{}", cfg.to_json()),
        "trace" => {
            // Export one scheduler's simulated epoch as a Chrome trace.
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            let sched = arg_value(&args, "--scheduler").unwrap_or_else(|| "AIRES".into());
            let out = arg_value(&args, "--out").unwrap_or_else(|| "trace.json".into());
            let d = aires::graphgen::catalog::by_name(&ds).expect("unknown dataset");
            let w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
            let r = aires::sched::all_schedulers()
                .iter()
                .find(|s| s.name().eq_ignore_ascii_case(&sched))
                .expect("unknown scheduler")
                .run_epoch(&w, &cm);
            match r.makespan_s {
                Some(t) => {
                    std::fs::write(&out, aires::memsim::trace::chrome_trace_log(&r.log))
                        .expect("write trace");
                    println!("{ds}/{sched}: {t:.2}s epoch, {} ops -> {out} (open in chrome://tracing)", r.log.len());
                }
                None => println!("{ds}/{sched}: OOM — {}", r.oom.unwrap()),
            }
        }
        "sweep" => {
            // Latency sweep over memory constraints for one dataset.
            let ds = arg_value(&args, "--dataset").unwrap_or_else(|| "kP1a".into());
            let points: usize =
                arg_value(&args, "--points").and_then(|v| v.parse().ok()).unwrap_or(8);
            let d = aires::graphgen::catalog::by_name(&ds).expect("unknown dataset");
            println!("{:>9} {:>11} {:>9} {:>9} {:>9}", "cap (GB)", "MaxMemory", "UCG", "ETC", "AIRES");
            for i in 0..points {
                let cap = d.memory_constraint_gb * (1.0 - i as f64 / points as f64 * 0.7);
                let mut w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
                w.gpu_mem_bytes = (cap * 1e9) as u64;
                let cells: Vec<String> = aires::sched::all_schedulers()
                    .iter()
                    .map(|s| {
                        s.run_epoch(&w, &cm)
                            .makespan_s
                            .map_or("OOM".into(), |t| format!("{t:.2}s"))
                    })
                    .collect();
                println!("{:>9.1} {:>11} {:>9} {:>9} {:>9}", cap, cells[0], cells[1], cells[2], cells[3]);
            }
        }
        "report" => {
            let text = report::full_report(&cm);
            match arg_value(&args, "--out") {
                Some(path) => {
                    std::fs::write(&path, &text).expect("write report");
                    println!("wrote {path}");
                }
                None => print!("{text}"),
            }
        }
        "prep" => {
            let name = args.get(1).cloned().unwrap_or_else(|| "kP1a".into());
            let d = aires::graphgen::catalog::by_name(&name).expect("unknown dataset");
            let w = aires::sched::Workload::from_catalog(d, cfg.feat_dim, cfg.layers);
            let t = aires::sched::Aires::prep_time(&w, &cm);
            println!(
                "{name}: one-time RoBW preprocessing (NVMe load + CPU partition): {}",
                aires::util::human_secs(t)
            );
        }
        "train" => {
            let steps: usize =
                arg_value(&args, "--steps").and_then(|v| v.parse().ok()).unwrap_or(100);
            let lr: f32 = arg_value(&args, "--lr").and_then(|v| v.parse().ok()).unwrap_or(2.0);
            let nodes: usize =
                arg_value(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(1024);
            let mut exec = aires::runtime::Executor::from_env().expect("executor");
            let mut rng = Pcg::seed(42);
            let g = aires::graphgen::kmer::generate(&mut rng, nodes, 3.2);
            let mut tr = aires::gcn::Trainer::new(&exec, &g, 42).expect("trainer");
            println!("training 2-layer GCN (n={}, f0={}, h={}, c={}) for {steps} steps", tr.n, tr.f0, tr.hidden, tr.classes);
            for step in 0..steps {
                let loss = tr.step(&mut exec, lr).expect("step");
                if step % 10 == 0 || step + 1 == steps {
                    println!("step {step:4}  loss {loss:.4}");
                }
            }
        }
        "spgemm" => {
            let nodes: usize =
                arg_value(&args, "--nodes").and_then(|v| v.parse().ok()).unwrap_or(600);
            let budget: u64 =
                arg_value(&args, "--budget").and_then(|v| v.parse().ok()).unwrap_or(8192);
            let mut exec = aires::runtime::Executor::from_env().expect("executor");
            let mut rng = Pcg::seed(7);
            let a = aires::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let a_hat = aires::sparse::norm::normalize_adjacency(&a);
            let x = aires::sparse::spmm::Dense::from_vec(
                nodes,
                64,
                (0..nodes * 64).map(|_| rng.normal() as f32).collect(),
            );
            let layer = aires::gcn::OocGcnLayer {
                w: aires::sparse::spmm::Dense::from_vec(
                    64,
                    64,
                    (0..64 * 64).map(|_| (rng.normal() * 0.2) as f32).collect(),
                ),
                b: vec![0.0; 64],
                relu: true,
                seg_budget: budget,
            };
            let mut mem = aires::memsim::GpuMem::new(256 << 20);
            let staging = aires::gcn::oocgcn::StagingConfig::depth(prefetch_depth);
            let (out, rep) = layer
                .forward_staged(&mut exec, &a_hat, &x, &mut mem, &pool, &staging)
                .expect("forward");
            println!(
                "out-of-core aggregation: {} segments (prefetch depth {}), ~{} artifact calls, peak {}, H2D {}",
                rep.segments,
                rep.prefetch_depth,
                rep.artifact_calls_estimate,
                aires::util::human_bytes(rep.peak_gpu_bytes),
                aires::util::human_bytes(rep.h2d_bytes)
            );
            // Verify against the CPU oracle.
            let want = aires::gcn::model::dense_affine(
                &aires::sparse::spmm::spmm(&a_hat, &x),
                &layer.w,
                &layer.b,
                true,
            );
            let diff = out.max_abs_diff(&want);
            println!("max |accelerator - oracle| = {diff:.2e} -> {}", if diff < 1e-3 { "OK" } else { "MISMATCH" });
        }
        "parcheck" => {
            // Serial-vs-parallel differential check + timing of the hot
            // kernels on generated graphs: the runtime surface for
            // `--threads` that needs no compiled artifacts.
            use aires::sparse::spgemm::{spgemm_gustavson, spgemm_gustavson_par};
            use aires::sparse::spmm::{spmm, spmm_par, Dense};
            use aires::util::{human_secs, Stopwatch};

            let scale: u32 =
                arg_value(&args, "--scale").and_then(|v| v.parse().ok()).unwrap_or(11);
            let feat: usize =
                arg_value(&args, "--feat").and_then(|v| v.parse().ok()).unwrap_or(64);
            let mut rng = Pcg::seed(77);
            let a = aires::graphgen::rmat::generate(&mut rng, scale, 8, Default::default());
            let h = Dense::from_vec(
                a.ncols,
                feat,
                (0..a.ncols * feat).map(|_| rng.normal() as f32).collect(),
            );
            println!(
                "parcheck: rmat-{scale} ({} nodes, {} nnz), feat {feat}, pool {} threads",
                a.nrows,
                a.nnz(),
                pool.threads()
            );

            let sw = Stopwatch::start();
            let c_ser = spgemm_gustavson(&a, &a);
            let t_spgemm = sw.secs();
            let sw = Stopwatch::start();
            let m_ser = spmm(&a, &h);
            let t_spmm = sw.secs();
            println!("{:>28} {:>10} {:>10} {:>9}", "kernel", "serial", "parallel", "speedup");

            let mut counts = vec![1usize, 2, 4, 8];
            if !counts.contains(&pool.threads()) {
                counts.push(pool.threads());
            }
            for t in counts {
                let p = Pool::new(t);
                let sw = Stopwatch::start();
                let c_par = spgemm_gustavson_par(&a, &a, &p);
                let tp = sw.secs();
                assert_eq!(c_par, c_ser, "spgemm parallel output diverged at {t} threads");
                println!(
                    "{:>28} {:>10} {:>10} {:>8.2}x",
                    format!("spgemm_gustavson_par({t}t)"),
                    human_secs(t_spgemm),
                    human_secs(tp),
                    t_spgemm / tp
                );
                let sw = Stopwatch::start();
                let m_par = spmm_par(&a, &h, &p);
                let tp = sw.secs();
                assert_eq!(m_par, m_ser, "spmm parallel output diverged at {t} threads");
                println!(
                    "{:>28} {:>10} {:>10} {:>8.2}x",
                    format!("spmm_par({t}t)"),
                    human_secs(t_spmm),
                    human_secs(tp),
                    t_spmm / tp
                );
            }
            println!("OK: parallel outputs byte-identical to the serial oracles");
        }
        _ => {
            println!(
                "aires — out-of-core GCN co-design (AIRES reproduction)\n\n\
                 usage: aires <catalog|features|fig3|fig6|fig7|fig8|fig9|table3|report|prep|train|spgemm|parcheck|trace|sweep|config-dump> [--config F] [--threads N] [--prefetch-depth D] [args]\n\
                 see README.md for details"
            );
        }
    }
}
