//! Multi-tenant batched inference: one staged pass of the adjacency
//! serving N concurrent tenant queries.
//!
//! The production north star ("millions of users") makes single-consumer
//! streaming untenable: staging a RoBW segment from the NVMe tier costs
//! the same whether one query or fifty multiply against it, so the
//! batched-SpMM insight (Wang et al., arXiv:1903.11409) lifts directly
//! into the out-of-core setting — **amortize every staged segment across
//! the whole batch before eviction**. This module is that front end:
//!
//! * **Admission control** ([`serve_batch`]): tenants are admitted in
//!   fixed order, each charging its feature-panel bytes against the
//!   [`GpuMem`] ledger. A tenant that does not fit is *rejected with a
//!   typed error* ([`ServeError::Admission`]) — never queued against the
//!   ledger, so admission can never deadlock the pass.
//! * **One staged pass**: the batch is planned once (from the admitted
//!   tenants' shared `seg_budget`) and streamed once through
//!   [`Prefetch::run_fanout`](crate::runtime::prefetch::Prefetch::run_fanout):
//!   each staged segment is multiplied against every admitted tenant's
//!   panel, then retired. Staged I/O is charged **once per segment, not
//!   once per tenant** (pinned by `diff_multitenant_matches_solo`).
//! * **Determinism**: every tenant's merge runs over its own disjoint
//!   aggregation panel in fixed row ranges, so tenant `t`'s output is
//!   byte-identical to running `t` alone through
//!   [`OocGcnLayer::forward_cpu`] at every prefetch depth, thread count,
//!   backing, and recycle point.
//! * **Open-loop load** ([`serve_open_loop`]): a fixed-rate arrival
//!   schedule batches pending requests per staged pass and reports
//!   per-tenant p50/p99 latency plus aggregate segments/s in a
//!   [`ServeReport`] (emitted into `BENCH_streaming.json` by the
//!   `micro_hotpath` bench and the `serve` CLI subcommand).

use crate::gcn::model::dense_affine;
use crate::gcn::oocgcn::{OocGcnLayer, StagingBacking, StagingConfig};
use crate::memsim::{GpuMem, OomError, Op, StagingMeter};
use crate::partition::robw::{materialize_into, robw_partition_par};
use crate::runtime::heal::{read_segment_healing, HealStats, RebuildSource};
use crate::runtime::pool::Pool;
use crate::runtime::segstore::SegmentRead;
use crate::sparse::spmm::{spmm_view_par_into, Dense};
use crate::sparse::Csr;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Poison-tolerant ledger lock (same contract as `gcn::pipeline`): the
/// ledger holds plain counters, so a panicking fan-out worker must not
/// mask its own payload behind a secondary `PoisonError` panic.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// One tenant's query: a feature panel and the layer to run it through,
/// against the batch's shared graph.
#[derive(Debug, Clone)]
pub struct TenantQuery {
    /// Node features, `[a_hat.nrows, f]`.
    pub x: Dense,
    /// Layer configuration (weights, bias, activation, segment budget).
    pub layer: OocGcnLayer,
}

/// Why a tenant's query was not answered.
#[derive(Debug, Clone)]
pub enum ServeError {
    /// The tenant's feature-panel reservation exceeded the ledger —
    /// rejected at admission, before any staging.
    Admission(OomError),
    /// The tenant's `seg_budget` differs from the batch plan's, so its
    /// query cannot ride this staged pass.
    PlanMismatch {
        /// The rejected tenant's segment budget.
        tenant_budget: u64,
        /// The budget the batch was planned with.
        batch_budget: u64,
    },
    /// The query's shapes do not fit the shared graph.
    BadQuery(String),
    /// The staged pass itself failed (planning, staging I/O, or segment
    /// ledger); every admitted tenant of the batch observes it.
    Streaming(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Admission(e) => write!(f, "admission rejected: {e}"),
            ServeError::PlanMismatch { tenant_budget, batch_budget } => write!(
                f,
                "segment budget {tenant_budget} does not match the batch plan's {batch_budget}"
            ),
            ServeError::BadQuery(msg) => write!(f, "bad query: {msg}"),
            ServeError::Streaming(msg) => write!(f, "streaming failure: {msg}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// What one [`serve_batch`] pass did.
#[derive(Debug, Clone, Default)]
pub struct BatchReport {
    /// RoBW segments the batch plan streamed.
    pub segments: usize,
    /// Tenants admitted onto the staged pass.
    pub tenants_admitted: usize,
    /// Tenants rejected (admission, plan mismatch, or bad shapes).
    pub tenants_rejected: usize,
    /// Total segment bytes staged — once per segment, independent of the
    /// tenant count.
    pub staged_bytes: u64,
    /// Ledger high-water mark over the pass.
    pub peak_gpu_bytes: u64,
    /// Staging depth the pass ran with.
    pub prefetch_depth: usize,
    /// Measured bytes read from the NVMe tier (disk backing only).
    pub disk_bytes: u64,
    /// Segment reads served by the host-RAM cache tier.
    pub cache_hits: usize,
    /// Segment reads that went to disk.
    pub cache_misses: usize,
    /// Recovery actions the pass's staging took (all-zero when fault-free;
    /// the only field allowed to differ from the fault-free oracle).
    pub heal: HealStats,
}

/// Ledger state shared between the staging producer and the fan-out
/// consumers: staged-but-unretired segment bytes (reconciled after an
/// abort) plus the one per-batch [`StagingMeter`].
struct BatchLedger<'a> {
    mem: &'a mut GpuMem,
    staged: u64,
    meter: StagingMeter,
    /// Recovery counters, separate from the oracle-compared meter.
    heal: HealStats,
}

/// Serve a batch of tenant queries with **one** staged pass of `a_hat`.
///
/// Scheduling, in fixed tenant order:
/// 1. Queries with shapes that do not fit the graph are rejected
///    ([`ServeError::BadQuery`]). The first well-formed query's
///    `seg_budget` fixes the batch plan; any other budget is rejected
///    ([`ServeError::PlanMismatch`]).
/// 2. Each remaining tenant charges its feature-panel bytes against the
///    ledger; an allocation failure rejects *that tenant only*
///    ([`ServeError::Admission`]) — the rest of the batch proceeds, and
///    nothing ever blocks on the ledger.
/// 3. The plan streams once through
///    [`run_fanout`](crate::runtime::prefetch::Prefetch::run_fanout):
///    every staged segment multiplies against each admitted tenant's
///    panel (each tenant's arithmetic identical to its solo pass), then
///    retires — segment ledger bytes freed and, with recycling, the slab
///    returned to the producer. A mid-stream failure aborts the pass and
///    surfaces as [`ServeError::Streaming`] on every admitted tenant.
///
/// On return the ledger is balanced on every path (panel reservations
/// and staged segments all freed), and each tenant's slot holds either
/// its combined output — byte-identical to its solo run — or the typed
/// error that kept it from completing.
pub fn serve_batch(
    a_hat: &Csr,
    queries: &[TenantQuery],
    mem: &mut GpuMem,
    pool: &Pool,
    staging: &StagingConfig,
) -> (Vec<Result<Dense, ServeError>>, BatchReport) {
    let nrows = a_hat.nrows;
    let nt = queries.len();
    let mut report =
        BatchReport { prefetch_depth: staging.prefetch.depth.max(1), ..BatchReport::default() };
    let mut out: Vec<Option<Result<Dense, ServeError>>> = (0..nt).map(|_| None).collect();

    // ---- 1. Validate shapes and fix the batch plan's budget. -----------
    let mut batch_budget: Option<u64> = None;
    let mut candidates: Vec<usize> = Vec::new();
    for (t, q) in queries.iter().enumerate() {
        if q.x.nrows != nrows {
            out[t] = Some(Err(ServeError::BadQuery(format!(
                "feature panel has {} rows, the shared graph has {nrows}",
                q.x.nrows
            ))));
            continue;
        }
        if q.layer.w.nrows != q.x.ncols {
            out[t] = Some(Err(ServeError::BadQuery(format!(
                "weight rows {} do not match the feature width {}",
                q.layer.w.nrows, q.x.ncols
            ))));
            continue;
        }
        match batch_budget {
            None => {
                batch_budget = Some(q.layer.seg_budget);
                candidates.push(t);
            }
            Some(b) if q.layer.seg_budget == b => candidates.push(t),
            Some(b) => {
                out[t] = Some(Err(ServeError::PlanMismatch {
                    tenant_budget: q.layer.seg_budget,
                    batch_budget: b,
                }))
            }
        }
    }
    let finish = |out: Vec<Option<Result<Dense, ServeError>>>, mut report: BatchReport| {
        report.tenants_rejected = nt - report.tenants_admitted;
        (
            out.into_iter()
                .map(|r| r.expect("every tenant slot resolved before return"))
                .collect(),
            report,
        )
    };
    let Some(budget) = batch_budget else {
        return finish(out, report);
    };

    // ---- 2. Plan once, verify the store, admit tenants. ----------------
    let plan = robw_partition_par(a_hat, budget, pool);
    report.segments = plan.len();
    report.staged_bytes = plan.iter().map(|s| s.bytes).sum();
    if let StagingBacking::Disk(store) = &staging.backing {
        if let Err(e) = store.check_plan(&plan) {
            let err =
                ServeError::Streaming(format!("segment store does not match the RoBW plan: {e}"));
            for t in candidates {
                out[t] = Some(Err(err.clone()));
            }
            return finish(out, report);
        }
    }
    let mut admitted: Vec<usize> = Vec::new();
    let mut panel_bytes: Vec<u64> = Vec::new();
    for &t in &candidates {
        let bytes = (nrows * queries[t].x.ncols * 4) as u64;
        match mem.alloc(bytes, "tenant feature panel") {
            Ok(()) => {
                admitted.push(t);
                panel_bytes.push(bytes);
            }
            Err(e) => out[t] = Some(Err(ServeError::Admission(e))),
        }
    }
    report.tenants_admitted = admitted.len();

    // Empty batch or 0-row graph: run the combines on empty aggregations
    // (the same degenerate path the pipeline takes), free the panels, done.
    if admitted.is_empty() || plan.is_empty() {
        for (k, &t) in admitted.iter().enumerate() {
            let q = &queries[t];
            let agg = Dense::zeros(nrows, q.x.ncols);
            out[t] = Some(Ok(dense_affine(&agg, &q.layer.w, &q.layer.b, q.layer.relu)));
            mem.free(panel_bytes[k]);
        }
        report.peak_gpu_bytes = mem.peak;
        return finish(out, report);
    }

    // ---- 3. One staged pass, fanned out across the batch. --------------
    let recycle = staging.recycle.as_deref();
    // Plan-wide scratch maxima for recycled in-memory staging (the disk
    // path uses the store's precomputed capacities).
    let (max_rows, max_nnz) = match (&staging.backing, recycle) {
        (StagingBacking::Memory, Some(_)) => (
            plan.iter().map(|s| s.row_hi - s.row_lo).max().unwrap_or(0),
            plan.iter().map(|s| s.nnz).max().unwrap_or(0),
        ),
        _ => (0, 0),
    };
    let mut aggs: Vec<Dense> = admitted
        .iter()
        .map(|&t| {
            let f = queries[t].x.ncols;
            match recycle {
                Some(rp) => Dense::from_vec(nrows, f, rp.take_panel(nrows * f)),
                None => Dense::zeros(nrows, f),
            }
        })
        .collect();
    let ledger = Mutex::new(BatchLedger {
        mem,
        staged: 0,
        meter: StagingMeter::default(),
        heal: HealStats::default(),
    });
    let plan_ref = &plan;
    // Each tenant's merge is serial *within* the tenant (the batch is the
    // parallel axis) and writes the same disjoint row ranges in the same
    // order as its solo pass — the view kernel computes rows
    // independently, so the bytes match the solo pool-parallel run too
    // (and a mapped read under `staging.mmap` multiplies straight off the
    // page cache, shared by every tenant of the batch).
    let serial = Pool::serial();
    let mut consumers: Vec<_> = aggs
        .iter_mut()
        .zip(&admitted)
        .map(|(agg, &t)| {
            let q = &queries[t];
            let f = q.x.ncols;
            let serial = &serial;
            move |i: usize, sub: &SegmentRead| -> Result<(), ServeError> {
                let seg = &plan_ref[i];
                spmm_view_par_into(
                    sub.view(),
                    &q.x,
                    serial,
                    &mut agg.data[seg.row_lo * f..seg.row_hi * f],
                );
                Ok(())
            }
        })
        .collect();
    let streamed = staging.prefetch.run_fanout(
        pool,
        plan.len(),
        // Producer: charge the segment once, stage it once.
        |i: usize, reuse: Option<Csr>| -> Result<SegmentRead, ServeError> {
            let seg = &plan_ref[i];
            {
                let mut led = lock(&ledger);
                led.mem.alloc(seg.bytes, "RoBW segment").map_err(|e| {
                    ServeError::Streaming(format!("segment {i} does not fit: {e}"))
                })?;
                led.staged += seg.bytes;
            }
            match &staging.backing {
                StagingBacking::Memory => {
                    let mut sub = match (reuse, recycle) {
                        (Some(m), _) => m,
                        (None, Some(rp)) => rp.take_csr(max_rows, max_nnz),
                        (None, None) => Csr::empty(0, 0),
                    };
                    materialize_into(a_hat, seg, &mut sub);
                    if let Some(cm) = &staging.io_cost {
                        let dur = cm.transfer_secs(Op::HtoD, seg.bytes);
                        std::thread::sleep(std::time::Duration::from_secs_f64(dur));
                    }
                    Ok(SegmentRead::Owned(sub))
                }
                StagingBacking::Disk(store) => {
                    // Pass-through under the default policy; recovery
                    // stats land on the ledger even when the read fails.
                    let mut heal = HealStats::default();
                    let res = read_segment_healing(
                        store,
                        i,
                        reuse,
                        recycle,
                        staging.mmap,
                        &staging.heal,
                        staging.chaos.as_deref(),
                        Some(RebuildSource { a: a_hat, seg }),
                        &mut heal,
                    );
                    let mut led = lock(&ledger);
                    led.heal.merge(&heal);
                    let (sub, origin) = res.map_err(|e| {
                        ServeError::Streaming(format!("staging segment {i} from disk: {e}"))
                    })?;
                    led.meter.record(origin.disk_bytes, origin.cache_hit);
                    Ok(sub)
                }
            }
        },
        &mut consumers,
        // Retire: runs only after the last tenant drained the segment —
        // free its ledger bytes and recycle the slab.
        |i: usize, sub: SegmentRead| {
            let seg = &plan_ref[i];
            let mut led = lock(&ledger);
            led.mem.free(seg.bytes);
            led.staged -= seg.bytes;
            Ok(if recycle.is_some() { sub.reclaim() } else { None })
        },
    );
    drop(consumers);

    // The stream has joined; reconcile whatever an abort stranded.
    let led = ledger.into_inner().unwrap_or_else(PoisonError::into_inner);
    if led.staged > 0 {
        led.mem.free(led.staged);
    }
    report.disk_bytes = led.meter.disk_bytes;
    report.cache_hits = led.meter.cache_hits;
    report.cache_misses = led.meter.cache_misses;
    report.heal = led.heal;
    match streamed {
        Ok(leftovers) => {
            if let Some(rp) = recycle {
                for m in leftovers {
                    rp.put_csr(m);
                }
            }
            for (k, &t) in admitted.iter().enumerate() {
                let q = &queries[t];
                out[t] = Some(Ok(dense_affine(&aggs[k], &q.layer.w, &q.layer.b, q.layer.relu)));
            }
        }
        Err(e) => {
            for &t in &admitted {
                out[t] = Some(Err(e.clone()));
            }
        }
    }
    // Retire the aggregation slabs and release every panel reservation —
    // the ledger balances on the success and the abort path alike.
    if let Some(rp) = recycle {
        for agg in aggs {
            rp.put_panel(agg.data);
        }
    }
    for &bytes in &panel_bytes {
        led.mem.free(bytes);
    }
    report.peak_gpu_bytes = led.mem.peak;
    finish(out, report)
}

/// Nearest-rank percentile of an ascending-sorted sample set (`p` in
/// `[0, 100]`; `NaN` on an empty set). Deterministic: no interpolation,
/// just the sample at the scaled rank. Delegates to
/// [`crate::util::percentile`] — the same function the perf-trajectory
/// statistics use, so serve reports and `bench report` tables agree.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    crate::util::percentile(sorted, p)
}

/// Open-loop load profile for [`serve_open_loop`].
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Requests each tenant issues over the run.
    pub requests_per_tenant: usize,
    /// Aggregate arrival rate in requests per second (the schedule is
    /// fixed up front — arrivals do not wait for completions, hence
    /// "open loop").
    pub rate_hz: f64,
    /// Most requests answered by one staged pass.
    pub max_batch: usize,
}

impl Default for OpenLoopConfig {
    fn default() -> OpenLoopConfig {
        OpenLoopConfig { requests_per_tenant: 8, rate_hz: 64.0, max_batch: 16 }
    }
}

/// One tenant's latency summary over an open-loop run.
#[derive(Debug, Clone)]
pub struct TenantLatency {
    /// Tenant index.
    pub tenant: usize,
    /// Requests answered with an output.
    pub completed: usize,
    /// Requests rejected with a typed error.
    pub rejected: usize,
    /// Median request latency in seconds (`NaN` with no completions).
    pub p50_s: f64,
    /// 99th-percentile request latency in seconds.
    pub p99_s: f64,
}

/// Aggregate report of one open-loop serving run.
#[derive(Debug, Clone, Default)]
pub struct ServeReport {
    /// Tenants in the catalog.
    pub tenants: usize,
    /// Total requests issued.
    pub requests: usize,
    /// Staged passes run.
    pub batches: usize,
    /// Segments streamed across all passes.
    pub segments_streamed: usize,
    /// Wall-clock of the run in seconds.
    pub wall_s: f64,
    /// Aggregate staged-segment throughput (`segments_streamed / wall_s`).
    pub segments_per_s: f64,
    /// Whether the ledger returned to its pre-run level after every batch.
    pub ledger_balanced: bool,
    /// Requests rejected with a typed error, summed over every tenant —
    /// the headline degraded-service signal (per-tenant breakdowns live in
    /// [`Self::per_tenant`]). The CI serve smoke gates on this being 0.
    pub rejected_total: usize,
    /// Recovery actions across every staged pass of the run (all-zero
    /// when fault-free).
    pub heal: HealStats,
    /// Per-tenant latency summaries, in tenant order.
    pub per_tenant: Vec<TenantLatency>,
}

impl ServeReport {
    /// JSON object mirroring the report (the `BENCH_streaming.json` /
    /// `serve` CLI emission format).
    pub fn to_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert("tenants".to_string(), Json::Num(self.tenants as f64));
        root.insert("requests".to_string(), Json::Num(self.requests as f64));
        root.insert("batches".to_string(), Json::Num(self.batches as f64));
        root.insert("segments_streamed".to_string(), Json::Num(self.segments_streamed as f64));
        root.insert("wall_s".to_string(), Json::Num(self.wall_s));
        root.insert("segments_per_s".to_string(), Json::Num(self.segments_per_s));
        root.insert("ledger_balanced".to_string(), Json::Bool(self.ledger_balanced));
        root.insert("rejected_total".to_string(), Json::Num(self.rejected_total as f64));
        let mut heal = BTreeMap::new();
        heal.insert("injected".to_string(), Json::Num(self.heal.injected as f64));
        heal.insert("retries".to_string(), Json::Num(self.heal.retries as f64));
        heal.insert("slow_reads".to_string(), Json::Num(self.heal.slow_reads as f64));
        heal.insert("quarantined".to_string(), Json::Num(self.heal.quarantined as f64));
        heal.insert("rebuilt".to_string(), Json::Num(self.heal.rebuilt as f64));
        heal.insert("backoff_bytes".to_string(), Json::Num(self.heal.backoff_bytes as f64));
        root.insert("heal".to_string(), Json::Obj(heal));
        let mut tenants = BTreeMap::new();
        for t in &self.per_tenant {
            let mut entry = BTreeMap::new();
            entry.insert("completed".to_string(), Json::Num(t.completed as f64));
            entry.insert("rejected".to_string(), Json::Num(t.rejected as f64));
            entry.insert("p50_s".to_string(), Json::Num(t.p50_s));
            entry.insert("p99_s".to_string(), Json::Num(t.p99_s));
            tenants.insert(format!("tenant_{}", t.tenant), Json::Obj(entry));
        }
        root.insert("per_tenant".to_string(), Json::Obj(tenants));
        Json::Obj(root)
    }
}

/// Drive [`serve_batch`] under open-loop load: requests arrive round-robin
/// across `queries` at a fixed aggregate rate, pending requests batch (up
/// to `max_batch` — deduplicated per tenant, since identical queries share
/// one answer) onto staged passes, and every request's latency is measured
/// arrival-to-completion. Returns per-tenant p50/p99 latency and aggregate
/// segments/s.
pub fn serve_open_loop(
    a_hat: &Csr,
    queries: &[TenantQuery],
    mem: &mut GpuMem,
    pool: &Pool,
    staging: &StagingConfig,
    cfg: &OpenLoopConfig,
) -> ServeReport {
    let nt = queries.len();
    let total = nt * cfg.requests_per_tenant;
    let rate = if cfg.rate_hz > 0.0 { cfg.rate_hz } else { 1.0 };
    let max_batch = cfg.max_batch.max(1);
    let baseline_used = mem.used;
    let mut report = ServeReport {
        tenants: nt,
        requests: total,
        ledger_balanced: true,
        ..ServeReport::default()
    };
    let mut samples: Vec<Vec<f64>> = vec![Vec::new(); nt];
    let mut rejected = vec![0usize; nt];
    let start = Instant::now();
    let mut next = 0usize; // next request (global index) not yet served
    while next < total {
        // Open loop: arrival `k` is due at `k / rate`, regardless of how
        // the server is keeping up. Sleep only when ahead of the schedule.
        let due = next as f64 / rate;
        let now = start.elapsed().as_secs_f64();
        if now < due {
            std::thread::sleep(std::time::Duration::from_secs_f64(due - now));
        }
        let now = start.elapsed().as_secs_f64();
        let mut batch: Vec<usize> = Vec::new();
        while next < total && (next as f64 / rate) <= now && batch.len() < max_batch {
            batch.push(next);
            next += 1;
        }
        // Distinct tenants of the pending batch, in fixed tenant order —
        // a tenant's duplicate requests share the one answer.
        let mut tenant_ids: Vec<usize> = batch.iter().map(|&r| r % nt).collect();
        tenant_ids.sort_unstable();
        tenant_ids.dedup();
        let batch_queries: Vec<TenantQuery> =
            tenant_ids.iter().map(|&t| queries[t].clone()).collect();
        let (results, brep) = serve_batch(a_hat, &batch_queries, mem, pool, staging);
        report.batches += 1;
        report.segments_streamed += brep.segments;
        report.heal.merge(&brep.heal);
        if mem.used != baseline_used {
            report.ledger_balanced = false;
        }
        let done = start.elapsed().as_secs_f64();
        for &r in &batch {
            let t = r % nt;
            let k = tenant_ids.binary_search(&t).expect("tenant is in the batch");
            match &results[k] {
                Ok(_) => samples[t].push(done - r as f64 / rate),
                Err(_) => rejected[t] += 1,
            }
        }
    }
    report.wall_s = start.elapsed().as_secs_f64();
    report.segments_per_s = if report.wall_s > 0.0 {
        report.segments_streamed as f64 / report.wall_s
    } else {
        0.0
    };
    report.rejected_total = rejected.iter().sum();
    report.per_tenant = (0..nt)
        .map(|t| {
            let mut lat = std::mem::take(&mut samples[t]);
            lat.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
            TenantLatency {
                tenant: t,
                completed: lat.len(),
                rejected: rejected[t],
                p50_s: percentile(&lat, 50.0),
                p99_s: percentile(&lat, 99.0),
            }
        })
        .collect();
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::GpuMem;
    use crate::runtime::recycle::BufferPool;
    use crate::runtime::segstore::SegmentStore;
    use crate::sparse::norm::normalize_adjacency;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg;
    use std::sync::Arc;

    fn test_graph(seed: u64, nodes: usize) -> Csr {
        let mut rng = Pcg::seed(seed);
        normalize_adjacency(&crate::graphgen::kmer::generate(&mut rng, nodes, 3.0))
    }

    fn tenant(rng: &mut Pcg, nrows: usize, f: usize, h: usize, budget: u64) -> TenantQuery {
        TenantQuery {
            x: Dense::from_vec(nrows, f, (0..nrows * f).map(|_| rng.normal() as f32).collect()),
            layer: OocGcnLayer {
                w: Dense::from_vec(
                    f,
                    h,
                    (0..f * h).map(|_| (rng.normal() * 0.2) as f32).collect(),
                ),
                b: vec![0.05; h],
                relu: true,
                seg_budget: budget,
            },
        }
    }

    #[test]
    fn batch_matches_solo_runs_byte_for_byte() {
        let a_hat = test_graph(91, 200);
        let mut rng = Pcg::seed(92);
        let queries: Vec<TenantQuery> =
            (0..3).map(|t| tenant(&mut rng, 200, 8 + 4 * t, 6, 2048)).collect();
        let pool = Pool::new(4);
        let staging = StagingConfig::depth(2);
        let mut mem = GpuMem::new(1 << 30);
        let (results, rep) = serve_batch(&a_hat, &queries, &mut mem, &pool, &staging);
        assert_eq!(rep.tenants_admitted, 3);
        assert_eq!(mem.used, 0, "ledger balances after the pass");
        for (t, (r, q)) in results.iter().zip(&queries).enumerate() {
            let got = r.as_ref().unwrap_or_else(|e| panic!("tenant {t}: {e}"));
            let mut solo_mem = GpuMem::new(1 << 30);
            let (want, _) = q
                .layer
                .forward_cpu(&a_hat, &q.x, &mut solo_mem, &pool, &staging)
                .unwrap();
            assert_eq!(got, &want, "tenant {t} diverged from its solo pass");
        }
    }

    #[test]
    fn admission_rejects_with_typed_error_and_balances() {
        let a_hat = test_graph(93, 150);
        let mut rng = Pcg::seed(94);
        let queries: Vec<TenantQuery> =
            (0..3).map(|_| tenant(&mut rng, 150, 16, 4, 2048)).collect();
        let panel = (150 * 16 * 4) as u64;
        let plan_max: u64 = robw_partition_par(&a_hat, 2048, &Pool::serial())
            .iter()
            .map(|s| s.bytes)
            .max()
            .unwrap();
        // Room for two panels plus staging headroom, but not three panels.
        let mut mem = GpuMem::new(2 * panel + 3 * plan_max);
        let (results, rep) =
            serve_batch(&a_hat, &queries, &mut mem, &Pool::new(2), &StagingConfig::depth(2));
        assert_eq!(rep.tenants_admitted, 2);
        assert_eq!(rep.tenants_rejected, 1);
        assert!(results[0].is_ok());
        assert!(results[1].is_ok());
        assert!(
            matches!(results[2], Err(ServeError::Admission(_))),
            "third tenant must be rejected, got {:?}",
            results[2]
        );
        assert_eq!(mem.used, 0, "rejected tenants leave nothing allocated");
    }

    #[test]
    fn plan_mismatch_and_bad_shapes_are_typed_rejections() {
        let a_hat = test_graph(95, 120);
        let mut rng = Pcg::seed(96);
        let good = tenant(&mut rng, 120, 8, 4, 2048);
        let other_budget = tenant(&mut rng, 120, 8, 4, 4096);
        let wrong_rows = tenant(&mut rng, 60, 8, 4, 2048);
        let mut unchained = tenant(&mut rng, 120, 8, 4, 2048);
        unchained.layer.w = Dense::zeros(5, 4);
        let queries = vec![good, other_budget, wrong_rows, unchained];
        let mut mem = GpuMem::new(1 << 30);
        let (results, rep) =
            serve_batch(&a_hat, &queries, &mut mem, &Pool::serial(), &StagingConfig::serial());
        assert!(results[0].is_ok());
        assert!(matches!(
            results[1],
            Err(ServeError::PlanMismatch { tenant_budget: 4096, batch_budget: 2048 })
        ));
        assert!(matches!(results[2], Err(ServeError::BadQuery(_))));
        assert!(matches!(results[3], Err(ServeError::BadQuery(_))));
        assert_eq!(rep.tenants_admitted, 1);
        assert_eq!(rep.tenants_rejected, 3);
        assert_eq!(mem.used, 0);
    }

    #[test]
    fn disk_backed_batch_stages_each_segment_once() {
        let a_hat = test_graph(97, 180);
        let mut rng = Pcg::seed(98);
        let queries: Vec<TenantQuery> =
            (0..4).map(|_| tenant(&mut rng, 180, 8, 4, 2048)).collect();
        let plan = robw_partition_par(&a_hat, 2048, &Pool::serial());
        let dir = TempDir::new("serve-disk");
        let store = Arc::new(SegmentStore::spill(&a_hat, &plan, dir.path(), 0).unwrap());
        let rp = Arc::new(BufferPool::new(64 << 20));
        let staging = StagingConfig::disk(store, 2).with_recycle(rp);
        let mut mem = GpuMem::new(1 << 30);
        let (results, rep) = serve_batch(&a_hat, &queries, &mut mem, &Pool::new(4), &staging);
        assert!(results.iter().all(|r| r.is_ok()));
        assert_eq!(rep.cache_misses, plan.len(), "every segment read exactly once");
        assert_eq!(rep.cache_hits, 0);
        let file_bytes: u64 = (0..plan.len())
            .map(|i| match &staging.backing {
                StagingBacking::Disk(s) => s.meta(i).file_bytes,
                _ => unreachable!(),
            })
            .sum();
        assert_eq!(rep.disk_bytes, file_bytes, "I/O charged once per segment, not per tenant");
        assert_eq!(mem.used, 0);
    }

    #[test]
    fn mmap_batch_matches_solo_runs_byte_for_byte() {
        let a_hat = test_graph(105, 180);
        let mut rng = Pcg::seed(106);
        let queries: Vec<TenantQuery> =
            (0..3).map(|_| tenant(&mut rng, 180, 8, 4, 2048)).collect();
        let plan = robw_partition_par(&a_hat, 2048, &Pool::serial());
        let dir = TempDir::new("serve-mmap");
        for enc in [
            crate::sparse::segio::SegEncoding::Raw,
            crate::sparse::segio::SegEncoding::Packed,
        ] {
            let store = Arc::new(
                SegmentStore::open_or_spill_encoded(&a_hat, &plan, dir.path(), 0, enc)
                    .unwrap(),
            );
            let staging = StagingConfig::disk(store, 2).with_mmap(true);
            let mut mem = GpuMem::new(1 << 30);
            let (results, rep) =
                serve_batch(&a_hat, &queries, &mut mem, &Pool::new(4), &staging);
            assert_eq!(rep.tenants_admitted, 3);
            assert_eq!(rep.cache_misses, plan.len(), "mapped reads bypass the host cache");
            assert_eq!(mem.used, 0);
            for (t, (r, q)) in results.iter().zip(&queries).enumerate() {
                let got = r.as_ref().unwrap_or_else(|e| panic!("tenant {t} ({enc}): {e}"));
                let mut solo_mem = GpuMem::new(1 << 30);
                let (want, _) = q
                    .layer
                    .forward_cpu(&a_hat, &q.x, &mut solo_mem, &Pool::new(4), &staging)
                    .unwrap();
                assert_eq!(got, &want, "tenant {t} ({enc}) diverged from its solo pass");
            }
        }
    }

    #[test]
    fn empty_and_degenerate_batches_resolve() {
        let a_hat = test_graph(99, 100);
        let mut mem = GpuMem::new(1 << 20);
        let (results, rep) =
            serve_batch(&a_hat, &[], &mut mem, &Pool::serial(), &StagingConfig::serial());
        assert!(results.is_empty());
        assert_eq!(rep.tenants_admitted, 0);
        assert_eq!(mem.used, 0);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 50.0), 7.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 51.0);
        assert_eq!(percentile(&v, 99.0), 99.0);
        assert_eq!(percentile(&v, 100.0), 100.0);
    }

    #[test]
    fn open_loop_reports_finite_latencies_and_balanced_ledger() {
        let a_hat = test_graph(101, 150);
        let mut rng = Pcg::seed(102);
        let queries: Vec<TenantQuery> =
            (0..2).map(|_| tenant(&mut rng, 150, 8, 4, 2048)).collect();
        let mut mem = GpuMem::new(1 << 30);
        let cfg = OpenLoopConfig { requests_per_tenant: 4, rate_hz: 400.0, max_batch: 8 };
        let rep = serve_open_loop(
            &a_hat,
            &queries,
            &mut mem,
            &Pool::new(2),
            &StagingConfig::depth(2),
            &cfg,
        );
        assert_eq!(rep.requests, 8);
        assert!(rep.batches >= 1);
        assert!(rep.ledger_balanced, "ledger must return to baseline after every batch");
        assert_eq!(rep.per_tenant.len(), 2);
        for t in &rep.per_tenant {
            assert_eq!(t.completed + t.rejected, 4);
            assert!(t.completed > 0, "tenant {} completed nothing", t.tenant);
            assert!(t.p50_s.is_finite() && t.p50_s >= 0.0);
            assert!(t.p99_s.is_finite() && t.p99_s >= t.p50_s);
        }
        assert!(rep.segments_per_s > 0.0);
        assert_eq!(
            rep.rejected_total,
            rep.per_tenant.iter().map(|t| t.rejected).sum::<usize>(),
            "aggregate rejection count must match the per-tenant breakdown"
        );
        let json = format!("{}", rep.to_json());
        assert!(json.contains("p99_s"), "{json}");
        assert!(json.contains("tenant_1"), "{json}");
        assert!(json.contains("\"rejected_total\":0"), "{json}");
        assert!(json.contains("\"quarantined\":0"), "{json}");
    }

    #[test]
    fn rejected_tenants_are_visible_in_the_open_loop_report() {
        let a_hat = test_graph(103, 150);
        let mut rng = Pcg::seed(104);
        let queries: Vec<TenantQuery> =
            (0..2).map(|_| tenant(&mut rng, 150, 8, 4, 2048)).collect();
        // Ledger fits one tenant panel (plus staging headroom), not two:
        // whenever both tenants batch together, one is rejected.
        let panel = (150 * 8 * 4) as u64;
        let plan_max: u64 = robw_partition_par(&a_hat, 2048, &Pool::serial())
            .iter()
            .map(|s| s.bytes)
            .max()
            .unwrap();
        let mut mem = GpuMem::new(panel + 3 * plan_max);
        let cfg = OpenLoopConfig { requests_per_tenant: 3, rate_hz: 1000.0, max_batch: 8 };
        let rep = serve_open_loop(
            &a_hat,
            &queries,
            &mut mem,
            &Pool::new(2),
            &StagingConfig::depth(1),
            &cfg,
        );
        assert!(rep.rejected_total > 0, "admission pressure must reject someone");
        assert_eq!(
            rep.rejected_total,
            rep.per_tenant.iter().map(|t| t.rejected).sum::<usize>()
        );
        assert!(rep.ledger_balanced);
        let json = format!("{}", rep.to_json());
        assert!(
            json.contains(&format!("\"rejected_total\":{}", rep.rejected_total)),
            "degraded service must be visible in the JSON report: {json}"
        );
    }
}
