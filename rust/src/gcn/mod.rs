//! GCN model layer: the workload the paper's system exists to serve
//! (Eqs. 1-4). Two execution paths:
//!
//! * [`model`] — a pure-rust reference GCN (sparse aggregation + dense
//!   combine) used as the correctness oracle and for CPU-side shares;
//! * [`oocgcn`] — the out-of-core path: RoBW-partitioned aggregation
//!   executed tile-by-tile through the PJRT `bsr_spmm` artifact, combined
//!   through the fused `gcn_combine` artifact — the real compute that the
//!   scheduler simulations model at paper scale;
//! * [`pipeline`] — the cross-layer streaming executor: an N-layer
//!   forward under one scheduler, overlapping layer `l`'s Phase III
//!   combine with layer `l+1`'s Phase I/II staging and optionally
//!   spilling intermediate feature panels through the tiered store;
//! * [`train`] — the e2e training driver looping the `gcn2_train_step`
//!   artifact (loss curve in EXPERIMENTS.md).

pub mod model;
pub mod oocgcn;
pub mod pipeline;
pub mod train;

pub use model::Gcn2Ref;
pub use oocgcn::{LayerReport, OocGcnLayer, StagingBacking, StagingConfig};
pub use pipeline::{OocGcnModel, PipelineConfig, PipelineReport};
pub use train::Trainer;
