//! GCN model layer: the workload the paper's system exists to serve
//! (Eqs. 1-4). Two execution paths:
//!
//! * [`model`] — a pure-rust reference GCN (sparse aggregation + dense
//!   combine) used as the correctness oracle and for CPU-side shares;
//! * [`oocgcn`] — the out-of-core path: RoBW-partitioned aggregation
//!   executed tile-by-tile through the PJRT `bsr_spmm` artifact, combined
//!   through the fused `gcn_combine` artifact — the real compute that the
//!   scheduler simulations model at paper scale;
//! * [`pipeline`] — the cross-layer streaming executor: an N-layer
//!   forward under one scheduler, overlapping layer `l`'s Phase III
//!   combine with layer `l+1`'s Phase I/II staging and optionally
//!   spilling intermediate feature panels through the tiered store;
//! * [`serve`] — the multi-tenant batched inference front end: one
//!   staged pass of the adjacency fanned out across N admitted tenant
//!   queries, with admission control against the [`GpuMem`](crate::memsim::GpuMem)
//!   ledger and open-loop latency reporting;
//! * [`train`] — the e2e training driver looping the `gcn2_train_step`
//!   artifact (loss curve in EXPERIMENTS.md);
//! * [`train_stream`] — out-of-core training end to end: the streamed
//!   backward pass reversing the concatenated RoBW plan, gradient panels
//!   through the tiered store, and the recompute-vs-reload policy for
//!   aggregated inputs, with the dense CPU path as its bitwise oracle;
//! * [`checkpoint`] — versioned, checksummed training checkpoints
//!   (parameters + step index + policy + RNG state) written with the
//!   write-temp-then-rename discipline, so a streamed run killed between
//!   steps resumes to bitwise-identical final parameters.

pub mod checkpoint;
pub mod model;
pub mod oocgcn;
pub mod pipeline;
pub mod serve;
pub mod train;
pub mod train_stream;

pub use checkpoint::Checkpoint;
pub use model::Gcn2Ref;
pub use oocgcn::{LayerReport, OocGcnLayer, StagingBacking, StagingConfig};
pub use pipeline::{OocGcnModel, PipelineConfig, PipelineReport};
pub use serve::{
    serve_batch, serve_open_loop, BatchReport, OpenLoopConfig, ServeError, ServeReport,
    TenantQuery,
};
pub use train::Trainer;
pub use train_stream::{RecomputePolicy, StepReport, StreamedTrainer, TrainStreamConfig};
