//! Pure-rust reference GCN (the CPU oracle for the accelerator path).

use crate::sparse::spmm::{spmm, Dense};
use crate::sparse::Csr;
use crate::util::rng::Pcg;

/// Dense matmul helper: x [m,k] · w [k,n] + b [n].
pub fn dense_affine(x: &Dense, w: &Dense, b: &[f32], relu: bool) -> Dense {
    assert_eq!(x.ncols, w.nrows);
    assert_eq!(w.ncols, b.len());
    let mut out = Dense::zeros(x.nrows, w.ncols);
    for i in 0..x.nrows {
        for l in 0..x.ncols {
            let xv = x.at(i, l);
            if xv == 0.0 {
                continue;
            }
            for j in 0..w.ncols {
                *out.at_mut(i, j) += xv * w.at(l, j);
            }
        }
        for j in 0..w.ncols {
            let v = out.at(i, j) + b[j];
            *out.at_mut(i, j) = if relu { v.max(0.0) } else { v };
        }
    }
    out
}

/// Two-layer reference GCN: logits = Â·relu(Â·X·W1 + b1)·W2 + b2.
pub struct Gcn2Ref {
    /// First-layer weights `[f0, hidden]`.
    pub w1: Dense,
    /// First-layer bias.
    pub b1: Vec<f32>,
    /// Second-layer weights `[hidden, classes]`.
    pub w2: Dense,
    /// Second-layer bias.
    pub b2: Vec<f32>,
}

impl Gcn2Ref {
    /// Small random init (scale 0.3, matching the python tests).
    pub fn init(rng: &mut Pcg, f0: usize, hidden: usize, classes: usize) -> Gcn2Ref {
        let mk = |rng: &mut Pcg, r: usize, c: usize| {
            Dense::from_vec(r, c, (0..r * c).map(|_| (rng.normal() * 0.3) as f32).collect())
        };
        Gcn2Ref {
            w1: mk(rng, f0, hidden),
            b1: vec![0.0; hidden],
            w2: mk(rng, hidden, classes),
            b2: vec![0.0; classes],
        }
    }

    /// Forward pass with a normalized adjacency Â in CSR.
    pub fn forward(&self, a_hat: &Csr, x: &Dense) -> Dense {
        let agg1 = spmm(a_hat, x);
        let h1 = dense_affine(&agg1, &self.w1, &self.b1, true);
        let agg2 = spmm(a_hat, &h1);
        dense_affine(&agg2, &self.w2, &self.b2, false)
    }

    /// Mean softmax cross-entropy over integer labels.
    pub fn loss(&self, a_hat: &Csr, x: &Dense, y: &[i32]) -> f64 {
        let logits = self.forward(a_hat, x);
        softmax_xent(&logits, y)
    }
}

/// Mean softmax cross-entropy (stable).
pub fn softmax_xent(logits: &Dense, y: &[i32]) -> f64 {
    assert_eq!(logits.nrows, y.len());
    let mut total = 0f64;
    for i in 0..logits.nrows {
        let row = logits.row(i);
        let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let logz: f64 = (row.iter().map(|&v| ((v - maxv) as f64).exp()).sum::<f64>()).ln()
            + maxv as f64;
        total += logz - row[y[i] as usize] as f64;
    }
    total / logits.nrows as f64
}

/// Classification accuracy of logits vs labels.
pub fn accuracy(logits: &Dense, y: &[i32]) -> f64 {
    let mut hit = 0usize;
    for i in 0..logits.nrows {
        let row = logits.row(i);
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if pred == y[i] as usize {
            hit += 1;
        }
    }
    hit as f64 / logits.nrows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::norm::normalize_adjacency;
    use crate::sparse::Coo;

    fn ring(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            let j = (i + 1) % n;
            coo.push(i as u32, j as u32, 1.0);
            coo.push(j as u32, i as u32, 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Pcg::seed(1);
        let a = normalize_adjacency(&ring(32));
        let x = Dense::from_vec(32, 8, (0..32 * 8).map(|_| rng.normal() as f32).collect());
        let model = Gcn2Ref::init(&mut rng, 8, 16, 4);
        let out = model.forward(&a, &x);
        assert_eq!((out.nrows, out.ncols), (32, 4));
    }

    #[test]
    fn xent_of_uniform_logits_is_log_c() {
        let logits = Dense::zeros(10, 4);
        let y: Vec<i32> = (0..10).map(|i| (i % 4) as i32).collect();
        let l = softmax_xent(&logits, &y);
        assert!((l - (4f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn accuracy_perfect_and_zero() {
        let mut logits = Dense::zeros(4, 2);
        for i in 0..4 {
            *logits.at_mut(i, i % 2) = 5.0;
        }
        let y: Vec<i32> = (0..4).map(|i| (i % 2) as i32).collect();
        assert_eq!(accuracy(&logits, &y), 1.0);
        let wrong: Vec<i32> = (0..4).map(|i| ((i + 1) % 2) as i32).collect();
        assert_eq!(accuracy(&logits, &wrong), 0.0);
    }

    #[test]
    fn dense_affine_relu_matches_manual() {
        let x = Dense::from_vec(1, 2, vec![1.0, -2.0]);
        let w = Dense::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = dense_affine(&x, &w, &[0.0, 0.5], true);
        assert_eq!(out.data, vec![1.0, 0.0]); // -2 + 0.5 clamped
    }
}
