//! Pure-rust reference GCN (the CPU oracle for the accelerator path).

use crate::runtime::pool::Pool;
use crate::sparse::spmm::{spmm, Dense};
use crate::sparse::Csr;
use crate::util::rng::Pcg;

/// Dense matmul helper: x [m,k] · w [k,n] + b [n].
pub fn dense_affine(x: &Dense, w: &Dense, b: &[f32], relu: bool) -> Dense {
    assert_eq!(x.ncols, w.nrows);
    assert_eq!(w.ncols, b.len());
    let mut out = Dense::zeros(x.nrows, w.ncols);
    for i in 0..x.nrows {
        for l in 0..x.ncols {
            let xv = x.at(i, l);
            if xv == 0.0 {
                continue;
            }
            for j in 0..w.ncols {
                *out.at_mut(i, j) += xv * w.at(l, j);
            }
        }
        for j in 0..w.ncols {
            let v = out.at(i, j) + b[j];
            *out.at_mut(i, j) = if relu { v.max(0.0) } else { v };
        }
    }
    out
}

/// Two-layer reference GCN: logits = Â·relu(Â·X·W1 + b1)·W2 + b2.
pub struct Gcn2Ref {
    /// First-layer weights `[f0, hidden]`.
    pub w1: Dense,
    /// First-layer bias.
    pub b1: Vec<f32>,
    /// Second-layer weights `[hidden, classes]`.
    pub w2: Dense,
    /// Second-layer bias.
    pub b2: Vec<f32>,
}

impl Gcn2Ref {
    /// Small random init (scale 0.3, matching the python tests).
    pub fn init(rng: &mut Pcg, f0: usize, hidden: usize, classes: usize) -> Gcn2Ref {
        let mk = |rng: &mut Pcg, r: usize, c: usize| {
            Dense::from_vec(r, c, (0..r * c).map(|_| (rng.normal() * 0.3) as f32).collect())
        };
        Gcn2Ref {
            w1: mk(rng, f0, hidden),
            b1: vec![0.0; hidden],
            w2: mk(rng, hidden, classes),
            b2: vec![0.0; classes],
        }
    }

    /// Forward pass with a normalized adjacency Â in CSR.
    pub fn forward(&self, a_hat: &Csr, x: &Dense) -> Dense {
        let agg1 = spmm(a_hat, x);
        let h1 = dense_affine(&agg1, &self.w1, &self.b1, true);
        let agg2 = spmm(a_hat, &h1);
        dense_affine(&agg2, &self.w2, &self.b2, false)
    }

    /// Mean softmax cross-entropy over integer labels.
    pub fn loss(&self, a_hat: &Csr, x: &Dense, y: &[i32]) -> f64 {
        let logits = self.forward(a_hat, x);
        softmax_xent(&logits, y)
    }
}

/// Mean softmax cross-entropy (stable).
pub fn softmax_xent(logits: &Dense, y: &[i32]) -> f64 {
    assert_eq!(logits.nrows, y.len());
    let mut total = 0f64;
    for i in 0..logits.nrows {
        let row = logits.row(i);
        let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let logz: f64 = (row.iter().map(|&v| ((v - maxv) as f64).exp()).sum::<f64>()).ln()
            + maxv as f64;
        total += logz - row[y[i] as usize] as f64;
    }
    total / logits.nrows as f64
}

/// Mean softmax cross-entropy *and* its gradient w.r.t. the logits:
/// `grad[i][c] = (softmax(row_i)[c] - [c == y_i]) / nrows`.
///
/// The loss arithmetic is exactly [`softmax_xent`]'s, operation for
/// operation (f64 shifted-exp sum, same fold for the row max), so a
/// trainer that reports this loss is bitwise comparable to one that
/// calls `softmax_xent` on the same logits. Probabilities are formed in
/// f64 from the same shifted exps and cast to f32 at the end.
pub fn softmax_xent_grad(logits: &Dense, y: &[i32]) -> (f64, Dense) {
    assert_eq!(logits.nrows, y.len());
    let n = logits.nrows;
    let mut grad = Dense::zeros(n, logits.ncols);
    let mut total = 0f64;
    for i in 0..n {
        let row = logits.row(i);
        let maxv = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&v| ((v - maxv) as f64).exp()).sum();
        let logz: f64 = sum.ln() + maxv as f64;
        total += logz - row[y[i] as usize] as f64;
        let grow = &mut grad.data[i * logits.ncols..(i + 1) * logits.ncols];
        for (g, &v) in grow.iter_mut().zip(row.iter()) {
            *g = ((((v - maxv) as f64).exp() / sum) / n as f64) as f32;
        }
        grow[y[i] as usize] -= 1.0 / n as f32;
    }
    (total / n as f64, grad)
}

/// `dw += aᵀ · dz` for one row range: `a` is `rows × f` and `dz` is
/// `rows × h`, both row-major slices; `dw` is the `f × h` weight-gradient
/// accumulator. The row loop is outermost and ascending, so accumulating
/// segment-by-segment over ascending row ranges produces the identical
/// f32 addition sequence per `dw` element as one whole-matrix call — the
/// property that makes the recompute policy, the reload policy, and the
/// dense oracle bitwise interchangeable. Parallel over `dw` rows (each
/// input column `i` owns a disjoint `dw` row), deterministically.
pub fn add_at_b(dw: &mut Dense, a: &[f32], dz: &[f32], rows: usize, pool: &Pool) {
    let (f, h) = (dw.nrows, dw.ncols);
    assert_eq!(a.len(), rows * f, "operand a shape mismatch");
    assert_eq!(dz.len(), rows * h, "operand dz shape mismatch");
    pool.for_each_row_chunk(&mut dw.data, h, |range, chunk| {
        for r in 0..rows {
            let arow = &a[r * f..(r + 1) * f];
            let zrow = &dz[r * h..(r + 1) * h];
            for i in range.clone() {
                let av = arow[i];
                if av == 0.0 {
                    continue;
                }
                let drow = &mut chunk[(i - range.start) * h..(i - range.start + 1) * h];
                for (d, &z) in drow.iter_mut().zip(zrow.iter()) {
                    *d += av * z;
                }
            }
        }
    });
}

/// `out = dz · wᵀ`: `dz` is `n × h`, `w` is `f × h`, `out` holds `n × f`
/// row-major and is overwritten. Each output element is one ascending dot
/// product, so any row partitioning is bitwise identical to the serial
/// loop. This is the backward combine (dAgg from dZ) of the streamed
/// trainer.
pub fn matmul_bt_into(dz: &Dense, w: &Dense, pool: &Pool, out: &mut [f32]) {
    let (n, h, f) = (dz.nrows, dz.ncols, w.nrows);
    assert_eq!(w.ncols, h, "inner dimension mismatch");
    assert_eq!(out.len(), n * f, "destination shape mismatch");
    pool.for_each_row_chunk(out, f, |range, chunk| {
        for (local, r) in range.clone().enumerate() {
            let zrow = &dz.data[r * h..(r + 1) * h];
            let orow = &mut chunk[local * f..(local + 1) * f];
            for (i, o) in orow.iter_mut().enumerate() {
                let wrow = &w.data[i * h..(i + 1) * h];
                let mut acc = 0f32;
                for (&z, &wv) in zrow.iter().zip(wrow.iter()) {
                    acc += z * wv;
                }
                *o = acc;
            }
        }
    });
}

/// Column sums of `dz` into `out` (the bias gradient), rows ascending —
/// serial on purpose: the reduction order *is* the contract.
pub fn column_sums_into(dz: &Dense, out: &mut [f32]) {
    assert_eq!(out.len(), dz.ncols, "destination shape mismatch");
    out.fill(0.0);
    for r in 0..dz.nrows {
        for (o, &z) in out.iter_mut().zip(dz.row(r).iter()) {
            *o += z;
        }
    }
}

/// Classification accuracy of logits vs labels.
pub fn accuracy(logits: &Dense, y: &[i32]) -> f64 {
    let mut hit = 0usize;
    for i in 0..logits.nrows {
        let row = logits.row(i);
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap();
        if pred == y[i] as usize {
            hit += 1;
        }
    }
    hit as f64 / logits.nrows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::norm::normalize_adjacency;
    use crate::sparse::Coo;

    fn ring(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            let j = (i + 1) % n;
            coo.push(i as u32, j as u32, 1.0);
            coo.push(j as u32, i as u32, 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Pcg::seed(1);
        let a = normalize_adjacency(&ring(32));
        let x = Dense::from_vec(32, 8, (0..32 * 8).map(|_| rng.normal() as f32).collect());
        let model = Gcn2Ref::init(&mut rng, 8, 16, 4);
        let out = model.forward(&a, &x);
        assert_eq!((out.nrows, out.ncols), (32, 4));
    }

    #[test]
    fn xent_of_uniform_logits_is_log_c() {
        let logits = Dense::zeros(10, 4);
        let y: Vec<i32> = (0..10).map(|i| (i % 4) as i32).collect();
        let l = softmax_xent(&logits, &y);
        assert!((l - (4f64).ln()).abs() < 1e-9);
    }

    #[test]
    fn accuracy_perfect_and_zero() {
        let mut logits = Dense::zeros(4, 2);
        for i in 0..4 {
            *logits.at_mut(i, i % 2) = 5.0;
        }
        let y: Vec<i32> = (0..4).map(|i| (i % 2) as i32).collect();
        assert_eq!(accuracy(&logits, &y), 1.0);
        let wrong: Vec<i32> = (0..4).map(|i| ((i + 1) % 2) as i32).collect();
        assert_eq!(accuracy(&logits, &wrong), 0.0);
    }

    #[test]
    fn softmax_xent_grad_loss_is_bitwise_softmax_xent() {
        let mut rng = Pcg::seed(5);
        let logits =
            Dense::from_vec(17, 5, (0..17 * 5).map(|_| rng.normal() as f32).collect());
        let y: Vec<i32> = (0..17).map(|i| (i % 5) as i32).collect();
        let (loss, grad) = softmax_xent_grad(&logits, &y);
        assert_eq!(loss.to_bits(), softmax_xent(&logits, &y).to_bits());
        // Gradient rows sum to ~0 (softmax probs sum to 1, one-hot to 1).
        for i in 0..17 {
            let s: f64 = grad.row(i).iter().map(|&v| v as f64).sum();
            assert!(s.abs() < 1e-6, "row {i} sums to {s}");
        }
        // Central differences validate the direction (f64 loss, f32 logits).
        let eps = 1e-3f32;
        for (i, j) in [(0usize, 0usize), (3, 2), (16, 4)] {
            let mut up = logits.clone();
            *up.at_mut(i, j) += eps;
            let mut dn = logits.clone();
            *dn.at_mut(i, j) -= eps;
            let fd = (softmax_xent(&up, &y) - softmax_xent(&dn, &y)) / (2.0 * eps as f64);
            let g = grad.at(i, j) as f64;
            assert!((fd - g).abs() < 1e-4, "({i},{j}): fd {fd} vs grad {g}");
        }
    }

    #[test]
    fn add_at_b_segment_accumulation_is_bitwise_whole() {
        // dW accumulated segment-by-segment over ascending row ranges must
        // be byte-identical to one whole-matrix call at any thread count —
        // the contract the recompute policy's per-segment dW rests on.
        let mut rng = Pcg::seed(6);
        let (rows, f, h) = (37usize, 6usize, 4usize);
        let a: Vec<f32> = (0..rows * f).map(|_| rng.normal() as f32).collect();
        let dz: Vec<f32> = (0..rows * h).map(|_| rng.normal() as f32).collect();
        let mut whole = Dense::zeros(f, h);
        add_at_b(&mut whole, &a, &dz, rows, &Pool::serial());
        for threads in [1usize, 2, 8] {
            let pool = Pool::new(threads);
            let mut seg = Dense::zeros(f, h);
            for (lo, hi) in [(0usize, 11usize), (11, 11), (11, 30), (30, 37)] {
                add_at_b(&mut seg, &a[lo * f..hi * f], &dz[lo * h..hi * h], hi - lo, &pool);
            }
            assert_eq!(seg, whole, "threads={threads}");
        }
    }

    #[test]
    fn matmul_bt_and_column_sums_match_naive() {
        let mut rng = Pcg::seed(7);
        let (n, h, f) = (13usize, 5usize, 7usize);
        let dz = Dense::from_vec(n, h, (0..n * h).map(|_| rng.normal() as f32).collect());
        let w = Dense::from_vec(f, h, (0..f * h).map(|_| rng.normal() as f32).collect());
        let mut naive = vec![0f32; n * f];
        for r in 0..n {
            for i in 0..f {
                let mut acc = 0f32;
                for j in 0..h {
                    acc += dz.at(r, j) * w.at(i, j);
                }
                naive[r * f + i] = acc;
            }
        }
        for threads in [1usize, 4] {
            let mut out = vec![f32::NAN; n * f];
            matmul_bt_into(&dz, &w, &Pool::new(threads), &mut out);
            assert_eq!(out, naive, "threads={threads}");
        }
        let mut db = vec![f32::NAN; h];
        column_sums_into(&dz, &mut db);
        for j in 0..h {
            let want: f32 = (0..n).fold(0f32, |acc, r| acc + dz.at(r, j));
            assert_eq!(db[j], want);
        }
    }

    #[test]
    fn dense_affine_relu_matches_manual() {
        let x = Dense::from_vec(1, 2, vec![1.0, -2.0]);
        let w = Dense::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let out = dense_affine(&x, &w, &[0.0, 0.5], true);
        assert_eq!(out.data, vec![1.0, 0.0]); // -2 + 0.5 clamped
    }
}
