//! Training driver: loops the AOT-compiled `gcn2_train_step` artifact.
//!
//! The full forward + softmax-xent + backward + SGD step was lowered once
//! at build time (L2); this driver owns the parameter state and the loop —
//! no Python anywhere near the path.

use crate::runtime::executor::BufView;
use crate::runtime::Executor;
use crate::sparse::norm::normalize_adjacency;
use crate::sparse::Csr;
use crate::util::rng::Pcg;
use anyhow::{anyhow, Result};

/// Truncate or pad `adjacency` to an `n × n` square, dropping entries in
/// columns `>= n`.
///
/// Padding contract: rows `>= adjacency.nrows` come out *empty* —
/// isolated zero-degree nodes. [`normalize_adjacency`] then anchors every
/// such node with a self-loop-only row (Â's `D^-1/2 (A+I) D^-1/2` adds the
/// identity before normalizing), so a padded node's features pass through
/// aggregation unmixed and training on a padded graph is well-defined —
/// the padded case `trainer_reduces_loss_on_kmer_graph` pins. Entries in
/// columns `>= n` of surviving rows are dropped, not wrapped.
///
/// Rebuild is fully pre-sized: columns are strictly ascending within each
/// row, so the survivors of a truncated row are exactly a prefix
/// (`partition_point`), a counting pass sizes all three sections up
/// front, and the copy pass is one `extend_from_slice` per row — the
/// same prefix-copy discipline as [`Csr::slice_rows_into`]. The previous
/// implementation round-tripped every surviving entry through a dense
/// `Coo` push loop and a full `to_csr` re-sort.
fn square_to_n(adjacency: &Csr, n: usize) -> Csr {
    let rows = adjacency.nrows.min(n);
    let mut rowptr = Vec::with_capacity(n + 1);
    rowptr.push(0usize);
    let mut nnz = 0usize;
    for i in 0..rows {
        let (lo, hi) = (adjacency.rowptr[i], adjacency.rowptr[i + 1]);
        nnz += adjacency.colidx[lo..hi].partition_point(|&c| (c as usize) < n);
        rowptr.push(nnz);
    }
    rowptr.resize(n + 1, nnz); // padded rows are empty
    let mut colidx = Vec::with_capacity(nnz);
    let mut vals = Vec::with_capacity(nnz);
    for i in 0..rows {
        let lo = adjacency.rowptr[i];
        let keep = rowptr[i + 1] - rowptr[i];
        colidx.extend_from_slice(&adjacency.colidx[lo..lo + keep]);
        vals.extend_from_slice(&adjacency.vals[lo..lo + keep]);
    }
    Csr { nrows: n, ncols: n, rowptr, colidx, vals }
}

/// Training state bound to one `gcn2_train_step_*` artifact.
pub struct Trainer {
    artifact: String,
    /// Static node count of the artifact.
    pub n: usize,
    /// Input feature width.
    pub f0: usize,
    /// Hidden width.
    pub hidden: usize,
    /// Output classes.
    pub classes: usize,
    a_dense: Vec<f32>,
    x: Vec<f32>,
    labels: Vec<i32>,
    /// Device literals of the three constant inputs (Â, X, labels), built
    /// once on the first step. They never change across SGD steps, so
    /// re-wrapping (and with it deep-copying the full dense graph) per
    /// step was pure allocator churn.
    const_lits: Option<[xla::Literal; 3]>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    /// Loss per completed training step.
    pub losses: Vec<f32>,
}

impl Trainer {
    /// Bind to the manifest's train-step artifact; the graph is truncated/
    /// padded to the artifact's static node count `n`.
    pub fn new(exec: &Executor, adjacency: &Csr, features_seed: u64) -> Result<Trainer> {
        let spec = exec
            .manifest()
            .find_prefix("gcn2_train_step_")
            .ok_or_else(|| anyhow!("train-step artifact missing"))?
            .clone();
        let n = spec.meta["n"] as usize;
        let f0 = spec.meta["f0"] as usize;
        let hidden = spec.meta["h"] as usize;
        let classes = spec.meta["c"] as usize;

        // Truncate / pad the adjacency to n nodes, then normalize.
        let a_hat = normalize_adjacency(&square_to_n(adjacency, n));
        let a_dense = a_hat.to_dense();

        let mut rng = Pcg::seed(features_seed);
        let x: Vec<f32> = (0..n * f0).map(|_| rng.normal() as f32).collect();
        // Learnable labels: random projection of features, quantile split.
        let proj: Vec<f32> = (0..f0).map(|_| rng.normal() as f32).collect();
        let mut scores: Vec<f32> = (0..n)
            .map(|i| (0..f0).map(|j| x[i * f0 + j] * proj[j]).sum())
            .collect();
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let labels: Vec<i32> = scores
            .iter_mut()
            .map(|s| {
                let rank = sorted.partition_point(|&v| v < *s);
                ((rank * classes / n).min(classes - 1)) as i32
            })
            .collect();

        let w1 = (0..f0 * hidden).map(|_| (rng.normal() * 0.3) as f32).collect();
        let w2 = (0..hidden * classes).map(|_| (rng.normal() * 0.3) as f32).collect();
        Ok(Trainer {
            artifact: spec.name,
            n,
            f0,
            hidden,
            classes,
            a_dense,
            x,
            labels,
            const_lits: None,
            w1,
            b1: vec![0.0; hidden],
            w2,
            b2: vec![0.0; classes],
            losses: Vec::new(),
        })
    }

    /// One SGD step; returns the loss before the update.
    ///
    /// Only the parameters and the learning rate are re-wrapped per step;
    /// the constant inputs (dense Â, X, labels — by far the largest
    /// buffers) are built into literals once and reused, so the training
    /// loop no longer copies the full graph on every step.
    pub fn step(&mut self, exec: &mut Executor, lr: f32) -> Result<f32> {
        if self.const_lits.is_none() {
            self.const_lits = Some([
                exec.prep_literal_view(&self.artifact, 0, BufView::F32(&self.a_dense))?,
                exec.prep_literal_view(&self.artifact, 1, BufView::F32(&self.x))?,
                exec.prep_literal_view(&self.artifact, 6, BufView::S32(&self.labels))?,
            ]);
        }
        let w1 = exec.prep_literal_view(&self.artifact, 2, BufView::F32(&self.w1))?;
        let b1 = exec.prep_literal_view(&self.artifact, 3, BufView::F32(&self.b1))?;
        let w2 = exec.prep_literal_view(&self.artifact, 4, BufView::F32(&self.w2))?;
        let b2 = exec.prep_literal_view(&self.artifact, 5, BufView::F32(&self.b2))?;
        let lr_lit = exec.prep_literal_view(&self.artifact, 7, BufView::F32(&[lr]))?;
        let [a, x, labels] = self.const_lits.as_ref().expect("built above");
        let outs = exec.run_literals(
            &self.artifact,
            &[a, x, &w1, &b1, &w2, &b2, labels, &lr_lit],
        )?;
        let loss = outs[0].as_f32()?[0];
        self.w1 = outs[1].as_f32()?.to_vec();
        self.b1 = outs[2].as_f32()?.to_vec();
        self.w2 = outs[3].as_f32()?.to_vec();
        self.b2 = outs[4].as_f32()?.to_vec();
        self.losses.push(loss);
        Ok(loss)
    }

    /// Run `steps` SGD steps, returning (first, best, last) losses.
    /// `steps == 0` is a typed error: there would be no losses to report
    /// (the `first`/`last` unwraps below used to panic on an empty curve;
    /// the streamed trainer shares this guard).
    pub fn train(&mut self, exec: &mut Executor, steps: usize, lr: f32) -> Result<(f32, f32, f32)> {
        if steps == 0 {
            return Err(anyhow!("training needs at least one step"));
        }
        for _ in 0..steps {
            self.step(exec, lr)?;
        }
        let first = *self.losses.first().expect("at least one step ran");
        let best = self.losses.iter().copied().fold(f32::INFINITY, f32::min);
        let last = *self.losses.last().expect("at least one step ran");
        Ok((first, best, last))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::find_artifact_dir;

    /// The pre-refactor semantics, kept as the oracle: push every entry
    /// with row < n and col < n through a COO and re-sort.
    fn square_to_n_reference(adjacency: &Csr, n: usize) -> Csr {
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..adjacency.nrows.min(n) {
            for (c, v) in adjacency.row(i) {
                if (c as usize) < n {
                    coo.push(i as u32, c, v);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn square_to_n_matches_the_coo_reference() {
        let mut rng = Pcg::seed(30);
        for (nodes, n) in [(120usize, 80usize), (80, 80), (50, 96), (1, 4), (64, 1)] {
            let g = crate::graphgen::kmer::generate(&mut rng, nodes, 3.0);
            let got = square_to_n(&g, n);
            got.validate().unwrap();
            assert_eq!(got, square_to_n_reference(&g, n), "nodes={nodes} n={n}");
        }
        // Rectangular input with columns past n: survivors are a prefix.
        let mut coo = crate::sparse::Coo::new(4, 10);
        for r in 0..4u32 {
            for c in [0u32, 2, 5, 9] {
                coo.push(r, c, (r + c) as f32);
            }
        }
        let wide = coo.to_csr();
        let got = square_to_n(&wide, 6);
        got.validate().unwrap();
        assert_eq!(got, square_to_n_reference(&wide, 6));
        assert_eq!(got.nnz(), 4 * 3, "columns >= 6 dropped");
    }

    #[test]
    fn trainer_reduces_loss_on_kmer_graph() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exec = Executor::new(&dir).unwrap();
        let mut rng = Pcg::seed(3);
        // Exact-size graph plus a padded one (`nodes < n`: square_to_n
        // fills the tail with isolated nodes that normalize_adjacency
        // anchors via self-loops — training must still converge).
        for nodes in [1024usize, 700] {
            let g = crate::graphgen::kmer::generate(&mut rng, nodes, 3.2);
            let mut tr = Trainer::new(&exec, &g, 42).unwrap();
            let (first, _best, last) = tr.train(&mut exec, 25, 2.0).unwrap();
            assert!(last < first, "nodes={nodes}: loss must decrease: {first} -> {last}");
        }
        // steps == 0 is a typed error, not a panic on the empty loss curve.
        let g = crate::graphgen::kmer::generate(&mut rng, 256, 3.2);
        let mut tr = Trainer::new(&exec, &g, 42).unwrap();
        let err = tr.train(&mut exec, 0, 2.0).unwrap_err();
        assert!(err.to_string().contains("at least one step"), "{err}");
    }
}
