//! Versioned, checksummed training checkpoints for the streamed trainer.
//!
//! One checkpoint captures everything a killed run needs to resume to
//! bitwise-identical final parameters: the parameter state of every layer,
//! the completed-step index, the resolved recompute policy, the loss curve
//! so far, and the driver's RNG snapshot ([`Pcg::state`]) — streamed steps
//! themselves draw no randomness, but the CLI's label/feature generation
//! does, and a resume must not replay or skip any of that stream.
//!
//! The on-disk record rides the segio container ([`KIND_CHECK`]): the same
//! magic/version/FNV-1a header discipline every spilled segment and panel
//! already uses, so a torn or corrupt checkpoint is a *typed* decode error,
//! never garbage parameters. Writes are atomic — encode to
//! `checkpoint.bin.tmp`, then `rename` onto `checkpoint.bin` — so a kill
//! mid-save leaves the previous checkpoint intact ([`load`] never sees a
//! half-written file).
//!
//! The body layout is fixed little-endian (byte-stable across runs, like
//! every other on-disk artifact in the repo):
//!
//! ```text
//! u32  checkpoint version (currently 1)
//! u64  completed-step index
//! u8   recompute policy (0 = reload, 1 = recompute, 2 = auto)
//! u64  rng state, u64 rng increment
//! u64  loss count, then count × u32 f32 bit patterns
//! u64  layer count, then per layer:
//!      u64 nrows, u64 ncols, u8 relu, u64 seg_budget,
//!      nrows × ncols × u32 weight bit patterns,
//!      u64 bias count, then count × u32 bias bit patterns
//! ```

use crate::gcn::oocgcn::OocGcnLayer;
use crate::gcn::train_stream::RecomputePolicy;
use crate::sparse::segio::{decode_blob, encode_blob};
use crate::sparse::spmm::Dense;
use crate::util::rng::Pcg;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Current (and only) checkpoint body version.
pub const CHECKPOINT_VERSION: u32 = 1;

/// File name a checkpoint directory holds its (single) checkpoint under.
pub const CHECKPOINT_FILE: &str = "checkpoint.bin";

/// A resumable snapshot of streamed-training state after some step.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Steps completed when the snapshot was taken (resume runs steps
    /// `step..total`).
    pub step: u64,
    /// The recompute policy the run was started with (resume must not
    /// silently switch policies mid-run).
    pub policy: RecomputePolicy,
    /// The driver RNG's [`Pcg::state`] snapshot at save time.
    pub rng: (u64, u64),
    /// Loss of every completed step, in order — bit patterns preserved.
    pub losses: Vec<f32>,
    /// Parameter state of every layer after `step` updates.
    pub layers: Vec<OocGcnLayer>,
}

impl Checkpoint {
    /// Rebuild the driver RNG from the snapshot (continues the stream
    /// bit-for-bit from the save point).
    pub fn rng(&self) -> Pcg {
        Pcg::from_state(self.rng)
    }
}

fn policy_tag(p: RecomputePolicy) -> u8 {
    match p {
        RecomputePolicy::Reload => 0,
        RecomputePolicy::Recompute => 1,
        RecomputePolicy::Auto => 2,
    }
}

fn policy_from_tag(t: u8) -> Result<RecomputePolicy> {
    match t {
        0 => Ok(RecomputePolicy::Reload),
        1 => Ok(RecomputePolicy::Recompute),
        2 => Ok(RecomputePolicy::Auto),
        other => bail!("checkpoint carries unknown recompute-policy tag {other}"),
    }
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian reader over the decoded blob payload —
/// every take is a typed error on a short body, so a truncated-inside-the-
/// container body (impossible via [`save`], possible via a crafted file)
/// cannot panic.
struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.off < n {
            bail!(
                "checkpoint body truncated: need {n} bytes at offset {}, have {}",
                self.off,
                self.buf.len() - self.off
            );
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    /// A count field narrowed to usize, bounded by the bytes actually
    /// present (every counted element occupies ≥ 4 body bytes, so any
    /// count beyond `remaining` is corrupt — reject before reserving).
    fn count(&mut self, what: &str) -> Result<usize> {
        let v = self.u64()?;
        let bound = (self.buf.len() - self.off) as u64 / 4;
        if v > bound {
            bail!("checkpoint {what} count {v} exceeds the {} remaining body bytes", 4 * bound);
        }
        Ok(v as usize)
    }

    fn finish(self) -> Result<()> {
        if self.off != self.buf.len() {
            bail!("checkpoint body has {} trailing bytes", self.buf.len() - self.off);
        }
        Ok(())
    }
}

/// Encode a checkpoint into its on-disk record (container included).
pub fn encode_checkpoint(ck: &Checkpoint) -> Vec<u8> {
    let mut body = Vec::new();
    put_u32(&mut body, CHECKPOINT_VERSION);
    put_u64(&mut body, ck.step);
    body.push(policy_tag(ck.policy));
    put_u64(&mut body, ck.rng.0);
    put_u64(&mut body, ck.rng.1);
    put_u64(&mut body, ck.losses.len() as u64);
    for &l in &ck.losses {
        put_u32(&mut body, l.to_bits());
    }
    put_u64(&mut body, ck.layers.len() as u64);
    for layer in &ck.layers {
        put_u64(&mut body, layer.w.nrows as u64);
        put_u64(&mut body, layer.w.ncols as u64);
        body.push(layer.relu as u8);
        put_u64(&mut body, layer.seg_budget);
        for &w in &layer.w.data {
            put_u32(&mut body, w.to_bits());
        }
        put_u64(&mut body, layer.b.len() as u64);
        for &b in &layer.b {
            put_u32(&mut body, b.to_bits());
        }
    }
    encode_blob(&body)
}

/// Decode an on-disk checkpoint record. The exact inverse of
/// [`encode_checkpoint`]: every f32 round-trips by bit pattern. Structural
/// defects (container checksums, record kind, truncation) surface as the
/// segio error; body defects (bad version, bad policy tag, short or
/// oversized sections) as typed messages naming the field.
pub fn decode_checkpoint(buf: &[u8]) -> Result<Checkpoint> {
    let body = decode_blob(buf).map_err(|e| anyhow!("checkpoint container: {e}"))?;
    let mut c = Cursor { buf: &body, off: 0 };
    let version = c.u32()?;
    if version != CHECKPOINT_VERSION {
        bail!("unsupported checkpoint version {version} (expected {CHECKPOINT_VERSION})");
    }
    let step = c.u64()?;
    let policy = policy_from_tag(c.u8()?)?;
    let rng = (c.u64()?, c.u64()?);
    let n_losses = c.count("loss")?;
    let mut losses = Vec::with_capacity(n_losses);
    for _ in 0..n_losses {
        losses.push(f32::from_bits(c.u32()?));
    }
    let nl = c.count("layer")?;
    let mut layers = Vec::with_capacity(nl);
    for l in 0..nl {
        let nrows = c.u64()? as usize;
        let ncols = c.u64()? as usize;
        let relu = match c.u8()? {
            0 => false,
            1 => true,
            other => bail!("checkpoint layer {l} has non-boolean relu byte {other}"),
        };
        let seg_budget = c.u64()?;
        let n = nrows.checked_mul(ncols).ok_or_else(|| {
            anyhow!("checkpoint layer {l}: {nrows}x{ncols} overflows the element count")
        })?;
        if n > (body.len() - c.off) / 4 {
            bail!("checkpoint layer {l}: {nrows}x{ncols} weights exceed the remaining body");
        }
        let mut w = Vec::with_capacity(n);
        for _ in 0..n {
            w.push(f32::from_bits(c.u32()?));
        }
        let nb = c.count("bias")?;
        let mut b = Vec::with_capacity(nb);
        for _ in 0..nb {
            b.push(f32::from_bits(c.u32()?));
        }
        layers.push(OocGcnLayer { w: Dense::from_vec(nrows, ncols, w), b, relu, seg_budget });
    }
    c.finish()?;
    Ok(Checkpoint { step, policy, rng, losses, layers })
}

/// Path of the checkpoint file inside `dir`.
pub fn checkpoint_path(dir: &Path) -> PathBuf {
    dir.join(CHECKPOINT_FILE)
}

/// Atomically persist `ck` under `dir` (created if missing): encode to
/// `checkpoint.bin.tmp`, then rename onto [`CHECKPOINT_FILE`]. A kill at
/// any point leaves either the previous checkpoint or the new one — never
/// a torn file. Returns the bytes written.
pub fn save(dir: &Path, ck: &Checkpoint) -> Result<u64> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let path = checkpoint_path(dir);
    let tmp = path.with_extension("bin.tmp");
    let buf = encode_checkpoint(ck);
    std::fs::write(&tmp, &buf).with_context(|| format!("write {}", tmp.display()))?;
    std::fs::rename(&tmp, &path)
        .with_context(|| format!("publish checkpoint {}", path.display()))?;
    Ok(buf.len() as u64)
}

/// Load the checkpoint under `dir`, if any. A missing file (or missing
/// directory) is `Ok(None)` — the fresh-start case; anything present but
/// undecodable is an error, never a silent fresh start.
pub fn load(dir: &Path) -> Result<Option<Checkpoint>> {
    let path = checkpoint_path(dir);
    let buf = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => return Err(anyhow!("read checkpoint {}: {e}", path.display())),
    };
    decode_checkpoint(&buf).with_context(|| format!("decode checkpoint {}", path.display()))
        .map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg;

    fn example() -> Checkpoint {
        let mut rng = Pcg::seed(90);
        let layers = vec![
            OocGcnLayer {
                w: Dense::from_vec(3, 4, (0..12).map(|_| rng.normal() as f32).collect()),
                b: (0..4).map(|_| rng.normal() as f32).collect(),
                relu: true,
                seg_budget: 1024,
            },
            OocGcnLayer {
                w: Dense::from_vec(4, 2, (0..8).map(|_| rng.normal() as f32).collect()),
                b: vec![-0.0, f32::from_bits(0x0000_0001)],
                relu: false,
                seg_budget: 2048,
            },
        ];
        Checkpoint {
            step: 7,
            policy: RecomputePolicy::Recompute,
            rng: rng.state(),
            losses: vec![1.5, 0.75, f32::from_bits(0x3f80_0001)],
            layers,
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn roundtrip_is_bitwise() {
        let ck = example();
        let back = decode_checkpoint(&encode_checkpoint(&ck)).unwrap();
        assert_eq!(back.step, ck.step);
        assert_eq!(back.policy, ck.policy);
        assert_eq!(back.rng, ck.rng);
        assert_eq!(bits(&back.losses), bits(&ck.losses));
        assert_eq!(back.layers.len(), ck.layers.len());
        for (a, b) in back.layers.iter().zip(ck.layers.iter()) {
            assert_eq!((a.w.nrows, a.w.ncols), (b.w.nrows, b.w.ncols));
            assert_eq!(bits(&a.w.data), bits(&b.w.data));
            assert_eq!(bits(&a.b), bits(&b.b));
            assert_eq!(a.relu, b.relu);
            assert_eq!(a.seg_budget, b.seg_budget);
        }
        // The RNG snapshot resumes the stream exactly.
        let mut orig = ck.rng();
        let mut restored = back.rng();
        for _ in 0..50 {
            assert_eq!(orig.next_u64(), restored.next_u64());
        }
    }

    #[test]
    fn save_load_and_missing_dir() {
        let dir = TempDir::new("checkpoint-unit");
        assert!(load(dir.path()).unwrap().is_none());
        assert!(load(&dir.path().join("never-created")).unwrap().is_none());
        let ck = example();
        let bytes = save(dir.path(), &ck).unwrap();
        assert_eq!(bytes, std::fs::metadata(checkpoint_path(dir.path())).unwrap().len());
        let back = load(dir.path()).unwrap().expect("checkpoint present");
        assert_eq!(back.step, ck.step);
        assert_eq!(bits(&back.layers[0].w.data), bits(&ck.layers[0].w.data));
        // Overwrite with a later step wins.
        let mut later = ck.clone();
        later.step = 8;
        save(dir.path(), &later).unwrap();
        assert_eq!(load(dir.path()).unwrap().unwrap().step, 8);
    }

    #[test]
    fn save_is_atomic_against_a_stale_tmp_and_kills_mid_write() {
        let dir = TempDir::new("checkpoint-atomic");
        let ck = example();
        save(dir.path(), &ck).unwrap();
        // A kill mid-write strands a torn tmp file; the published
        // checkpoint must stay intact and the next save must recover.
        let tmp = checkpoint_path(dir.path()).with_extension("bin.tmp");
        std::fs::write(&tmp, b"torn partial write").unwrap();
        assert_eq!(load(dir.path()).unwrap().unwrap().step, ck.step);
        let mut next = ck.clone();
        next.step = 9;
        save(dir.path(), &next).unwrap();
        assert!(!tmp.exists(), "publish consumes the tmp file");
        assert_eq!(load(dir.path()).unwrap().unwrap().step, 9);
    }

    #[test]
    fn corruption_and_version_skew_are_typed_errors_not_fresh_starts() {
        let dir = TempDir::new("checkpoint-corrupt");
        let ck = example();
        save(dir.path(), &ck).unwrap();
        let path = checkpoint_path(dir.path());
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(dir.path()).unwrap_err();
        assert!(err.to_string().contains("decode checkpoint"), "{err}");

        // A future body version is refused by name, not misparsed.
        let mut body_v2 = encode_checkpoint(&ck);
        // Body starts after the 64-byte container header; bump the version
        // word and re-seal both container checksums.
        body_v2[64] = 2;
        let payload_sum = crate::sparse::segio::fnv1a64(&body_v2[64..]);
        body_v2[48..56].copy_from_slice(&payload_sum.to_le_bytes());
        let header_sum = crate::sparse::segio::fnv1a64(&body_v2[0..56]);
        body_v2[56..64].copy_from_slice(&header_sum.to_le_bytes());
        let err = decode_checkpoint(&body_v2).unwrap_err();
        assert!(err.to_string().contains("unsupported checkpoint version 2"), "{err}");

        // An oversized count field cannot cause a huge reserve: it is
        // bounded by the bytes actually present.
        let mut big = encode_checkpoint(&ck);
        // loss-count field sits at body offset 29 (4 + 8 + 1 + 16).
        big[64 + 29..64 + 37].copy_from_slice(&u64::MAX.to_le_bytes());
        let payload_sum = crate::sparse::segio::fnv1a64(&big[64..]);
        big[48..56].copy_from_slice(&payload_sum.to_le_bytes());
        let header_sum = crate::sparse::segio::fnv1a64(&big[0..56]);
        big[56..64].copy_from_slice(&header_sum.to_le_bytes());
        let err = decode_checkpoint(&big).unwrap_err();
        assert!(err.to_string().contains("loss count"), "{err}");
    }
}
