//! Out-of-core GCN layer: the real-compute embodiment of the paper's
//! pipeline at laptop scale.
//!
//! The adjacency is RoBW-partitioned (Algorithm 1) under a byte budget;
//! each aligned segment's aggregation runs through the PJRT `bsr_spmm`
//! artifact (the accelerator path), and the combination runs through the
//! fused `gcn_combine` artifact. A [`GpuMem`] ledger enforces the memory
//! constraint exactly the way the scheduler models it, so the laptop-scale
//! run exercises the same planning code the paper-scale simulation uses.

use crate::memsim::GpuMem;
use crate::partition::robw::{materialize, robw_partition};
use crate::runtime::pool::Pool;
use crate::runtime::tile_exec::{BsrSpmmExec, CombineExec};
use crate::runtime::Executor;
use crate::sparse::spmm::Dense;
use crate::sparse::Csr;
use anyhow::{anyhow, Result};

/// Execution report for one out-of-core layer pass.
#[derive(Debug, Clone, Default)]
pub struct LayerReport {
    pub segments: usize,
    pub artifact_calls_estimate: usize,
    pub peak_gpu_bytes: u64,
    pub h2d_bytes: u64,
}

/// One out-of-core GCN layer (aggregation + fused combine).
pub struct OocGcnLayer {
    pub w: Dense,
    pub b: Vec<f32>,
    pub relu: bool,
    /// Per-segment GPU byte budget for CSR A (Eq. 7's 3p).
    pub seg_budget: u64,
}

impl OocGcnLayer {
    /// Forward with serial host-side packing (see [`Self::forward_pooled`]).
    pub fn forward(
        &self,
        exec: &mut Executor,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
    ) -> Result<(Dense, LayerReport)> {
        self.forward_pooled(exec, a_hat, x, mem, &Pool::serial())
    }

    /// Forward: relu((Â·x)·w + b), streaming Â in RoBW segments.
    ///
    /// `mem` models the device: the feature panel and each segment are
    /// "allocated" and freed as the schedule would, so exceeding the
    /// constraint fails exactly like the simulated OOM. Per-segment tile
    /// extraction/packing runs on `pool` (the CLI's `--threads`).
    pub fn forward_pooled(
        &self,
        exec: &mut Executor,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
    ) -> Result<(Dense, LayerReport)> {
        let spmm_exec = BsrSpmmExec::for_feature_width(exec, x.ncols)?;
        let comb = CombineExec::for_widths(exec, x.ncols, self.w.ncols, self.relu)?;

        // Phase I: feature panel resident (the GDS leg in the simulation).
        let b_bytes = (x.nrows * x.ncols * 4) as u64;
        mem.alloc(b_bytes, "feature panel")
            .map_err(|e| anyhow!("feature panel does not fit: {e}"))?;

        let segs = robw_partition(a_hat, self.seg_budget);
        let mut agg = Dense::zeros(a_hat.nrows, x.ncols);
        let mut report = LayerReport { segments: segs.len(), ..Default::default() };

        for seg in &segs {
            // Phase II: segment in, partial C computed, segment freed.
            mem.alloc(seg.bytes, "RoBW segment")
                .map_err(|e| anyhow!("segment does not fit: {e}"))?;
            report.h2d_bytes += seg.bytes;
            let sub = materialize(a_hat, seg);
            let part = spmm_exec.spmm_with_pool(exec, &sub, x, pool)?;
            agg.data[seg.row_lo * x.ncols..seg.row_hi * x.ncols]
                .copy_from_slice(&part.data);
            report.artifact_calls_estimate +=
                sub.nnz().div_ceil(spmm_exec.shape.nb * spmm_exec.shape.bm * spmm_exec.shape.bk);
            mem.free(seg.bytes);
        }

        // Phase III: output stays "resident"; combine through the fused tile.
        let out = comb.combine(exec, &agg, &self.w, &self.b)?;
        report.peak_gpu_bytes = mem.peak;
        mem.free(b_bytes);
        Ok((out, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gcn::model::dense_affine;
    use crate::runtime::find_artifact_dir;
    use crate::sparse::norm::normalize_adjacency;
    use crate::sparse::spmm::spmm;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    #[test]
    fn ooc_layer_matches_reference() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exec = Executor::new(&dir).unwrap();
        let mut rng = Pcg::seed(5);
        // kmer-like small graph, 500 nodes (< K=1024 of the artifact).
        let a = crate::graphgen::kmer::generate(&mut rng, 500, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(500, 64, (0..500 * 64).map(|_| rng.normal() as f32).collect());
        let w = Dense::from_vec(64, 64, (0..64 * 64).map(|_| (rng.normal() * 0.2) as f32).collect());
        let b: Vec<f32> = vec![0.1; 64];

        let layer = OocGcnLayer { w: w.clone(), b: b.clone(), relu: true, seg_budget: 4096 };
        let mut mem = GpuMem::new(64 << 20);
        let (got, report) = layer.forward(&mut exec, &a_hat, &x, &mut mem).unwrap();
        assert!(report.segments > 1, "budget must force multiple segments");

        let want = dense_affine(&spmm(&a_hat, &x), &w, &b, true);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "max diff {diff}");
    }

    #[test]
    fn ooc_layer_ooms_when_panel_too_big() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exec = Executor::new(&dir).unwrap();
        let mut coo = Coo::new(64, 64);
        for i in 0..64u32 {
            coo.push(i, (i + 1) % 64, 1.0);
        }
        let a_hat = normalize_adjacency(&coo.to_csr());
        let x = Dense::zeros(64, 64);
        let layer = OocGcnLayer {
            w: Dense::zeros(64, 64),
            b: vec![0.0; 64],
            relu: true,
            seg_budget: 4096,
        };
        let mut mem = GpuMem::new(1024); // absurdly small
        assert!(layer.forward(&mut exec, &a_hat, &x, &mut mem).is_err());
    }
}
