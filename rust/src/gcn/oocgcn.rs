//! Out-of-core GCN layer: the real-compute embodiment of the paper's
//! pipeline at laptop scale.
//!
//! The adjacency is RoBW-partitioned (Algorithm 1) under a byte budget;
//! each aligned segment's aggregation runs through the PJRT `bsr_spmm`
//! artifact (the accelerator path), and the combination runs through the
//! fused `gcn_combine` artifact. A [`GpuMem`] ledger enforces the memory
//! constraint exactly the way the scheduler models it, so the laptop-scale
//! run exercises the same planning code the paper-scale simulation uses.
//!
//! Phase II streaming goes through [`runtime::prefetch`](crate::runtime::prefetch):
//! a producer task packs (and, when an I/O cost model is attached, charges
//! the simulated H2D latency of) segment `i+1` while the calling thread
//! computes segment `i` — the paper's transfer/compute overlap, executed
//! rather than merely modelled. Partials land in fixed disjoint row ranges
//! and are merged in segment order, so the output is byte-identical to the
//! depth-1 serial pass at every prefetch depth and thread count
//! (`rust/tests/differential.rs`).
//!
//! Since the cross-layer refactor the streaming scaffolding itself lives
//! in [`gcn::pipeline`](crate::gcn::pipeline): a single-layer forward is
//! the one-layer special case of the multi-layer engine
//! ([`OocGcnModel`](crate::gcn::pipeline::OocGcnModel) runs N layers under
//! one scheduler without draining the pipeline at layer boundaries), so
//! `forward_staged`/`forward_cpu` here are thin wrappers.

use crate::gcn::pipeline::{forward_pipelined_cpu, forward_pipelined_staged, PipelineConfig};
use crate::memsim::{CostModel, GpuMem};
use crate::runtime::chaos::FaultPlan;
use crate::runtime::heal::{HealPolicy, HealStats};
use crate::runtime::pool::Pool;
use crate::runtime::prefetch::Prefetch;
use crate::runtime::recycle::BufferPool;
use crate::runtime::segstore::SegmentStore;
use crate::runtime::Executor;
use crate::sparse::spmm::Dense;
use crate::sparse::Csr;
use anyhow::Result;
use std::sync::Arc;

/// Execution report for one out-of-core layer pass.
#[derive(Debug, Clone, Default)]
pub struct LayerReport {
    /// RoBW segments the adjacency streamed in.
    pub segments: usize,
    /// Estimated accelerator invocations (tile batches).
    pub artifact_calls_estimate: usize,
    /// Ledger high-water mark over the pass. With `prefetch_depth > 1`
    /// this includes staged-ahead segments and (alone among the report
    /// fields) depends on staging timing; everything else, above all the
    /// output, is deterministic.
    pub peak_gpu_bytes: u64,
    /// Total segment bytes staged host-to-device.
    pub h2d_bytes: u64,
    /// Staging depth the pass ran with (1 = serial staging).
    pub prefetch_depth: usize,
    /// Bytes actually read from the NVMe tier — *measured* I/O of a
    /// disk-backed pass (0 with in-memory backing; host-cache hits add
    /// nothing). Deterministic: the producer reads segments strictly in
    /// index order, so this does not depend on depth or thread count.
    pub disk_bytes: u64,
    /// Segment reads served by the host-RAM cache tier (disk backing only).
    pub cache_hits: usize,
    /// Segment reads that went to disk (disk backing only).
    pub cache_misses: usize,
    /// Seconds the cost model charges for the measured NVMe reads — set
    /// when a disk-backed pass runs with [`StagingConfig::io_cost`]
    /// attached: memsim charges the measured byte counts instead of
    /// sleeping on planner estimates.
    pub staged_io_modeled_s: f64,
    /// Recovery actions this layer's staging took (retries, quarantines,
    /// rebuilds, virtual backoff). All-zero on a fault-free pass — and the
    /// *only* field allowed to differ between a healed run and its
    /// fault-free oracle.
    pub heal: HealStats,
}

/// Where the Phase II producer gets segment bytes from.
#[derive(Debug, Clone, Default)]
pub enum StagingBacking {
    /// Slice segments out of the in-memory matrix (`materialize`) — the
    /// historical path; any attached [`StagingConfig::io_cost`] is charged
    /// as a simulated sleep on the planner-estimated segment bytes.
    #[default]
    Memory,
    /// Read segments from a spilled [`SegmentStore`] — the true
    /// out-of-core path: every staged segment is a checksum-verified file
    /// read served through the store's bounded host-RAM cache tier, and
    /// I/O accounting uses *measured* byte counts
    /// ([`LayerReport::disk_bytes`]) instead of simulated sleeps.
    Disk(Arc<SegmentStore>),
}

/// Phase II staging configuration for one forward pass.
#[derive(Debug, Clone, Default)]
pub struct StagingConfig {
    /// Pipeline depth policy (see [`Prefetch`]); defaults to double
    /// buffering (depth 2).
    pub prefetch: Prefetch,
    /// With [`StagingBacking::Memory`]: when set, the producer charges
    /// each segment's simulated H2D latency
    /// (`CostModel::transfer_secs(Op::HtoD, bytes)`) as real staging time
    /// — the I/O the scheduler models becomes wall-clock the pipeline must
    /// actually hide (the `micro_hotpath` overlap bench). With
    /// [`StagingBacking::Disk`] nothing sleeps — the file reads are real —
    /// and this model instead prices the measured disk bytes into
    /// [`LayerReport::staged_io_modeled_s`].
    pub io_cost: Option<CostModel>,
    /// Segment source: in-memory slicing (default) or a spilled
    /// [`SegmentStore`]. Output is byte-identical either way at every
    /// depth, thread count, and cache size
    /// (`rust/tests/differential.rs`).
    pub backing: StagingBacking,
    /// Buffer recycling policy. `None` (default) is the fresh-allocation
    /// oracle: every staged segment allocates its own scratch, exactly
    /// the historical behaviour. `Some(pool)` threads the
    /// [`BufferPool`] through the whole pipeline — the producer decodes
    /// into recycled scratch, the consumer hands drained buffers back
    /// through the prefetch return channel, and steady-state staging
    /// performs zero heap allocations per segment
    /// (`rust/tests/alloc_free.rs`). Output is byte-identical either way.
    pub recycle: Option<Arc<BufferPool>>,
    /// Recovery policy for tiered-store reads (see
    /// [`runtime::heal`](crate::runtime::heal)). The default is fail-fast
    /// — every store fault stays a typed error, exactly the historical
    /// behaviour. With retries/rebuild enabled, transient faults heal with
    /// virtual backoff and persistent corruption is quarantined and
    /// rebuilt, all counted in [`LayerReport::heal`]; the served bytes are
    /// identical either way.
    pub heal: HealPolicy,
    /// Optional seeded fault injector consulted before every disk-backed
    /// store read (see [`runtime::chaos`](crate::runtime::chaos)). `None`
    /// (default) injects nothing. Plans carry consumed per-target
    /// counters, so build a fresh plan per run when comparing runs.
    pub chaos: Option<Arc<FaultPlan>>,
    /// Zero-copy staging: serve raw-encoded segment reads (and spilled
    /// feature panels) as page-cache-backed mappings
    /// ([`SegmentStore::read_mapped`](crate::runtime::segstore::SegmentStore::read_mapped))
    /// instead of copying payloads into heap scratch, and spill
    /// intermediate panels as per-plan-boundary chunk records. Packed
    /// segments and non-native layouts transparently fall back to the
    /// copying decoder. Served bytes are identical either way
    /// (`rust/tests/differential.rs`); only the copy count changes
    /// (`rust/tests/alloc_free.rs`).
    pub mmap: bool,
}

impl StagingConfig {
    /// Serial staging (depth 1, in-memory, no charged I/O, fresh
    /// allocations): the oracle configuration.
    pub fn serial() -> StagingConfig {
        StagingConfig { prefetch: Prefetch::new(1), ..StagingConfig::default() }
    }

    /// In-memory double buffering at `depth` with no charged I/O.
    pub fn depth(depth: usize) -> StagingConfig {
        StagingConfig { prefetch: Prefetch::new(depth), ..StagingConfig::default() }
    }

    /// Disk-backed staging from `store` at `depth`.
    pub fn disk(store: Arc<SegmentStore>, depth: usize) -> StagingConfig {
        StagingConfig {
            prefetch: Prefetch::new(depth),
            backing: StagingBacking::Disk(store),
            ..StagingConfig::default()
        }
    }

    /// The same configuration with buffer recycling through `pool`.
    pub fn with_recycle(mut self, pool: Arc<BufferPool>) -> StagingConfig {
        self.recycle = Some(pool);
        self
    }

    /// The same configuration with recovery policy `heal`.
    pub fn with_heal(mut self, heal: HealPolicy) -> StagingConfig {
        self.heal = heal;
        self
    }

    /// The same configuration with fault injection from `plan`.
    pub fn with_chaos(mut self, plan: Arc<FaultPlan>) -> StagingConfig {
        self.chaos = Some(plan);
        self
    }

    /// The same configuration with zero-copy mapped reads toggled.
    pub fn with_mmap(mut self, mmap: bool) -> StagingConfig {
        self.mmap = mmap;
        self
    }
}

/// One out-of-core GCN layer (aggregation + fused combine).
#[derive(Debug, Clone)]
pub struct OocGcnLayer {
    /// Combination weights `[f, h]`.
    pub w: Dense,
    /// Combination bias `[h]`.
    pub b: Vec<f32>,
    /// Apply ReLU after the affine combine.
    pub relu: bool,
    /// Per-segment GPU byte budget for CSR A (Eq. 7's 3p).
    pub seg_budget: u64,
}

impl OocGcnLayer {
    /// Forward with serial staging and a serial pool — the oracle every
    /// pipelined configuration is byte-compared against.
    pub fn forward(
        &self,
        exec: &mut Executor,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
    ) -> Result<(Dense, LayerReport)> {
        self.forward_staged(exec, a_hat, x, mem, &Pool::serial(), &StagingConfig::serial())
    }

    /// Forward on `pool` with the default double-buffered staging.
    pub fn forward_pooled(
        &self,
        exec: &mut Executor,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
    ) -> Result<(Dense, LayerReport)> {
        self.forward_staged(exec, a_hat, x, mem, pool, &StagingConfig::default())
    }

    /// Forward: relu((Â·x)·w + b), streaming Â in RoBW segments through
    /// the prefetch pipeline.
    ///
    /// `mem` models the device: the feature panel and each in-flight
    /// segment are "allocated" and freed as the schedule would, so
    /// exceeding the constraint fails exactly like the simulated OOM.
    /// Budget for `staging.prefetch.depth` concurrent segments (the AIRES
    /// plan's `3p` term exists for exactly this headroom). Per-segment
    /// tile extraction/packing runs on `pool` (the CLI's `--threads`);
    /// staging of segment `i+1` overlaps segment `i`'s compute whenever
    /// the depth allows.
    pub fn forward_staged(
        &self,
        exec: &mut Executor,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        staging: &StagingConfig,
    ) -> Result<(Dense, LayerReport)> {
        let cfg = PipelineConfig::staged(staging.clone());
        let (out, rep) = forward_pipelined_staged(
            std::slice::from_ref(self),
            exec,
            a_hat,
            x,
            mem,
            pool,
            &cfg,
        )?;
        Ok((out, rep.into_single()))
    }

    /// Artifact-free forward pass: identical planning, ledger and prefetch
    /// pipeline, with per-segment aggregation on
    /// [`spmm_par_into`](crate::sparse::spmm::spmm_par_into) — each
    /// partial lands directly in its row range of the pass-wide
    /// aggregation panel, no per-segment partial is ever allocated — and
    /// the combination on the host. This is the execution surface the
    /// differential suite drives in environments without compiled PJRT
    /// artifacts; its output is byte-identical to
    /// `dense_affine(spmm(a_hat, x), w, b, relu)` at every prefetch depth
    /// and thread count.
    pub fn forward_cpu(
        &self,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        staging: &StagingConfig,
    ) -> Result<(Dense, LayerReport)> {
        let cfg = PipelineConfig::staged(staging.clone());
        let (out, rep) =
            forward_pipelined_cpu(std::slice::from_ref(self), a_hat, x, mem, pool, &cfg)?;
        Ok((out, rep.into_single()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gcn::model::dense_affine;
    use crate::runtime::find_artifact_dir;
    use crate::sparse::norm::normalize_adjacency;
    use crate::sparse::spmm::spmm;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn test_layer(rng: &mut Pcg, f: usize, h: usize, seg_budget: u64) -> OocGcnLayer {
        OocGcnLayer {
            w: Dense::from_vec(f, h, (0..f * h).map(|_| (rng.normal() * 0.2) as f32).collect()),
            b: vec![0.1; h],
            relu: true,
            seg_budget,
        }
    }

    #[test]
    fn ooc_layer_matches_reference() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exec = Executor::new(&dir).unwrap();
        let mut rng = Pcg::seed(5);
        // kmer-like small graph, 500 nodes (< K=1024 of the artifact).
        let a = crate::graphgen::kmer::generate(&mut rng, 500, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(500, 64, (0..500 * 64).map(|_| rng.normal() as f32).collect());
        let layer = test_layer(&mut rng, 64, 64, 4096);

        let mut mem = GpuMem::new(64 << 20);
        let (got, report) = layer.forward(&mut exec, &a_hat, &x, &mut mem).unwrap();
        assert!(report.segments > 1, "budget must force multiple segments");
        assert_eq!(report.prefetch_depth, 1, "forward() is the serial-staging oracle");

        let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, true);
        let diff = got.max_abs_diff(&want);
        assert!(diff < 1e-3, "max diff {diff}");

        // The double-buffered pooled pass is byte-identical to the oracle.
        let mut mem2 = GpuMem::new(64 << 20);
        let (got2, report2) =
            layer.forward_pooled(&mut exec, &a_hat, &x, &mut mem2, &Pool::new(4)).unwrap();
        assert_eq!(got2, got, "prefetch pipeline must not change the output");
        assert_eq!(report2.prefetch_depth, 2);
        assert_eq!(report2.h2d_bytes, report.h2d_bytes);
    }

    #[test]
    fn ooc_layer_ooms_when_panel_too_big() {
        let Some(dir) = find_artifact_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let mut exec = Executor::new(&dir).unwrap();
        let mut coo = Coo::new(64, 64);
        for i in 0..64u32 {
            coo.push(i, (i + 1) % 64, 1.0);
        }
        let a_hat = normalize_adjacency(&coo.to_csr());
        let x = Dense::zeros(64, 64);
        let layer = OocGcnLayer {
            w: Dense::zeros(64, 64),
            b: vec![0.0; 64],
            relu: true,
            seg_budget: 4096,
        };
        let mut mem = GpuMem::new(1024); // absurdly small
        assert!(layer.forward(&mut exec, &a_hat, &x, &mut mem).is_err());
    }

    #[test]
    fn cpu_forward_matches_oracle_at_every_depth_and_thread_count() {
        let mut rng = Pcg::seed(6);
        let a = crate::graphgen::kmer::generate(&mut rng, 300, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(300, 16, (0..300 * 16).map(|_| rng.normal() as f32).collect());
        let layer = test_layer(&mut rng, 16, 8, 2048);
        let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, true);

        for depth in [1usize, 2, 4] {
            for threads in [1usize, 2, 8] {
                let mut mem = GpuMem::new(64 << 20);
                let pool = Pool::new(threads);
                let (got, report) = layer
                    .forward_cpu(&a_hat, &x, &mut mem, &pool, &StagingConfig::depth(depth))
                    .unwrap();
                assert_eq!(got, want, "depth={depth} threads={threads}");
                assert!(report.segments > 1);
                assert_eq!(report.prefetch_depth, depth.max(1));
                assert_eq!(mem.used, 0, "everything freed after the pass");
            }
        }
    }

    #[test]
    fn cpu_forward_ooms_without_segment_headroom() {
        let mut rng = Pcg::seed(7);
        let a = crate::graphgen::kmer::generate(&mut rng, 200, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::zeros(200, 8);
        let layer = test_layer(&mut rng, 8, 8, 1024);
        // Panel fits, segments do not.
        let mut mem = GpuMem::new((200 * 8 * 4) + 64);
        let err = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .unwrap_err();
        assert!(err.to_string().contains("segment does not fit"), "{err}");
        assert_eq!(mem.used, 0, "error path must return panel + segments to the ledger");
    }

    #[test]
    fn disk_backed_forward_matches_memory_and_meters_io() {
        let mut rng = Pcg::seed(9);
        let a = crate::graphgen::kmer::generate(&mut rng, 250, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(250, 8, (0..250 * 8).map(|_| rng.normal() as f32).collect());
        let layer = test_layer(&mut rng, 8, 8, 2048);
        let mut mem = GpuMem::new(64 << 20);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .unwrap();
        assert_eq!(base.disk_bytes, 0, "in-memory staging reads no disk");

        let dir = crate::testing::TempDir::new("oocgcn-disk");
        let segs = crate::partition::robw::robw_partition(&a_hat, layer.seg_budget);
        let store = Arc::new(
            SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap(),
        );
        let mut staging = StagingConfig::disk(store.clone(), 2);
        staging.io_cost = Some(CostModel::default());
        let mut mem2 = GpuMem::new(64 << 20);
        let (got, rep) =
            layer.forward_cpu(&a_hat, &x, &mut mem2, &Pool::new(2), &staging).unwrap();
        assert_eq!(got, want, "disk-backed pass must be byte-identical");
        assert_eq!(rep.segments, base.segments);
        assert_eq!(rep.h2d_bytes, base.h2d_bytes);
        assert_eq!(rep.cache_misses, segs.len(), "cacheless store reads every file");
        assert_eq!(rep.cache_hits, 0);
        let expect_disk: u64 = (0..store.len()).map(|i| store.meta(i).file_bytes).sum();
        assert_eq!(rep.disk_bytes, expect_disk, "measured bytes = sum of file sizes");
        assert!(rep.staged_io_modeled_s > 0.0, "io_cost prices the measured bytes");
        assert_eq!(mem2.used, 0);
    }

    #[test]
    fn disk_backed_forward_rejects_mismatched_plan() {
        let mut rng = Pcg::seed(10);
        let a = crate::graphgen::kmer::generate(&mut rng, 200, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::zeros(200, 8);
        let layer = test_layer(&mut rng, 8, 8, 2048);
        let dir = crate::testing::TempDir::new("oocgcn-planmismatch");
        // Spill under a *different* budget than the layer plans with.
        let other = crate::partition::robw::robw_partition(&a_hat, 512);
        let store = Arc::new(SegmentStore::spill(&a_hat, &other, dir.path(), 0).unwrap());
        let mut mem = GpuMem::new(64 << 20);
        let err = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::disk(store, 1))
            .unwrap_err();
        assert!(err.to_string().contains("does not match the RoBW plan"), "{err}");
        assert_eq!(mem.used, 0, "plan guard fires before any allocation");
    }

    #[test]
    fn warm_host_cache_serves_second_pass_without_disk() {
        let mut rng = Pcg::seed(11);
        let a = crate::graphgen::kmer::generate(&mut rng, 200, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(200, 8, (0..200 * 8).map(|_| rng.normal() as f32).collect());
        let layer = test_layer(&mut rng, 8, 8, 1536);
        let segs = crate::partition::robw::robw_partition(&a_hat, layer.seg_budget);
        let dir = crate::testing::TempDir::new("oocgcn-warm");
        let unbounded = crate::runtime::segstore::UNBOUNDED_CACHE;
        let store =
            Arc::new(SegmentStore::spill(&a_hat, &segs, dir.path(), unbounded).unwrap());
        let staging = StagingConfig::disk(store, 2);
        let mut mem = GpuMem::new(64 << 20);
        let (first, rep1) =
            layer.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &staging).unwrap();
        assert_eq!(rep1.cache_misses, segs.len());
        let mut mem = GpuMem::new(64 << 20);
        let (second, rep2) =
            layer.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &staging).unwrap();
        assert_eq!(second, first);
        assert_eq!(rep2.cache_hits, segs.len(), "warm pass is all host-tier hits");
        assert_eq!(rep2.disk_bytes, 0);
    }

    #[test]
    fn recycled_staging_is_byte_identical_and_actually_recycles() {
        let mut rng = Pcg::seed(12);
        let a = crate::graphgen::kmer::generate(&mut rng, 250, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(250, 8, (0..250 * 8).map(|_| rng.normal() as f32).collect());
        let layer = test_layer(&mut rng, 8, 8, 1536);
        let mut mem = GpuMem::new(64 << 20);
        let (want, base) = layer
            .forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &StagingConfig::serial())
            .unwrap();
        assert!(base.segments > 3, "need a real stream");

        let pool_mem = Arc::new(BufferPool::new(64 << 20));
        for depth in [1usize, 2, 4] {
            // In-memory backing, recycled.
            let staging = StagingConfig::depth(depth).with_recycle(pool_mem.clone());
            let mut mem = GpuMem::new(64 << 20);
            let (got, rep) =
                layer.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &staging).unwrap();
            assert_eq!(got, want, "memory recycled depth={depth}");
            assert_eq!(rep.h2d_bytes, base.h2d_bytes);
            assert_eq!(mem.used, 0);
        }
        let st = pool_mem.stats();
        assert!(st.hits > 0, "buffers must actually cycle through the pool");
        assert!(st.returns > 0, "end-of-stream buffers retire to the pool");

        // Disk backing, recycled, cacheless (every read from a file).
        let dir = crate::testing::TempDir::new("oocgcn-recycle");
        let segs = crate::partition::robw::robw_partition(&a_hat, layer.seg_budget);
        let store = Arc::new(SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap());
        let pool_disk = Arc::new(BufferPool::new(64 << 20));
        for depth in [1usize, 2] {
            let staging =
                StagingConfig::disk(store.clone(), depth).with_recycle(pool_disk.clone());
            let mut mem = GpuMem::new(64 << 20);
            let (got, rep) =
                layer.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &staging).unwrap();
            assert_eq!(got, want, "disk recycled depth={depth}");
            assert_eq!(rep.cache_hits, 0);
            assert_eq!(mem.used, 0);
        }
        assert!(pool_disk.stats().hits > 0);
    }

    #[test]
    fn ledger_balances_under_tight_budgets_at_every_depth() {
        // Near the OOM boundary with staging concurrency the *outcome*
        // (Ok vs segment-OOM) may depend on timing, but the invariants may
        // not: a success is byte-identical to the oracle and an error
        // leaves the ledger fully freed.
        let mut rng = Pcg::seed(8);
        let a = crate::graphgen::kmer::generate(&mut rng, 200, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(200, 8, (0..200 * 8).map(|_| rng.normal() as f32).collect());
        let layer = test_layer(&mut rng, 8, 8, 1024);
        let want = dense_affine(&spmm(&a_hat, &x), &layer.w, &layer.b, true);
        let panel = (200 * 8 * 4) as u64;
        for depth in [1usize, 2, 4] {
            for headroom in [1024u64, 1536, 2048, 4096] {
                let mut mem = GpuMem::new(panel + headroom);
                let pool = Pool::new(2);
                match layer.forward_cpu(&a_hat, &x, &mut mem, &pool, &StagingConfig::depth(depth))
                {
                    Ok((got, _)) => assert_eq!(got, want, "depth={depth} headroom={headroom}"),
                    Err(e) => assert!(
                        e.to_string().contains("segment does not fit"),
                        "depth={depth} headroom={headroom}: {e}"
                    ),
                }
                assert_eq!(mem.used, 0, "depth={depth} headroom={headroom}: ledger unbalanced");
            }
        }
    }
}
